"""Pipeline parallelism: GPipe schedule must equal the sequential stack
(forward AND gradients), run on a 4-stage host-device mesh."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.distribute.pipeline import gpipe

P_, M, B, D = 4, 8, 16, 32
mesh = Mesh(np.asarray(jax.devices()).reshape(P_), ("pp",))
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.standard_normal((P_, D, D)) * 0.2, jnp.float32)
x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

# stage_fn receives this stage's local slice (leading dim = 1 here)
stage = lambda w, a: jnp.tanh(a @ w[0])

# sequential reference
ref = x
for i in range(P_):
    ref = jnp.tanh(ref @ Ws[i])

out = gpipe(stage, Ws, x, mesh=mesh, microbatches=M)
err = float(jnp.max(jnp.abs(out - ref)))
assert err < 1e-5, err

# gradients flow through the pipeline (backward schedule via autodiff)
def loss_pipe(Ws):
    o = gpipe(stage, Ws, x, mesh=mesh, microbatches=M)
    return jnp.sum(o * o)

def loss_ref(Ws):
    a = x
    for i in range(P_):
        a = jnp.tanh(a @ Ws[i])
    return jnp.sum(a * a)

g1 = jax.grad(loss_pipe)(Ws)
g2 = jax.grad(loss_ref)(Ws)
gerr = float(jnp.max(jnp.abs(g1 - g2)))
assert gerr < 1e-4, gerr
print("PIPELINE_OK", err, gerr)
"""


@pytest.mark.slow
def test_gpipe_matches_sequential_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "PIPELINE_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
