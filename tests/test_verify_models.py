"""The verify models + invariants + explorer budget satellite.

Small configs here (the CLI's acceptance matrix runs the full
over-committed 3x3>6 instance); the point of each test is a property
of the machinery, not scale.
"""

import pytest

from repro.core.explorer import explore
from repro.verify.harness import (ServerConfig, ServerScenario, canon_pages,
                                  empty_projection)
from repro.verify.invariants import (allocator_invariants, drain_incomplete,
                                     server_invariants, spec_invariants,
                                     violated, violates_any)
from repro.verify.models import (AllocConfig, AllocatorSemantics,
                                 ServerSemantics, SpecConfig, SpecSemantics,
                                 build_driver_model)
from repro.verify.mutants import MUTANTS

SMALL = AllocConfig(n_slots=2, page_size=2, pages_per_slot=2, n_pages=3)


# ---------------------------------------------------------------------------
# explorer budget satellite
# ---------------------------------------------------------------------------


def test_bounded_run_is_distinct_from_verified():
    sem = AllocatorSemantics(AllocConfig(), canonical=True)
    res = explore(build_driver_model(sem),
                  violates_any(allocator_invariants()),
                  schedule="por", max_states=50)
    assert res.truncated and res.property_holds
    assert res.status == "bounded"
    assert res.bound_reason == "max_states"
    assert res.frontier_peak > 0
    assert res.states <= 50 + 1


def test_depth_limit_reported_as_bound_reason():
    sem = AllocatorSemantics(SMALL, canonical=True)
    res = explore(build_driver_model(sem),
                  violates_any(allocator_invariants()),
                  schedule="por", depth_limit=4)
    assert res.status == "bounded"
    assert res.bound_reason == "depth_limit"


def test_violation_wins_over_bound_and_on_violation_fires():
    sem = AllocatorSemantics(SMALL, canonical=True)
    seen = []
    # "violation": any page allocated at all — reachable in one op
    res = explore(build_driver_model(sem),
                  lambda G: G["alloc"][4][0] >= 0,
                  schedule="por", stop_on_first=False,
                  on_violation=seen.append)
    assert res.status == "violated" and not res.property_holds
    assert res.counterexample is not None
    assert seen and seen[0].trail == res.counterexample.trail
    assert len(seen) >= 1


def test_verified_status_on_exhausted_space():
    sem = AllocatorSemantics(SMALL, canonical=True)
    res = explore(build_driver_model(sem),
                  violates_any(allocator_invariants()),
                  schedule="por")
    assert res.status == "verified"
    assert not res.truncated and res.bound_reason is None
    assert res.states > 100


# ---------------------------------------------------------------------------
# page-symmetry canonicalization
# ---------------------------------------------------------------------------


def test_canon_pages_fixes_initial_projection():
    proj = empty_projection(SMALL.n_slots, SMALL.kv_spec())
    assert canon_pages(proj) == proj


def test_canon_pages_idempotent_and_structure_preserving():
    sem = AllocatorSemantics(SMALL, canonical=False)
    G = sem.init_globals()
    for op in [("ensure", 0, 4), ("share", 0, 1, 2), ("release", 0),
               ("ensure", 0, 2)]:
        sem.apply(G, op)
    c1 = canon_pages(G["alloc"])
    assert canon_pages(c1) == c1
    # same structure: refcount multiset, mapped-cell pattern, tops
    assert sorted(c1[1]) == sorted(G["alloc"][1])
    assert c1[4] == G["alloc"][4]
    assert [[p == -1 for p in row] for row in c1[0]] == \
        [[p == -1 for p in row] for row in G["alloc"][0]]


def test_canonical_and_exact_models_agree_on_invariants():
    for canonical in (False, True):
        sem = AllocatorSemantics(SMALL, canonical=canonical)
        res = explore(build_driver_model(sem),
                      violates_any(allocator_invariants()),
                      schedule="por")
        assert res.status == "verified"


def test_canonical_quotient_is_smaller():
    exact = explore(build_driver_model(AllocatorSemantics(SMALL)),
                    violates_any(allocator_invariants()), schedule="por")
    quot = explore(
        build_driver_model(AllocatorSemantics(SMALL, canonical=True)),
        violates_any(allocator_invariants()), schedule="por")
    assert quot.states < exact.states


# ---------------------------------------------------------------------------
# invariant predicates on seeded-bad states
# ---------------------------------------------------------------------------


def _bad(proj):
    return violated(allocator_invariants(), {"alloc": proj})


def test_invariants_catch_refcount_drift():
    pt = ((0, -1), (-1, -1))
    assert "refcount_conservation" in _bad(
        (pt, (2, 0, 0), (0, -1, -1), (2, 1), (0, -1)))


def test_invariants_catch_lost_page():
    pt = ((-1, -1), (-1, -1))
    # page 0 neither free nor held
    assert "no_lost_pages" in _bad(
        (pt, (0, 0, 0), (-1, -1, -1), (2, 1), (-1, -1)))


def test_invariants_catch_double_free():
    pt = ((-1, -1), (-1, -1))
    assert "no_double_free" in _bad(
        (pt, (0, 0, 0), (-1, -1, -1), (2, 1, 1), (-1, -1)))


def test_invariants_catch_freed_page_still_mapped():
    pt = ((0, -1), (-1, -1))
    bad = _bad((pt, (0, 0, 0), (-1, -1, -1), (2, 1, 0), (0, -1)))
    assert "freed_never_mapped" in bad


def test_invariants_catch_owner_inconsistency():
    pt = ((0, -1), (-1, -1))
    # page 0 held by slot 0 but owner says slot 1
    assert "owner_consistent" in _bad(
        (pt, (1, 0, 0), (1, -1, -1), (2, 1), (0, -1)))


def test_invariants_catch_entry_above_high_water():
    pt = ((-1, 0), (-1, -1))
    assert "high_water_clean" in _bad(
        (pt, (1, 0, 0), (0, -1, -1), (2, 1), (-1, -1)))


def test_clean_projection_passes_all():
    assert _bad(empty_projection(2, SMALL.kv_spec())) == []


# ---------------------------------------------------------------------------
# the three machines, exhaustively
# ---------------------------------------------------------------------------


def test_allocator_model_verified_on_overcommitted_small_config():
    sem = AllocatorSemantics(SMALL, canonical=True)
    res = explore(build_driver_model(sem),
                  violates_any(allocator_invariants()),
                  schedule="por", collect_terminals=True)
    assert res.status == "verified"
    # no deadlock: some op is enabled at every state
    assert res.terminals == []


SCEN = ServerScenario(name="t", prompts=((3, 3, 3), (4, 4, 4, 4), (5, 5)),
                      max_new=(2, 1, 1))


@pytest.mark.parametrize("cfg,scen", [
    (ServerConfig(policy="fcfs", batch=3), SCEN),
    (ServerConfig(policy="fcfs", batch=3, share_prefix=True),
     ServerScenario(name="share",
                    prompts=((7, 7, 7, 7), (7, 7, 7, 5), (7, 7)),
                    max_new=(2, 1, 1))),
    (ServerConfig(policy="priority", batch=2, aging_slack=3),
     ServerScenario(name="slo", prompts=((3, 3, 3), (4, 4), (5, 5, 5)),
                    max_new=(2, 1, 1),
                    slo=("batch", "interactive", "interactive"))),
    (ServerConfig(policy="prefix", batch=3, share_prefix=True),
     ServerScenario(name="pf", prompts=((7, 7, 7, 7), (7, 7, 7, 5), (9, 9)),
                    max_new=(2, 1, 1))),
], ids=["fcfs", "fcfs-share", "priority", "prefix"])
def test_server_model_verified_and_drains(cfg, scen):
    sem = ServerSemantics(cfg, scen)
    res = explore(build_driver_model(sem),
                  violates_any(server_invariants(cfg)),
                  schedule="por", collect_terminals=True)
    assert res.status == "verified", res.counterexample
    assert res.terminals, "model must reach a drained terminal"
    for t in res.terminals:
        assert drain_incomplete(t.globals) == []


def test_server_model_catches_planted_allocator_bug():
    cfg = ServerConfig(policy="fcfs", batch=3, share_prefix=True)
    scen = ServerScenario(name="share",
                          prompts=((7, 7, 7, 7), (7, 7, 7, 5), (7, 7)),
                          max_new=(2, 1, 1))
    sem = ServerSemantics(cfg, scen,
                          allocator_cls=MUTANTS["share-skips-refcount"])
    res = explore(build_driver_model(sem),
                  violates_any(server_invariants(cfg)),
                  schedule="por")
    assert res.status == "violated"
    broken = violated(server_invariants(cfg), res.counterexample.globals)
    assert broken


def test_spec_model_verified_and_both_slots_retire():
    cfg = SpecConfig()
    sem = SpecSemantics(cfg)
    res = explore(build_driver_model(sem),
                  violates_any(spec_invariants(cfg)),
                  schedule="por", collect_terminals=True)
    assert res.status == "verified", res.counterexample
    assert res.terminals
    for t in res.terminals:
        assert t.globals["done"] == (1, 1)
        # every page handed back
        assert len(t.globals["alloc"][3]) == cfg.n_pages


def test_spec_model_exercises_draft_shrinking():
    """At least one reachable state offers a spec op whose full depth
    does NOT fit — the shrink loop's raison d'etre."""

    cfg = SpecConfig()
    sem = SpecSemantics(cfg)
    seen_shrink = []

    class Probe(SpecSemantics):
        def apply(self, G, op):
            if op[0] == "spec":
                d = op[1]
                if not self._grow_fits(G, 0, G["pos"][0] + d + 1):
                    seen_shrink.append(op)
            return SpecSemantics.apply(self, G, op)

    probe = Probe(cfg)
    explore(build_driver_model(probe),
            violates_any(spec_invariants(cfg)), schedule="por")
    assert seen_shrink, "pool never forced a draft shrink; tighten SpecConfig"
