"""Substrate tests: optimizer, compression, data pipeline, checkpointing,
fault-tolerant loop (failure injection, straggler re-dispatch, restart)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import hypothesis, st  # skips property tests if absent
from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import DataConfig, SyntheticLM
from repro.configs.base import ShapeSpec
from repro.models import build_model
from repro.optim import (adamw_init, adamw_update, compress_int8,
                         cosine_schedule, decompress_int8, ef_compress_grads,
                         global_norm)
from repro.runtime import (LoopConfig, SimulatedFailure, TrainConfig,
                           build_train_step, init_train_state, run_training)

settings = hypothesis.settings(max_examples=20, deadline=None,
                               suppress_health_check=list(hypothesis.HealthCheck))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - jnp.array([1.0, 2.0])) ** 2)

    for _ in range(400):
        g = jax.grad(loss)(params)
        params, opt, m = adamw_update(params, g, opt, lr=0.05,
                                      weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 2.0], atol=0.05)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.int32(100))) < 1e-5
    assert float(lr(jnp.int32(5))) == pytest.approx(5e-4)


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    huge = {"w": jnp.full(4, 1e9)}
    _, _, m = adamw_update(params, huge, opt, lr=1e-3, clip_norm=1.0)
    assert float(m["grad_norm"]) == pytest.approx(2e9, rel=1e-3)


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@settings
@hypothesis.given(n=st.integers(1, 2000), seed=st.integers(0, 2**31))
def test_int8_roundtrip_bounded_error(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n), jnp.float32)
    q, s = compress_int8(x)
    y = decompress_int8(q, s, x.shape)
    blockwise_max = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - y))) <= blockwise_max / 127.0 + 1e-6


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(512),
                          jnp.float32)}
    out1, r1 = ef_compress_grads(g, None)
    # the residual is exactly the quantization error
    np.testing.assert_allclose(
        np.asarray(g["w"] - out1["w"]), np.asarray(r1["w"]), atol=1e-6)
    # feeding zero grads next step flushes the residual back in
    zero = {"w": jnp.zeros(512)}
    out2, r2 = ef_compress_grads(zero, r1)
    total = np.asarray(out1["w"] + out2["w"] + r2["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]), atol=1e-5)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_sharding():
    cfg = get_config("smollm-135m").reduced()
    shape = ShapeSpec("t", 32, 8, "train")
    d0 = SyntheticLM(cfg, shape, DataConfig(seed=1), host_index=0,
                     host_count=2)
    d1 = SyntheticLM(cfg, shape, DataConfig(seed=1), host_index=1,
                     host_count=2)
    assert d0.local_batch == 4
    b0a, b0b = d0.batch(7), d0.batch(7)
    np.testing.assert_array_equal(np.asarray(b0a["tokens"]),
                                  np.asarray(b0b["tokens"]))
    # different hosts see different data
    assert not np.array_equal(np.asarray(d0.batch(7)["tokens"]),
                              np.asarray(d1.batch(7)["tokens"]))
    # iterator resumes mid-stream
    it = d0.iterate(start=7)
    np.testing.assert_array_equal(np.asarray(next(it)["tokens"]),
                                  np.asarray(b0a["tokens"]))


def test_data_tokens_in_vocab():
    cfg = get_config("smollm-135m").reduced()
    shape = ShapeSpec("t", 64, 4, "train")
    d = SyntheticLM(cfg, shape)
    t = np.asarray(d.batch(0)["tokens"])
    assert t.min() >= 0 and t.max() < cfg.vocab


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "scalar": jnp.float32(3.5)}
    save_checkpoint(str(tmp_path), 3, tree)
    out, manifest = load_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert np.asarray(out["nested"]["b"]).dtype == np.dtype("bfloat16") or \
        str(np.asarray(out["nested"]["b"]).dtype) == "bfloat16"


def test_checkpoint_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, save_interval=1)
    tree = {"w": jnp.zeros(3)}
    for step in (1, 2, 3, 4):
        mgr.save(step, jax.tree.map(lambda x: x + step, tree))
    mgr.wait()
    from repro.checkpoint.store import available_steps
    assert available_steps(str(tmp_path)) == [3, 4]
    assert mgr.latest_step() == 4
    out, _ = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), [4, 4, 4])


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)})
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


# ---------------------------------------------------------------------------
# fault-tolerant loop
# ---------------------------------------------------------------------------

def _toy_setup():
    cfg = get_config("smollm-135m").reduced()
    api = build_model(cfg)
    tcfg = TrainConfig(lr=1e-3, warmup=2, total_steps=50)
    state = init_train_state(api, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(build_train_step(api, tcfg))
    shape = ShapeSpec("t", 16, 2, "train")
    data = SyntheticLM(cfg, shape)
    return state, step, data


def test_loop_runs_and_loss_decreases(tmp_path):
    state, step, data = _toy_setup()
    final, hist = run_training(
        step_fn=step, init_state=state, batch_fn=data.batch,
        cfg=LoopConfig(total_steps=30, ckpt_every=10),
        ckpt_dir=str(tmp_path))
    assert len(hist.losses) == 30
    assert np.mean(hist.losses[-5:]) < np.mean(hist.losses[:5])


def test_loop_failure_injection_restores(tmp_path):
    state, step, data = _toy_setup()
    fail_at = {12}

    def inject(step_i):
        if step_i in fail_at:
            fail_at.clear()
            raise SimulatedFailure("pod lost")

    final, hist = run_training(
        step_fn=step, init_state=state, batch_fn=data.batch,
        cfg=LoopConfig(total_steps=20, ckpt_every=5),
        ckpt_dir=str(tmp_path), inject=inject)
    assert hist.restarts == 1
    assert hist.resumed_from == [10]      # restarted from step 10 ckpt
    assert len(hist.losses) >= 20


def test_loop_restart_resumes_from_checkpoint(tmp_path):
    state, step, data = _toy_setup()
    _, hist1 = run_training(
        step_fn=step, init_state=state, batch_fn=data.batch,
        cfg=LoopConfig(total_steps=10, ckpt_every=5),
        ckpt_dir=str(tmp_path))
    # second run continues to 15 from the committed step-10 checkpoint
    _, hist2 = run_training(
        step_fn=step, init_state=state, batch_fn=data.batch,
        cfg=LoopConfig(total_steps=15, ckpt_every=5),
        ckpt_dir=str(tmp_path))
    assert hist2.resumed_from == [10]
    assert len(hist2.losses) == 5


def test_loop_straggler_redispatch():
    state, step, data = _toy_setup()
    import time as _t
    slow = {8}

    def inject(step_i):
        if step_i in slow:
            slow.clear()
            _t.sleep(1.0)

    _, hist = run_training(
        step_fn=step, init_state=state, batch_fn=data.batch,
        cfg=LoopConfig(total_steps=12, straggler_factor=2.5), inject=inject)
    assert hist.straggler_events, "slow step not detected"
    assert hist.redispatched >= 1
