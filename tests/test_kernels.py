"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes/dtypes + hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_stub import hypothesis, st  # skips property tests if absent

from repro.kernels.flash_attention.ops import attention_ref, flash_attention
from repro.kernels.matmul_tuned.ops import matmul_ref, matmul_tuned
from repro.kernels.tuned_reduction.ops import reduce_1d, reduce_ref

settings = hypothesis.settings(max_examples=25, deadline=None,
                               suppress_health_check=list(hypothesis.HealthCheck))


# ---------------------------------------------------------------------------
# tuned_reduction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [1, 100, 128 * 8, 128 * 8 * 3 + 17, 100_000])
@pytest.mark.parametrize("op", ["min", "max"])
def test_reduce_matches_ref(dtype, n, op):
    rng = np.random.default_rng(hash((n, op)) % 2**32)
    x = jnp.asarray(rng.standard_normal(n) * 100, dtype)
    got = reduce_1d(x, op=op, block_rows=16)
    want = reduce_ref(x, op)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_rows", [8, 16, 64, 256])
def test_reduce_block_size_invariance(block_rows):
    """Tuning parameter must not change the result (the invariant the
    paper's auto-tuning relies on)."""

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-2**30, 2**30, size=12_345), jnp.int32)
    got = reduce_1d(x, op="min", block_rows=block_rows)
    assert int(got) == int(reduce_ref(x, "min"))


@settings
@hypothesis.given(n=st.integers(1, 5000), seed=st.integers(0, 2**31),
                  op=st.sampled_from(["min", "max", "sum"]))
def test_reduce_property(n, seed, op):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-10**6, 10**6, size=n), jnp.int32)
    got = reduce_1d(x, op=op, block_rows=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(reduce_ref(x, op)))


# ---------------------------------------------------------------------------
# matmul_tuned
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 512),
                                   (512, 128, 256)])
def test_matmul_matches_ref(dtype, tol, shape):
    M, N, K = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    a = jnp.asarray(rng.standard_normal((M, K)), dtype)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype)
    got = matmul_tuned(a, b, bm=128, bn=128, bk=128)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * K ** 0.5)


@pytest.mark.parametrize("blocks", [(128, 128, 128), (256, 128, 256),
                                    (128, 256, 512)])
def test_matmul_block_invariance(blocks):
    bm, bn, bk = blocks
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((256, 512)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    got = matmul_tuned(a, b, bm=bm, bn=bn, bk=bk)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=5e-2)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(dtype, tol, causal):
    rng = np.random.default_rng(11)
    B, H, S, D = 2, 2, 256, 64
    q, k, v = (jnp.asarray(rng.standard_normal((B, H, S, D)), dtype)
               for _ in range(3))
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol,
                               atol=tol * 10)


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_sliding_window(window):
    rng = np.random.default_rng(13)
    B, H, S, D = 1, 2, 256, 64
    q, k, v = (jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
               for _ in range(3))
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128), (256, 256)])
def test_flash_block_invariance(bq, bk):
    rng = np.random.default_rng(5)
    q, k, v = (jnp.asarray(rng.standard_normal((1, 1, 256, 64)), jnp.float32)
               for _ in range(3))
    ref = attention_ref(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-4)


@settings
@hypothesis.given(
    s_blocks=st.integers(1, 4), d=st.sampled_from([64, 128]),
    seed=st.integers(0, 2**31), causal=st.booleans())
def test_flash_property(s_blocks, d, seed, causal):
    S = 64 * s_blocks
    rng = np.random.default_rng(seed)
    q, k, v = (jnp.asarray(rng.standard_normal((1, 1, S, d)), jnp.float32)
               for _ in range(3))
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# sweep_eval (the tuner's lattice evaluator as a TPU kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("warp", [None, 8])
@pytest.mark.parametrize("block_rows", [8, 64])
def test_sweep_eval_matches_wave_model(warp, block_rows):
    from repro.core.search_space import wg_ts_space
    from repro.core.wave_model import WaveParams, model_time
    from repro.kernels.sweep_eval.ops import sweep_eval

    p = WaveParams(size=1 << 12, NP=64, GMT=16, L=4, kind="minimum",
                   NU=15, warp=warp)
    arrs = wg_ts_space(p.size).to_arrays()
    out = np.asarray(sweep_eval(jnp.asarray(arrs["WG"], jnp.int32),
                                jnp.asarray(arrs["TS"], jnp.int32), p,
                                block_rows=block_rows))
    for i, (wg, ts) in enumerate(zip(arrs["WG"], arrs["TS"])):
        assert out[i] == model_time(p, int(wg), int(ts))


@settings
@hypothesis.given(size_exp=st.integers(4, 16), np_exp=st.integers(2, 7),
                  gmt=st.sampled_from([4, 16, 64]))
def test_sweep_eval_property(size_exp, np_exp, gmt):
    from repro.core.search_space import wg_ts_space
    from repro.core.wave_model import WaveParams, model_time
    from repro.kernels.sweep_eval.ops import sweep_eval

    p = WaveParams(size=1 << size_exp, NP=1 << np_exp, GMT=gmt,
                   kind="minimum")
    arrs = wg_ts_space(p.size).to_arrays()
    out = np.asarray(sweep_eval(jnp.asarray(arrs["WG"], jnp.int32),
                                jnp.asarray(arrs["TS"], jnp.int32), p))
    idx = int(np.argmin(out))
    truth = min(model_time(p, int(w), int(t))
                for w, t in zip(arrs["WG"], arrs["TS"]))
    assert int(out[idx]) == truth
