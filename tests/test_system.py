"""End-to-end behaviour tests for the whole system: the paper's method
tunes a real kernel and a real training configuration; training improves
under the tuned configuration; all engines agree."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core import (PlatformSpec, WaveParams, model_time, wg_ts_space)
from repro.core.tpu_machine import (TPUConfig, TPUWorkload, hbm_fits,
                                    step_time, tune_distributed,
                                    workload_from_arch)
from repro.data import SyntheticLM
from repro.models import build_model
from repro.runtime import (LoopConfig, TrainConfig, build_train_step,
                           init_train_state, run_training)
from repro.tune import FunctionTunable, PlatformTunable, tune


def test_four_step_method_end_to_end():
    """Steps 1-4 of the paper on the Minimum problem: tune, validate the
    counterexample, confirm optimality against the exhaustive grid."""

    spec = PlatformSpec(size=32, NP=4, GMT=4, kind="minimum")
    res = tune(PlatformTunable(spec), engine="explorer", cache=None)
    wp = WaveParams(size=32, NP=4, GMT=4, kind="minimum")
    truth = min(model_time(wp, c["WG"], c["TS"]) for c in wg_ts_space(32))
    assert res.t_min == truth
    from repro.core import build_model as build_platform
    assert res.witness.validate(build_platform(spec))


def test_tuned_kernel_beats_naive_cost():
    """The tuner's block size must beat the worst lattice point on the
    kernel cost model (and the kernel result stays exact)."""

    from repro.kernels.tuned_reduction import ops as red
    n = 1 << 18
    space = red.tuning_space(n)
    costs = {cfg["block_rows"]: red.cost_model(cfg, n=n) for cfg in space}
    res = tune(FunctionTunable(lambda c: red.cost_model(c, n=n), space),
               engine="grid", cache=None)
    assert res.t_min == min(costs.values())
    x = jnp.asarray(np.random.default_rng(0).integers(-10**9, 10**9, n),
                    jnp.int32)
    got = red.reduce_1d(x, op="min",
                        block_rows=res.best_config["block_rows"])
    assert int(got) == int(red.reduce_ref(x, "min"))


def test_distributed_tuner_respects_hbm_and_improves():
    w = workload_from_arch("qwen3-32b", "train_4k")
    best, t, ranked = tune_distributed(w, chips_per_pod=256, pods=1)
    assert hbm_fits(w, best)
    base = step_time(w, TPUConfig(dp=16, tp=16, pods=1, microbatches=1))
    assert t["total"] <= base["total"] * 1.0001
    totals = [r[0] for r in ranked]
    assert totals == sorted(totals)


def test_llama4_single_pod_infeasible_two_pods_feasible():
    """The machine model reproduces the dry-run finding: 400B params do
    not fit one 256-chip v5e pod for training, but fit two pods."""

    w = workload_from_arch("llama4-maverick-400b-a17b", "train_4k")
    with pytest.raises(RuntimeError):
        tune_distributed(w, chips_per_pod=256, pods=1)
    best, t, _ = tune_distributed(w, chips_per_pod=256, pods=2)
    assert best.fsdp            # only FSDP variants fit


def test_training_improves_under_tuned_config():
    cfg = get_config("smollm-135m").reduced()
    api = build_model(cfg)
    w = TPUWorkload(params=api.param_count(),
                    active_params=api.param_count(), layers=cfg.n_layers,
                    d_model=cfg.d_model, seq=64, global_batch=16,
                    vocab=cfg.vocab)
    best, _, _ = tune_distributed(w, chips_per_pod=1, pods=1)
    tcfg = TrainConfig(lr=3e-3, warmup=5, total_steps=100,
                       microbatches=min(best.microbatches, 4))
    state = init_train_state(api, jax.random.PRNGKey(0), tcfg)
    step = jax.jit(build_train_step(api, tcfg))
    data = SyntheticLM(cfg, ShapeSpec("t", 64, 16, "train"))
    _, hist = run_training(step_fn=step, init_state=state,
                           batch_fn=data.batch,
                           cfg=LoopConfig(total_steps=40))
    assert np.mean(hist.losses[-5:]) < np.mean(hist.losses[:5]) - 0.2


def test_microbatched_step_matches_unbatched():
    """Gradient accumulation must be loss/grad-equivalent to the full
    batch (up to accumulation-order rounding)."""

    cfg = get_config("smollm-135m").reduced()
    api = build_model(cfg)
    data = SyntheticLM(cfg, ShapeSpec("t", 32, 8, "train"))
    batch = data.batch(0)
    t1 = TrainConfig(lr=1e-3, warmup=1, total_steps=10, microbatches=1)
    t4 = TrainConfig(lr=1e-3, warmup=1, total_steps=10, microbatches=4)
    s1 = init_train_state(api, jax.random.PRNGKey(0), t1)
    s4 = init_train_state(api, jax.random.PRNGKey(0), t4)
    n1, m1 = jax.jit(build_train_step(api, t1))(s1, batch)
    n4, m4 = jax.jit(build_train_step(api, t4))(s4, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        n1.params, n4.params)
    assert max(jax.tree.leaves(d)) < 5e-2


def test_chunked_ce_equals_fused():
    """loss_seq_chunk is a memory-layout change only — bit-identical."""

    cfg = get_config("smollm-135m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 20)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 20)),
                                   jnp.int32)}
    l1 = api.loss(params, batch)
    l2 = build_model(cfg.replace(loss_seq_chunk=8)).loss(params, batch)
    assert float(l1) == float(l2)


def test_ssd_bf16_close_to_f32():
    cfg = get_config("mamba2-2.7b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 16)), jnp.int32)
    f1 = api.forward(params, {"tokens": toks}).astype(jnp.float32)
    f2 = build_model(cfg.replace(ssd_dtype="bfloat16")).forward(
        params, {"tokens": toks}).astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(f1 - f2))) / float(jnp.max(jnp.abs(f1)))
    assert rel < 0.05
