"""Dry-run machinery tests: sharding resolution, HLO collective parsing,
scan trip-count semantics, cell lowering on small meshes, roofline math."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distribute.sharding import (Rules, arg_sharding, default_rules,
                                       shard_like)
from repro.launch.cells import collective_bytes, lower_cell, rules_for_arch
from repro.launch.roofline import analyze, model_flops

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


def small_mesh():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_rules_spec_dedups_axes():
    r = Rules.make(batch=("data",), embed=("data",), mlp="model")
    spec = r.spec(("batch", "seq", "embed"))
    assert spec == P(("data",), None, None) or spec == P("data", None, None)


def test_arg_sharding_divisibility_fallback():
    mesh = small_mesh()
    r = default_rules()
    # 1-ways always divide; use a fake 16-way sizes check via the rule
    # logic instead: non-divisible heads fall back to embed
    sh = arg_sharding((2560, 20, 128), ("embed", "heads", None), mesh, r)
    assert sh.spec[0] is not None          # embed got the batch axes


def test_arg_sharding_prefers_canonical_rule():
    dev = np.asarray(jax.devices() * 1).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    r = default_rules()
    sh = arg_sharding((4096, 32, 128), ("embed", "heads", None), mesh, r)
    # with 1-sized axes everything divides; heads keeps "model"
    assert sh.spec[1] == "model"


def test_rules_for_arch_moe_fallback():
    from repro.configs import get_config
    r8 = rules_for_arch(get_config("mixtral-8x22b"))
    assert r8.get("experts") is None and r8.get("expert_mlp") == "model"
    r128 = rules_for_arch(get_config("llama4-maverick-400b-a17b"))
    assert r128.get("experts") == "model"


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

SAMPLE_HLO = """
  %ar = f32[16000,4096]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[256,1024]{1,0} all-gather(%y), dimensions={0}
  %rs = (f32[128]{0}, f32[128]{0}) reduce-scatter(%a, %b), dimensions={0}
  %cp = u32[64]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = s8[32,32]{1,0} all-to-all(%w), dimensions={1}
  %ar2 = f32[10]{0} all-reduce-start(%q), replica_groups={}
  %not_a_collective = f32[5]{0} add(%p, %q)
"""


def test_collective_bytes_parser():
    out = collective_bytes(SAMPLE_HLO)
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 16000 * 4096 * 4 + 10 * 4
    assert out["all-gather"]["bytes"] == 256 * 1024 * 2
    assert out["reduce-scatter"]["bytes"] == 2 * 128 * 4
    assert out["collective-permute"]["bytes"] == 64 * 4
    assert out["all-to-all"]["bytes"] == 32 * 32
    assert out["total_bytes"] == sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict))


# ---------------------------------------------------------------------------
# scan trip-count semantics (the composition premise)
# ---------------------------------------------------------------------------

def _flops(compiled) -> float:
    # jax < 0.5 returns a one-element list of dicts; newer a dict
    ca = compiled.cost_analysis()
    return (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]


def test_cost_analysis_counts_scan_body_once():
    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    scan_flops = _flops(jax.jit(f).lower(x, ws).compile())

    def g(x, ws):
        y = x
        for i in range(10):
            y = y @ ws[i]
        return y.sum()

    unrolled = _flops(jax.jit(g).lower(x, ws).compile())
    assert scan_flops < unrolled / 5     # body counted ~once, not 10x
    # composition: module + (trips-1) * body ~= unrolled
    body = 2 * 64 ** 3
    assert abs((scan_flops + 9 * body) - unrolled) / unrolled < 0.05


# ---------------------------------------------------------------------------
# cell lowering on an in-process 1x1 mesh
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_lower_cell_smollm_train_on_tiny_mesh():
    res = lower_cell("smollm-135m", "train_4k", mesh=small_mesh())
    assert res.status == "ok", res.reason
    assert res.cost.get("flops", 0) > 0
    assert res.memory.get("temp_size_in_bytes", 0) > 0


@pytest.mark.slow
def test_lower_cell_decode_on_tiny_mesh():
    res = lower_cell("smollm-135m", "decode_32k", mesh=small_mesh())
    assert res.status == "ok", res.reason


def test_lower_cell_skips_long500k_for_full_attention():
    res = lower_cell("minitron-8b", "long_500k", mesh=small_mesh())
    assert res.status == "skipped"
    assert "sub-quadratic" in res.reason


# ---------------------------------------------------------------------------
# input specs (the dry-run contract)
# ---------------------------------------------------------------------------

def test_input_specs_decode_emits_per_slot_position_vector():
    """The server feeds a (B,) per-slot position vector; a scalar
    ``cur_len`` spec lowered a *different* decode_step than serving
    runs (broadcasting folds the vector path away)."""

    from repro.configs import SHAPES, get_config
    from repro.models import build_model

    api = build_model(get_config("smollm-135m").reduced())
    shape = SHAPES["decode_32k"].reduced()
    specs = api.input_specs(shape)
    B = shape.global_batch
    assert specs["tokens"].shape == (B, 1)
    assert specs["cur_len"].shape == (B,)

    chunked = api.input_specs(shape, prefill_chunk=16)
    assert chunked["tokens"].shape == (B, 16)
    assert chunked["positions"].shape == (B,)
    assert chunked["lengths"].shape == (B,)
    assert "cur_len" not in chunked

    # the specs must lower the steps serving actually jits
    import jax
    import jax.numpy as jnp
    from repro.models.common import abstract_params
    state = specs["state"]
    jax.jit(api.decode_step).lower(
        abstract_params(api.specs), state, specs["tokens"],
        specs["cur_len"])
    jax.jit(api.prefill_step).lower(
        abstract_params(api.specs), state, chunked["tokens"],
        chunked["positions"], chunked["lengths"])


# ---------------------------------------------------------------------------
# multi-device subprocess (8 host devices, 2x4 mesh)
# ---------------------------------------------------------------------------

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from jax.sharding import Mesh
from repro.launch.cells import lower_cell
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
res = lower_cell("smollm-135m", "train_4k", mesh=mesh)
assert res.status == "ok", res.reason
assert res.collectives.get("total_bytes", 0) > 0, "expected collectives"
print("SUBPROC_OK", res.cost.get("flops"))
"""


@pytest.mark.slow
def test_lower_cell_multi_device_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SUBPROC_OK" in out.stdout, out.stdout + out.stderr


# ---------------------------------------------------------------------------
# roofline math
# ---------------------------------------------------------------------------

def test_roofline_composition():
    rec = {
        "arch": "smollm-135m", "shape": "train_4k", "mesh": "16x16",
        "status": "ok", "n_devices": 256,
        "cost": {"flops": 1e12, "bytes_accessed": 1e9},
        "collectives": {"total_bytes": 4e9},
        "memory": {"peak_hbm_bytes": 2 ** 30},
        "block": {"status": "ok", "settings": {"trips": 30},
                  "cost": {"flops": 5e11, "bytes_accessed": 1e8},
                  "collectives": {"total_bytes": 3e9}},
    }
    r = analyze(rec)
    assert r.hlo_flops_per_dev == pytest.approx(1e12 + 29 * 5e11)
    assert r.coll_bytes_per_dev == pytest.approx(4e9 + 29 * 3e9)
    assert r.dominant in ("compute", "memory", "collective")
    assert 0 < r.useful_ratio < 1.0
    assert r.step_time_s == max(r.compute_s, r.memory_s, r.collective_s)


def test_model_flops_moe_uses_active_params():
    dense = model_flops("minitron-8b", "train_4k")
    assert dense > 0
    from repro.launch.roofline import active_params
    from repro.models.api import build_model
    from repro.configs import get_config
    total = build_model(get_config("mixtral-8x22b")).param_count()
    act = active_params("mixtral-8x22b")
    assert act < total * 0.45       # top-2 of 8 experts + attention


def test_model_flops_decode_is_per_token():
    f_train = model_flops("minitron-8b", "train_4k")
    f_dec = model_flops("minitron-8b", "decode_32k")
    assert f_dec < f_train / 1000
