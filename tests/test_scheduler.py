"""Scheduler subsystem tests: policy registry, per-policy decisions
(against a fake server), end-to-end preemption/prefix-sharing parity on
real models, the seeded workload generator, and the ``serve.scheduler``
tunable's plan/cache integration."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.scheduler import (SCHEDULER_KINDS, FCFSScheduler,
                                     PrefixAffinityScheduler,
                                     PriorityScheduler, make_scheduler)
from repro.runtime.serve import Request, Server
from repro.runtime.tunables import SchedulerTunable, scheduler_tunable
from repro.runtime.workload import (TraceConfig, drive_trace,
                                    generate_trace, summarize)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_kinds_and_aliases():
    assert SCHEDULER_KINDS == ("fcfs", "prefix", "priority")
    assert isinstance(make_scheduler(None), FCFSScheduler)
    assert isinstance(make_scheduler("priority"), PriorityScheduler)
    assert isinstance(make_scheduler("prefix-affinity"),
                      PrefixAffinityScheduler)
    inst = FCFSScheduler(age_limit=3)
    assert make_scheduler(inst) is inst
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("sjf")
    with pytest.raises(ValueError, match="kwargs"):
        make_scheduler(inst, age_limit=5)


def test_registry_kwargs_reach_the_policy():
    s = make_scheduler("fcfs", age_limit=2)
    assert s.age_limit == 2 and s.kind == "fcfs"


# ---------------------------------------------------------------------------
# policy decisions against a fake server (the scheduler contract)
# ---------------------------------------------------------------------------


class FakeServer:
    """Just the scheduler-facing surface of ``Server``."""

    def __init__(self, queue=(), slots=(), paged=True, fits=None,
                 prefix_lens=None, sources=()):
        self.queue = list(queue)
        self.paged = paged
        self._slots = list(slots)          # (slot, seq, Request)
        self._fits = fits                  # None -> everything fits
        self._prefix = prefix_lens or {}   # id(req) -> shared length
        self._sources = set(sources)

    def admit_fits(self, req):
        return True if self._fits is None else self._fits(req)

    def live_slots(self):
        return [s for s, _, _ in self._slots]

    def has_free_slot(self):
        return False                       # callers construct full houses

    def slot_seq(self, slot):
        return next(seq for s, seq, _ in self._slots if s == slot)

    def slot_request(self, slot):
        return next(r for s, _, r in self._slots if s == slot)

    def shared_prefix_len(self, req):
        return self._prefix.get(id(req), 0)

    def is_share_source(self, slot):
        return slot in self._sources


def _req(rid, plen=4, slo="interactive", deadline=None, skips=0):
    r = Request(rid=rid, prompt=list(range(1, plen + 1)), max_new=4,
                slo=slo, deadline=deadline)
    r.skips = skips
    return r


def test_fcfs_first_fit_skips_oversized_and_ages_it():
    big, small1, small2 = _req(0, plen=20), _req(1), _req(2)
    srv = FakeServer(queue=[big, small1, small2],
                     fits=lambda r: len(r.prompt) < 10)
    sched = FCFSScheduler(age_limit=2)
    assert sched.pick(srv) == 1            # big doesn't fit -> first small
    assert big.skips == 1
    srv.queue.pop(1)
    assert sched.pick(srv) == 1 and big.skips == 2


def test_fcfs_aging_barrier_stops_starvation():
    """Regression for first-fit starvation: once the head request has
    been bypassed ``age_limit`` times it becomes a barrier — younger
    requests can no longer jump it, so pool drain flows to it."""

    big = _req(0, plen=20, skips=2)
    srv = FakeServer(queue=[big, _req(1), _req(2)],
                     fits=lambda r: len(r.prompt) < 10)
    sched = FCFSScheduler(age_limit=2)
    assert sched.pick(srv) is None         # hold admission for the barrier
    assert big.skips == 2                  # a held round is not a bypass
    srv._fits = lambda r: True
    assert sched.pick(srv) == 0            # pages freed -> barrier admits


def test_fcfs_contiguous_admits_strictly_in_order():
    srv = FakeServer(queue=[_req(0, plen=20), _req(1)], paged=False,
                     fits=lambda r: False)
    assert FCFSScheduler().pick(srv) == 0


def test_fcfs_victim_is_youngest():
    srv = FakeServer(slots=[(0, 5, _req(0)), (1, 9, _req(1)),
                            (2, 7, _req(2))])
    sched = FCFSScheduler()
    assert sched.victim(srv) == 1
    assert sched.preempt_for(srv) is None  # fcfs never preempts for SLO


def test_priority_orders_class_then_deadline():
    q = [_req(0, slo="batch"), _req(1, slo="interactive", deadline=90.0),
         _req(2, slo="interactive", deadline=40.0)]
    srv = FakeServer(queue=q)
    assert PriorityScheduler().pick(srv) == 2      # EDF within interactive
    assert q[0].skips == 1 and q[1].skips == 1     # both were bypassed


def test_priority_aging_promotes_starved_batch_request():
    q = [_req(0, slo="batch", skips=3), _req(1, slo="interactive")]
    srv = FakeServer(queue=q)
    assert PriorityScheduler(age_limit=3).pick(srv) == 0


def test_priority_victim_lowest_class_youngest():
    srv = FakeServer(slots=[(0, 1, _req(0, slo="batch")),
                            (1, 2, _req(1, slo="interactive")),
                            (2, 3, _req(2, slo="batch"))])
    assert PriorityScheduler().victim(srv) == 2    # batch before interactive


def test_priority_preempts_only_for_strictly_higher_class():
    batch_house = [(0, 1, _req(0, slo="batch")), (1, 2, _req(1, slo="batch"))]
    sched = PriorityScheduler()
    srv = FakeServer(queue=[_req(9, slo="interactive")], slots=batch_house)
    assert sched.preempt_for(srv) == 1             # youngest batch slot
    srv = FakeServer(queue=[_req(9, slo="batch")], slots=batch_house)
    assert sched.preempt_for(srv) is None          # equal class: no eviction
    assert PriorityScheduler(preempt=False).preempt_for(
        FakeServer(queue=[_req(9)], slots=batch_house)) is None


def test_prefix_affinity_prefers_longest_shared_prefix():
    q = [_req(0), _req(1), _req(2)]
    srv = FakeServer(queue=q, prefix_lens={id(q[1]): 8, id(q[2]): 16})
    assert PrefixAffinityScheduler().pick(srv) == 2
    srv = FakeServer(queue=[_req(0), _req(1)])     # nothing shares
    assert PrefixAffinityScheduler().pick(srv) == 0


def test_prefix_affinity_victim_spares_share_sources():
    srv = FakeServer(slots=[(0, 1, _req(0)), (1, 3, _req(1)),
                            (2, 2, _req(2))], sources={1})
    assert PrefixAffinityScheduler().victim(srv) == 2  # youngest non-source


# ---------------------------------------------------------------------------
# end-to-end: preemption and prefix sharing on a real model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    return api, api.init(jax.random.PRNGKey(0))


def _solo_out(api, params, prompt, max_new, **kw):
    solo = Server(api, params, batch=1, context=48, **kw)
    ref = solo.submit(list(prompt), max_new=max_new)
    solo.run_until_drained()
    return ref.out


def test_priority_preemption_resumes_with_exact_output(model):
    """An interactive arrival evicts the lone batch slot mid-decode; the
    batch request keeps its generated tokens, re-prefills them on
    resume, and still matches its undisturbed solo drain token for
    token."""

    api, params = model
    long_p = list(range(1, 17))
    short_p = [7, 5, 3, 2]
    srv = Server(api, params, batch=1, context=48, paged=True, page_size=4,
                 prefill_chunk=8, scheduler="priority")
    rb = srv.submit(long_p, max_new=6, slo="batch")
    for _ in range(4):
        srv.tick()                         # batch request is decoding
    assert rb.out                          # some progress to preserve
    ri = srv.submit(short_p, max_new=4, slo="interactive", deadline=20.0)
    srv.run_until_drained()
    assert srv.preemptions >= 1 and rb.preempted >= 1
    assert ri.done and rb.done
    assert ri.out == _solo_out(api, params, short_p, 4, prefill_chunk=8)
    assert rb.out == _solo_out(api, params, long_p, 6, prefill_chunk=8)
    # and the interactive request finished first (that was the point)
    assert srv.completed[0] is ri


def test_shared_prefix_drain_matches_unshared_token_for_token(model):
    """COW prefix sharing is an allocation change, not a semantics
    change: staggered sharers must emit exactly the contiguous solo
    stream, while actually sharing pages."""

    api, params = model
    prefix = list(range(11, 29))           # 18 tokens: unaligned at ps=4
    prompts = [prefix + [40 + i, 50 + i] for i in range(3)]
    srv = Server(api, params, batch=4, context=48, paged=True, page_size=4,
                 prefill_chunk=8, scheduler="prefix", share_prefix=True)
    first = srv.submit(prompts[0], max_new=4)
    while not first.out:
        srv.tick()                         # source holds a written prefix
    reqs = [first] + [srv.submit(p, max_new=4) for p in prompts[1:]]
    srv.run_until_drained()
    st = srv.stats()
    assert st["share_hits"] == 2 and st["shared_tokens"] > 0
    assert st["cow_copies"] == 2           # one partial-page copy each
    for p, r in zip(prompts, reqs):
        assert r.out == _solo_out(api, params, p, 4, prefill_chunk=8)
        assert r.shared_prefix > 0 or r is first


def test_shared_prefix_parity_with_speculation(model):
    """Sharing composes with speculative decoding: paged + shared +
    ngram drafter still reproduces the plain contiguous stream."""

    api, params = model
    prefix = list(range(3, 19))
    prompts = [prefix + [20 + i] for i in range(2)]
    srv = Server(api, params, batch=3, context=48, paged=True, page_size=4,
                 prefill_chunk=8, share_prefix=True, speculate="ngram",
                 spec_depth=3)
    first = srv.submit(prompts[0], max_new=5)
    while not first.out:
        srv.tick()
    second = srv.submit(prompts[1], max_new=5)
    srv.run_until_drained()
    assert srv.stats()["share_hits"] == 1
    for p, r in zip(prompts, (first, second)):
        assert r.out == _solo_out(api, params, p, 5, prefill_chunk=8)


def test_share_prefix_requires_paged_attention(model):
    api, params = model
    with pytest.raises(ValueError, match="needs paged=True"):
        Server(api, params, batch=2, context=48, share_prefix=True)


def test_share_prefix_rejects_ssm_state():
    cfg = get_config("hymba-1.5b").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="pure-attention"):
        Server(api, params, batch=2, context=48, paged=True, page_size=8,
               share_prefix=True)


def test_fcfs_aging_admits_starved_long_prompt_e2e(model):
    """Anti-starvation end to end: a long prompt that never fits while
    short requests stream past is eventually made a barrier and served;
    every output stays solo-exact."""

    api, params = model
    # a 45-token prompt needs all 12 pool pages AT ADMISSION: it never
    # fits while any short slot is live, so first-fit alone would
    # starve it indefinitely
    long_p = list(range(1, 46))
    srv = Server(api, params, batch=2, context=48, paged=True, page_size=4,
                 kv_pages=12, prefill_chunk=8,
                 scheduler=make_scheduler("fcfs", age_limit=2))
    # staggered lifetimes: slots free one at a time, so there is always
    # a live short holding pages when the freed slot picks
    shorts = [srv.submit([60, 61, 62], max_new=2),
              srv.submit([63, 64, 65], max_new=5)]
    big = srv.submit(long_p, max_new=3)
    for i in range(2, 6):                  # keep short traffic arriving
        srv.tick()
        shorts.append(srv.submit([60 + i, 61 + i, 62 + i],
                                 max_new=2 + i % 3))
    srv.run_until_drained()
    assert big.done and big.skips >= 2
    assert big.out == _solo_out(api, params, long_p, 3, prefill_chunk=8)
    for r in shorts:
        assert r.out == _solo_out(api, params, r.prompt, r.max_new,
                                  prefill_chunk=8)


def test_policies_produce_identical_outputs_on_a_trace(model):
    """Scheduling changes WHEN tokens are produced, never WHICH: the
    same trace drains to byte-identical per-request outputs under every
    policy (sharing included)."""

    api, params = model
    trace = generate_trace(TraceConfig(
        requests=8, burst=3, burst_every=5, prompt_len=(4, 12),
        max_new=(3, 5), shared_frac=0.5, prefix_len=8, vocab=250, seed=3))
    outs = {}
    for policy in SCHEDULER_KINDS:
        srv = Server(api, params, batch=2, context=48, paged=True,
                     page_size=4, kv_pages=16, prefill_chunk=8,
                     scheduler=policy, share_prefix=(policy == "prefix"))
        recs = drive_trace(srv, trace)
        outs[policy] = {rid: tuple(rec["request"].out)
                        for rid, rec in recs.items()}
    assert outs["fcfs"] == outs["priority"] == outs["prefix"]


# ---------------------------------------------------------------------------
# workload generator
# ---------------------------------------------------------------------------


def test_generate_trace_is_deterministic_and_seed_sensitive():
    cfg = TraceConfig(requests=16, shared_frac=0.5, seed=7)
    a, b = generate_trace(cfg), generate_trace(cfg)
    assert a == b
    c = generate_trace(dataclasses.replace(cfg, seed=8))
    assert a != c
    for r in a:
        assert 1 <= r.max_new and len(r.prompt) >= cfg.prompt_len[0]
        assert r.deadline == r.arrival + cfg.deadlines[r.slo]


def test_generate_trace_bursty_arrivals_and_shared_prefix():
    cfg = TraceConfig(requests=9, arrival="bursty", burst=3, burst_every=5,
                      shared_frac=1.0, prefix_len=6, seed=0)
    trace = generate_trace(cfg)
    assert [r.arrival for r in trace] == [0, 0, 0, 5, 5, 5, 10, 10, 10]
    heads = {r.prompt[:6] for r in trace}
    assert len(heads) == 1                 # every request opens identically
    with pytest.raises(ValueError, match="unknown arrival"):
        generate_trace(dataclasses.replace(cfg, arrival="weibull"))


def test_summarize_scores_deadlines():
    records = {
        0: {"latency": 10, "slo": "interactive", "met": True, "tokens": 5},
        1: {"latency": 50, "slo": "interactive", "met": False, "tokens": 7},
        2: {"latency": 30, "slo": "batch", "met": True, "tokens": 4},
    }
    s = summarize(records, ticks=60)
    assert s["requests"] == 3 and s["slo_attainment"] == pytest.approx(2 / 3)
    assert s["goodput_tokens"] == 9        # only deadline-met tokens
    assert s["p50_batch"] == 30.0
    assert s["p99_all"] == pytest.approx(np.percentile([10, 50, 30], 99))


# ---------------------------------------------------------------------------
# SchedulerTunable
# ---------------------------------------------------------------------------


def test_scheduler_tunable_space_and_cost_rank():
    tb = SchedulerTunable(requests=16, burst=8, shared_frac=0.5,
                          kv_pages=24, page_size=8)
    cfgs = list(tb.space())
    assert len(cfgs) == len(tb.policies) * len(tb.age_limits)
    costs = {c["policy"]: tb.cost(c) for c in cfgs if c["age_limit"] == 4}
    assert all(np.isfinite(v) and v > 0 for v in costs.values())
    # on a bursty interactive mix, the model must at least distinguish
    # the policies (it ranks; measure() settles)
    assert len(set(costs.values())) > 1


def test_scheduler_tunable_fingerprint_excludes_model_handles():
    tb = scheduler_tunable(None, arch="smollm-135m", requests=6)
    fp = tb.fingerprint()
    assert fp["tunable"] == "serve.scheduler"
    assert fp["unit"] == "us_per_goodput_token"
    assert "api" not in fp and "params" not in fp and "last_stats" not in fp
    assert fp["prompt_len"] == [6, 20]     # JSON-stable lists
    # identity is the trace + lattice, so JSON round-trips agree
    tb2 = SchedulerTunable(**{k: v for k, v in fp.items()
                              if k not in ("tunable", "unit")})
    assert tb2.fingerprint() == fp


def test_scheduler_plan_roundtrip_zero_engine_runs(tmp_path):
    """Acceptance slice: ``serve.scheduler`` resolves from the plan
    registry, measures real trace drains into the cache, and a second
    pure-JSON pass is a pure cache hit (zero engine runs)."""

    from repro.tune import TuningCache, TuningPlan, tune

    cache = TuningCache(tmp_path / "c.json")
    params = {"arch": "smollm-135m", "context": 48, "batch": 2,
              "page_size": 8, "prefill_chunk": 8, "requests": 4,
              "burst": 2, "burst_every": 4, "prompt_len": [4, 8],
              "max_new": [2, 3], "prefix_len": 8, "age_limits": [4]}
    tb = SchedulerTunable(**params)
    res = tune(tb, engine="measure", cache=cache, top_k=1, repeats=1)
    assert res.stats["provenance"] == "measured"
    assert tb.last_stats is not None       # real drain happened
    assert res.best_config["policy"] in SCHEDULER_KINDS

    spec = {"name": "sched-warmup", "jobs": [
        {"tunable": "serve.scheduler", "params": params,
         "engine": "measure", "engine_kwargs": {"top_k": 1, "repeats": 1}}]}
    report = TuningPlan.from_spec(spec).run(cache=cache)
    assert report.ok and report.results[0].status == "hit"
    assert report.results[0].best_config == dict(res.best_config)


# ---------------------------------------------------------------------------
# migration: pre-split import paths stay alive
# ---------------------------------------------------------------------------


def test_moved_tunables_reexported_from_serve():
    from repro.runtime import serve, tunables
    for name in ("DecodeBatchTunable", "PrefillChunkTunable",
                 "KVPageTunable", "SchedulerTunable", "timed_server_drain",
                 "kv_cache_stream_s", "decode_batch_tunable",
                 "choose_batch"):
        assert getattr(serve, name) is getattr(tunables, name)
    # the move must not disturb cache identity: fingerprints of the
    # re-exported classes carry the same tunable names as before
    assert serve.DecodeBatchTunable(param_bytes=1 << 20, layers=2,
                                    d_model=64, kv_width=32, context=32,
                                    requests=2, mean_new=2
                                    ).fingerprint()["tunable"] == \
        "serve.decode_batch"
