"""Serving runtime tests: continuous batching, slot reuse, correctness
against the offline forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.serve import DecodeBatchTunable, Server, choose_batch


def make(name="smollm-135m", batch=3, context=32):
    cfg = get_config(name).reduced().replace(logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params, Server(api, params, batch=batch,
                                    context=context)


def test_server_drains_all_requests():
    cfg, api, params, server = make()
    rng = np.random.default_rng(0)
    reqs = [server.submit(rng.integers(0, cfg.vocab, 5).tolist(), max_new=4)
            for _ in range(7)]
    server.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert len(server.completed) == 7


def test_server_more_requests_than_slots_reuses_slots():
    cfg, api, params, server = make(batch=2)
    reqs = [server.submit([1, 2, 3], max_new=3) for _ in range(5)]
    server.run_until_drained()
    assert len(server.completed) == 5


def test_server_greedy_matches_offline_forward():
    """A single request with an empty batch must reproduce the offline
    greedy continuation from the full forward pass."""

    cfg, api, params, server = make(batch=1, context=32)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 6).tolist()
    req = server.submit(prompt, max_new=4)
    server.run_until_drained()

    # offline: greedy continuation via repeated full forwards
    toks = list(prompt)
    for _ in range(4):
        logits = api.forward(params, {"tokens": jnp.asarray([toks],
                                                            jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.out == toks[len(prompt):]


def test_server_staggered_admissions_match_single_request_decoding():
    """Mixed-progress slots: a request admitted while another is already
    several tokens in must decode exactly as it would alone.  Before
    per-slot positions, ``tick`` collapsed all active slots onto
    ``slot_pos.max()``, giving lagging slots the wrong RoPE rotation and
    ring-cache slot."""

    cfg, api, params, server = make(batch=2, context=32)
    rng = np.random.default_rng(11)
    prompt_a = rng.integers(0, cfg.vocab, 6).tolist()
    prompt_b = rng.integers(0, cfg.vocab, 3).tolist()

    req_a = server.submit(prompt_a, max_new=4)
    for _ in range(3):
        server.tick()                    # A alone: slot_pos[A] runs ahead
    req_b = server.submit(prompt_b, max_new=4)   # admitted at pos 0
    server.run_until_drained()
    assert req_a.done and req_b.done

    # each request must match a solo single-slot server (no interference)
    for prompt, req in ((prompt_a, req_a), (prompt_b, req_b)):
        solo = Server(api, params, batch=1, context=32)
        ref = solo.submit(prompt, max_new=4)
        solo.run_until_drained()
        assert req.out == ref.out


def test_server_staggered_admissions_sliding_window():
    """Same staggering through a ring-buffer (sliding-window) cache:
    per-slot ring slots and validity masks must not cross-talk."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32", window=8)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    server = Server(api, params, batch=2, context=24)
    rng = np.random.default_rng(5)
    prompt_a = rng.integers(0, cfg.vocab, 10).tolist()  # > window
    prompt_b = rng.integers(0, cfg.vocab, 4).tolist()

    req_a = server.submit(prompt_a, max_new=3)
    for _ in range(5):
        server.tick()
    req_b = server.submit(prompt_b, max_new=3)
    server.run_until_drained()

    for prompt, req in ((prompt_a, req_a), (prompt_b, req_b)):
        solo = Server(api, params, batch=1, context=24)
        ref = solo.submit(prompt, max_new=3)
        solo.run_until_drained()
        assert req.out == ref.out


def test_server_respects_context_limit():
    cfg, api, params, server = make(batch=1, context=16)
    req = server.submit([1] * 4, max_new=100)   # longer than context
    server.run_until_drained()
    assert req.done
    assert len(req.out) < 16


def test_choose_batch_measure_engine_times_real_drains():
    """``engine="measure"`` refines the modeled slot count against real
    ``Server`` drains: the winner's measured drain time is <= the pure
    cost-model pick's measured drain time (both are in the shortlist)."""

    cfg, api, params, _ = make()
    batch, res = choose_batch(api, context=16, requests=3, max_new=2,
                              params=params, engine="measure", cache=None,
                              budget=2, repeats=1)
    assert res.stats["provenance"] == "measured"
    assert res.t_min > 0.0
    assert batch == res.best_config["batch"]
    assert res.stats["measured_pick"]["measured"] <= \
        res.stats["modeled_pick"]["measured"]


def test_decode_batch_tunable_measure_requires_model():
    tb = DecodeBatchTunable(param_bytes=1 << 20, layers=2, d_model=64,
                            context=16, requests=2, mean_new=2)
    import pytest
    with pytest.raises(RuntimeError, match="api=/params="):
        tb.measure({"batch": 1})


def test_encdec_serving_with_encoder_prefill():
    """Whisper-style serving: encoder runs at admission, decoder
    cross-attends to the request's frames; output must match the offline
    enc-dec greedy continuation."""

    from repro.configs import get_config
    cfg = get_config("whisper-medium").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    server = Server(api, params, batch=1, context=24)
    rng = np.random.default_rng(7)
    frames = (rng.standard_normal((cfg.enc_seq, cfg.d_model)) * 0.1
              ).astype("float32")
    prompt = rng.integers(0, cfg.vocab, 5).tolist()
    req = server.submit(prompt, max_new=3, frames=frames)
    server.run_until_drained()
    assert req.done and len(req.out) == 3

    # offline greedy with the same frames
    toks = list(prompt)
    fb = jnp.asarray(frames, jnp.bfloat16)[None]
    for _ in range(3):
        logits = api.forward(params, {
            "tokens": jnp.asarray([toks], jnp.int32), "frames": fb})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.out == toks[len(prompt):]
