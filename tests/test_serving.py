"""Serving runtime tests: continuous batching, chunked prefill, slot
reuse, correctness against the offline forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.serve import (DecodeBatchTunable, PrefillChunkTunable,
                                 Server, choose_batch, choose_kv_page,
                                 choose_prefill_chunk,
                                 prefill_chunk_tunable)


def make(name="smollm-135m", batch=3, context=32, **srv_kw):
    cfg = get_config(name).reduced().replace(logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params, Server(api, params, batch=batch,
                                    context=context, **srv_kw)


def test_server_drains_all_requests():
    cfg, api, params, server = make()
    rng = np.random.default_rng(0)
    reqs = [server.submit(rng.integers(0, cfg.vocab, 5).tolist(), max_new=4)
            for _ in range(7)]
    server.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 4 for r in reqs)
    assert len(server.completed) == 7


def test_server_more_requests_than_slots_reuses_slots():
    cfg, api, params, server = make(batch=2)
    reqs = [server.submit([1, 2, 3], max_new=3) for _ in range(5)]
    server.run_until_drained()
    assert len(server.completed) == 5


def test_server_greedy_matches_offline_forward():
    """A single request with an empty batch must reproduce the offline
    greedy continuation from the full forward pass."""

    cfg, api, params, server = make(batch=1, context=32)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 6).tolist()
    req = server.submit(prompt, max_new=4)
    server.run_until_drained()

    # offline: greedy continuation via repeated full forwards
    toks = list(prompt)
    for _ in range(4):
        logits = api.forward(params, {"tokens": jnp.asarray([toks],
                                                            jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.out == toks[len(prompt):]


def test_server_staggered_admissions_match_single_request_decoding():
    """Mixed-progress slots: a request admitted while another is already
    several tokens in must decode exactly as it would alone.  Before
    per-slot positions, ``tick`` collapsed all active slots onto
    ``slot_pos.max()``, giving lagging slots the wrong RoPE rotation and
    ring-cache slot."""

    cfg, api, params, server = make(batch=2, context=32)
    rng = np.random.default_rng(11)
    prompt_a = rng.integers(0, cfg.vocab, 6).tolist()
    prompt_b = rng.integers(0, cfg.vocab, 3).tolist()

    req_a = server.submit(prompt_a, max_new=4)
    for _ in range(3):
        server.tick()                    # A alone: slot_pos[A] runs ahead
    req_b = server.submit(prompt_b, max_new=4)   # admitted at pos 0
    server.run_until_drained()
    assert req_a.done and req_b.done

    # each request must match a solo single-slot server (no interference)
    for prompt, req in ((prompt_a, req_a), (prompt_b, req_b)):
        solo = Server(api, params, batch=1, context=32)
        ref = solo.submit(prompt, max_new=4)
        solo.run_until_drained()
        assert req.out == ref.out


def test_server_staggered_admissions_sliding_window():
    """Same staggering through a ring-buffer (sliding-window) cache:
    per-slot ring slots and validity masks must not cross-talk."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32", window=8)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    server = Server(api, params, batch=2, context=24)
    rng = np.random.default_rng(5)
    prompt_a = rng.integers(0, cfg.vocab, 10).tolist()  # > window
    prompt_b = rng.integers(0, cfg.vocab, 4).tolist()

    req_a = server.submit(prompt_a, max_new=3)
    for _ in range(5):
        server.tick()
    req_b = server.submit(prompt_b, max_new=3)
    server.run_until_drained()

    for prompt, req in ((prompt_a, req_a), (prompt_b, req_b)):
        solo = Server(api, params, batch=1, context=24)
        ref = solo.submit(prompt, max_new=3)
        solo.run_until_drained()
        assert req.out == ref.out


def test_server_respects_context_limit():
    cfg, api, params, server = make(batch=1, context=16)
    req = server.submit([1] * 4, max_new=12)    # exactly fills the context
    server.run_until_drained()
    assert req.done
    assert len(req.out) <= 12
    assert len(req.prompt) + len(req.out) <= 16


def test_submit_rejects_empty_prompt():
    cfg, api, params, server = make(batch=1, context=16)
    with pytest.raises(ValueError, match="empty prompt"):
        server.submit([], max_new=4)


def test_submit_rejects_oversized_prompt():
    """A prompt longer than context - max_new can never fit its
    generation budget; it must fail loudly at submission, not wedge or
    silently truncate mid-drain."""

    cfg, api, params, server = make(batch=1, context=16)
    with pytest.raises(ValueError, match="context - max_new"):
        server.submit([1] * 13, max_new=4)
    server.submit([1] * 12, max_new=4)          # boundary case is fine
    server.run_until_drained()


def test_submit_rejects_nonpositive_max_new():
    cfg, api, params, server = make(batch=1, context=16)
    with pytest.raises(ValueError, match="max_new"):
        server.submit([1, 2], max_new=0)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_chunked_prefill_matches_tokenwise_and_offline(chunk):
    """Chunked prefill is an optimization, not a semantics change: any
    chunk size must reproduce the tokenwise (chunk=1) greedy output,
    which itself matches the offline full-forward continuation."""

    cfg, api, params, server = make(batch=1, context=32,
                                    prefill_chunk=chunk)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, 20).tolist()
    req = server.submit(prompt, max_new=4)
    server.run_until_drained()

    tokenwise = Server(api, params, batch=1, context=32, prefill_chunk=1)
    ref = tokenwise.submit(prompt, max_new=4)
    tokenwise.run_until_drained()
    assert req.out == ref.out

    toks = list(prompt)
    for _ in range(4):
        logits = api.forward(params, {"tokens": jnp.asarray([toks],
                                                            jnp.int32)})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.out == toks[len(prompt):]


def test_chunked_prefill_fewer_ticks():
    cfg, api, params, server = make(batch=1, context=32, prefill_chunk=8)
    req = server.submit(list(range(1, 17)), max_new=2)
    ticks = 0
    while not req.done:
        server.tick()
        ticks += 1
    # 2 prefill ticks (16/8; the second yields the first output token)
    # + 1 decode tick, vs 16 + 1 tokenwise
    assert ticks == 3


def test_chunked_prefill_sliding_window_ring():
    """Chunk larger than the SWA ring (window=8 -> C=8 cache slots,
    chunk=32): in-chunk tokens overwrite ring slots earlier in-chunk
    queries still need, so the step must attend the pre-chunk snapshot
    plus in-chunk keys — not the post-scatter ring."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32", window=8)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, 20).tolist()

    outs = {}
    for chunk in (1, 32):
        srv = Server(api, params, batch=1, context=32, prefill_chunk=chunk)
        req = srv.submit(prompt, max_new=4)
        srv.run_until_drained()
        outs[chunk] = req.out
    assert outs[32] == outs[1]


@pytest.mark.parametrize("arch", ["hymba-1.5b", "mamba2-2.7b"])
def test_chunked_prefill_ssm_and_hybrid(arch):
    """SSM/hybrid blocks step the chunk via scan; recurrent state must
    advance identically to the tokenwise path."""

    cfg = get_config(arch).reduced().replace(logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, 14).tolist()
    outs = {}
    for chunk in (1, 8):
        srv = Server(api, params, batch=1, context=32, prefill_chunk=chunk)
        req = srv.submit(prompt, max_new=3)
        srv.run_until_drained()
        outs[chunk] = req.out
    assert outs[8] == outs[1]


def test_slot_reuse_resets_recurrent_state():
    """A reused slot must not inherit the previous request's SSM state:
    position masking hides stale KV entries, but the recurrence has no
    position — the same request served twice through one slot must
    produce the same output."""

    cfg = get_config("mamba2-2.7b").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    server = Server(api, params, batch=1, context=32, prefill_chunk=8)
    prompt = list(range(1, 13))
    r1 = server.submit(prompt, max_new=3)
    server.run_until_drained()
    r2 = server.submit(prompt, max_new=3)
    server.run_until_drained()
    assert r1.out == r2.out


def test_chunked_prefill_staggered_mixed_phases():
    """A tick with one slot decoding and another mid-prefill: both run
    (decode step + chunked prefill step in the same tick) and neither
    corrupts the other — each request matches its solo drain."""

    cfg, api, params, server = make(batch=2, context=32, prefill_chunk=4)
    rng = np.random.default_rng(11)
    prompt_a = rng.integers(0, cfg.vocab, 6).tolist()
    prompt_b = rng.integers(0, cfg.vocab, 17).tolist()

    req_a = server.submit(prompt_a, max_new=5)
    for _ in range(3):
        server.tick()            # A: prefill (2 ticks) + 1 decode tick
    req_b = server.submit(prompt_b, max_new=4)   # B prefills, A decodes
    server.run_until_drained()
    assert req_a.done and req_b.done

    for prompt, req in ((prompt_a, req_a), (prompt_b, req_b)):
        solo = Server(api, params, batch=1, context=32, prefill_chunk=4)
        ref = solo.submit(prompt, max_new=req.max_new)
        solo.run_until_drained()
        assert req.out == ref.out


def test_chunked_prefill_staggered_sliding_window():
    """Mixed phases through ring-buffer caches: per-slot rings and the
    chunk-wide scatter must not cross-talk."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32", window=8)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    server = Server(api, params, batch=2, context=24, prefill_chunk=4)
    rng = np.random.default_rng(5)
    prompt_a = rng.integers(0, cfg.vocab, 10).tolist()   # > window
    prompt_b = rng.integers(0, cfg.vocab, 13).tolist()

    req_a = server.submit(prompt_a, max_new=3)
    for _ in range(4):
        server.tick()
    req_b = server.submit(prompt_b, max_new=3)
    server.run_until_drained()

    for prompt, req in ((prompt_a, req_a), (prompt_b, req_b)):
        solo = Server(api, params, batch=1, context=24, prefill_chunk=4)
        ref = solo.submit(prompt, max_new=3)
        solo.run_until_drained()
        assert req.out == ref.out


# ---------------------------------------------------------------------------
# prefill-chunk tuning
# ---------------------------------------------------------------------------


def test_prefill_chunk_tunable_space_and_cost():
    tb = PrefillChunkTunable(param_bytes=1 << 20, layers=2, d_model=64,
                             kv_width=32, context=64, prompt_len=48,
                             requests=4, mean_new=4, batch=2)
    chunks = [cfg["chunk"] for cfg in tb.space()]
    assert chunks == [1, 2, 4, 8, 16, 32, 64]
    # bigger chunks need strictly fewer prefill ticks; the modeled cost
    # must reward the amortized weight stream at small-chunk scale
    assert tb.cost({"chunk": 16}) < tb.cost({"chunk": 1})
    fp = tb.fingerprint()
    assert fp["tunable"] == "serve.prefill_chunk"
    assert fp["kv_width"] == 32 and "api" not in fp


def test_prefill_chunk_tunable_measure_requires_model():
    tb = PrefillChunkTunable(param_bytes=1 << 20, layers=2, d_model=64,
                             kv_width=32, context=32, prompt_len=16,
                             requests=2, mean_new=2, batch=1)
    with pytest.raises(RuntimeError, match="api=/params="):
        tb.measure({"chunk": 4})


def test_choose_prefill_chunk_measure_engine_times_real_drains():
    """``engine="measure"`` refines the modeled chunk against real
    long-prompt ``Server`` drains, provenance-tagged."""

    cfg, api, params, _ = make()
    chunk, res = choose_prefill_chunk(api, context=32, prompt_len=16,
                                      requests=2, max_new=2, batch=2,
                                      params=params, engine="measure",
                                      cache=None, budget=2, repeats=1)
    assert res.stats["provenance"] == "measured"
    assert res.t_min > 0.0
    assert chunk == res.best_config["chunk"]
    assert res.stats["measured_pick"]["measured"] <= \
        res.stats["modeled_pick"]["measured"]


def test_decode_batch_cost_uses_gqa_kv_width():
    """The KV-traffic term must scale with the n_kv_heads*hd cache
    width, not d_model — modeling full-width caches overestimated KV
    reads by the GQA ratio and biased slot counts low."""

    kw = dict(param_bytes=1 << 24, layers=4, d_model=256, context=1024,
              requests=64, mean_new=32, dispatch_s=0.0)
    full = DecodeBatchTunable(**kw, kv_width=256)     # MHA: no grouping
    gqa = DecodeBatchTunable(**kw, kv_width=64)       # 4x grouped
    legacy = DecodeBatchTunable(**kw)                 # kv_width=0 fallback
    for b in (4, 16):
        assert gqa.cost({"batch": b}) < full.cost({"batch": b})
        assert legacy.cost({"batch": b}) == full.cost({"batch": b})
    # kv_width keys the cache entry so stale full-width entries miss
    assert full.fingerprint()["kv_width"] == 256
    assert gqa.fingerprint() != full.fingerprint()
    # cheaper KV traffic tips the drain optimum to MORE slots (or at
    # minimum never fewer) for the same load
    from repro.tune import tune
    b_gqa = tune(gqa, engine="grid", cache=None).best_config["batch"]
    b_full = tune(full, engine="grid", cache=None).best_config["batch"]
    assert b_gqa >= b_full


def test_choose_batch_measure_engine_times_real_drains():
    """``engine="measure"`` refines the modeled slot count against real
    ``Server`` drains: the winner's measured drain time is <= the pure
    cost-model pick's measured drain time (both are in the shortlist)."""

    cfg, api, params, _ = make()
    batch, res = choose_batch(api, context=16, requests=3, max_new=2,
                              params=params, engine="measure", cache=None,
                              budget=2, repeats=1)
    assert res.stats["provenance"] == "measured"
    assert res.t_min > 0.0
    assert batch == res.best_config["batch"]
    assert res.stats["measured_pick"]["measured"] <= \
        res.stats["modeled_pick"]["measured"]


def test_decode_batch_tunable_measure_requires_model():
    tb = DecodeBatchTunable(param_bytes=1 << 20, layers=2, d_model=64,
                            context=16, requests=2, mean_new=2)
    import pytest
    with pytest.raises(RuntimeError, match="api=/params="):
        tb.measure({"batch": 1})


def test_choose_kv_page_measure_engine_times_real_paged_drains():
    """``engine="measure"`` refines the modeled page size against real
    mixed-length PAGED ``Server`` drains, provenance-tagged — the same
    contract as the slot-count and prefill-chunk tunables."""

    cfg, api, params, _ = make()
    page, res = choose_kv_page(api, context=32, prompt_lens=[4, 12],
                               requests=3, max_new=2, batch=2,
                               params=params, engine="measure",
                               cache=None, budget=2, repeats=1)
    assert res.stats["provenance"] == "measured"
    assert res.t_min > 0.0
    assert page == res.best_config["page"]
    assert res.stats["measured_pick"]["measured"] <= \
        res.stats["modeled_pick"]["measured"]


def test_encdec_serving_with_encoder_prefill():
    """Whisper-style serving: encoder runs at admission, decoder
    cross-attends to the request's frames; output must match the offline
    enc-dec greedy continuation."""

    from repro.configs import get_config
    cfg = get_config("whisper-medium").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    server = Server(api, params, batch=1, context=24)
    rng = np.random.default_rng(7)
    frames = (rng.standard_normal((cfg.enc_seq, cfg.d_model)) * 0.1
              ).astype("float32")
    prompt = rng.integers(0, cfg.vocab, 5).tolist()
    req = server.submit(prompt, max_new=3, frames=frames)
    server.run_until_drained()
    assert req.done and len(req.out) == 3

    # offline greedy with the same frames
    toks = list(prompt)
    fb = jnp.asarray(frames, jnp.bfloat16)[None]
    for _ in range(3):
        logits = api.forward(params, {
            "tokens": jnp.asarray([toks], jnp.int32), "frames": fb})
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.out == toks[len(prompt):]
