"""Conformance bridge: model vs real PagedKVAllocator, both directions,
plus the mutant battery (the checker must catch every planted bug with
a trail that replays as a concrete failure)."""

import random

import jax
import pytest
from _hypothesis_stub import hypothesis, st

from repro.runtime.kv import PagedKVAllocator
from repro.runtime.scheduler import TracingScheduler, make_scheduler
from repro.verify.conformance import (ConformanceError, coupled_explore,
                                      ops_from_trail, replay_ops,
                                      trace_accepted)
from repro.verify.models import AllocConfig, AllocatorSemantics
from repro.verify.mutants import MUTANTS

SMALL = AllocConfig(n_slots=2, page_size=2, pages_per_slot=2, n_pages=3)


def test_real_allocator_conforms_exhaustively_on_small_config():
    sem = AllocatorSemantics(SMALL, canonical=True)
    res = coupled_explore(sem)
    assert res.ok and res.status == "verified", res.message
    assert res.transitions > 500


def test_exact_mode_conformance_also_holds():
    res = coupled_explore(AllocatorSemantics(SMALL, canonical=False),
                          max_states=20_000)
    assert res.ok, res.message


@pytest.mark.parametrize("name", sorted(MUTANTS))
def test_mutant_caught_with_replayable_trail(name):
    sem = AllocatorSemantics(SMALL, canonical=True)
    res = coupled_explore(sem, MUTANTS[name])
    assert not res.ok, f"checker missed mutant {name}"
    assert res.ops, "counterexample must carry an op trail"
    # the trail reproduces the failure on a fresh mutant allocator...
    with pytest.raises(ConformanceError):
        replay_ops(sem, list(res.ops), MUTANTS[name])
    # ...and the same ops replay clean on the correct allocator
    replay_ops(sem, list(res.ops), PagedKVAllocator)


def test_ops_from_trail_parses_select_labels():
    trail = ("driver[0]:0:goto", "driver[0]:1:select=('ensure', 0, 2)",
             "driver[0]:2:apply", "driver[0]:1:select=('release', 0)")
    assert ops_from_trail(trail) == [("ensure", 0, 2), ("release", 0)]


def test_replay_flags_wrong_expectation():
    sem = AllocatorSemantics(SMALL, canonical=True)
    # legal prefix, then an op whose model return (True) the real
    # allocator cannot match because the pool is exhausted elsewhere
    ops = [("ensure", 0, 4), ("ensure", 1, 4)]
    # 2+2 pages needed > 3 in pool: model says second ensure fails too,
    # so this replays CLEAN (agreement on failure is conformance)
    alloc = replay_ops(sem, ops)
    assert alloc.free_pages == 1


# ---------------------------------------------------------------------------
# direction 2: every real trace is a model path
# ---------------------------------------------------------------------------


def _random_walk_trace(seed: int, steps: int = 40):
    """Drive a REAL allocator by ops the model deems enabled, recording
    through the kv trace hook."""

    rng = random.Random(seed)
    sem = AllocatorSemantics(SMALL, canonical=False)
    alloc = PagedKVAllocator(SMALL.kv_spec(), SMALL.n_slots)
    alloc.trace = []
    for _ in range(steps):
        ops = sem.enabled_ops({"alloc": alloc.project()})
        if not ops:   # pragma: no cover - SMALL never deadlocks
            break
        op = rng.choice(ops)
        getattr(alloc, op[0])(*op[1:])
    return alloc.trace


def test_randomized_real_traces_are_model_paths():
    for seed in range(25):
        trace = _random_walk_trace(seed)
        sem = AllocatorSemantics(SMALL, canonical=False)
        trace_accepted(sem, trace)   # raises on any divergence


@hypothesis.given(st.integers(min_value=0, max_value=10**6))
@hypothesis.settings(max_examples=25, deadline=None)
def test_randomized_real_traces_are_model_paths_hypothesis(seed):
    sem = AllocatorSemantics(SMALL, canonical=False)
    trace_accepted(sem, _random_walk_trace(seed))


def test_trace_accepted_rejects_canonical_semantics():
    with pytest.raises(ValueError, match="exact"):
        trace_accepted(AllocatorSemantics(SMALL, canonical=True), [])


def test_trace_accepted_flags_tampered_trace():
    trace = _random_walk_trace(3)
    # find a recorded ensure and lie about its return
    for i, (m, args, ret) in enumerate(trace):
        if m == "ensure":
            trace[i] = (m, args, not ret)
            break
    else:
        pytest.skip("walk recorded no ensure")
    with pytest.raises(ConformanceError):
        trace_accepted(AllocatorSemantics(SMALL, canonical=False), trace)


# ---------------------------------------------------------------------------
# direction 2 at full scale: a REAL Server run
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    return api, api.init(jax.random.PRNGKey(0))


def test_real_server_allocator_trace_is_a_model_path(model):
    """Every allocator call a real paged COW serving run makes — with
    the real prefix scheduler making the decisions — is a legal path of
    the abstract model with identical returns."""

    from repro.runtime.serve import Server
    api, params = model
    sched = TracingScheduler(make_scheduler("prefix"))
    srv = Server(api, params, batch=3, context=48, paged=True, page_size=4,
                 prefill_chunk=8, scheduler=sched, share_prefix=True)
    assert srv.scheduler.kind == "traced-prefix"
    srv.alloc.trace = []
    prefix = list(range(11, 29))
    for i in range(4):
        srv.submit(prefix + [40 + i, 50 + i], max_new=3)
    srv.run_until_drained()
    assert srv.alloc.trace, "paged run must touch the allocator"
    assert sched.trace and any(h == "pick" for h, _ in sched.trace)

    spec = srv.alloc.spec
    sem = AllocatorSemantics(
        AllocConfig(n_slots=srv.batch, page_size=spec.page_size,
                    pages_per_slot=spec.pages_per_slot,
                    n_pages=spec.n_pages),
        canonical=False)
    trace_accepted(sem, srv.alloc.trace)
