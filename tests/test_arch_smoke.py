"""Per-architecture smoke tests (deliverable f): REDUCED same-family
configs run one forward + one train step on CPU; output shapes + no NaNs.
The FULL configs are exercised only via the dry-run."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, supports
from repro.models import build_model

ALL = sorted(ARCHS)


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)) * 0.1,
            jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)) * 0.1,
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_and_finite(name):
    cfg = get_config(name).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits = api.forward(params, batch)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("name", ALL)
def test_one_train_step_no_nans(name):
    cfg = get_config(name).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(lambda q: api.loss(q, b))(p)
        new_p = jax.tree.map(lambda w, g: w - 1e-3 * g.astype(w.dtype),
                             p, grads)
        return loss, new_p

    loss, new_params = step(params, batch)
    assert bool(jnp.isfinite(loss))
    assert loss > 0
    flat = jax.tree.leaves(new_params)
    assert all(bool(jnp.isfinite(x.astype(jnp.float32)).all()) for x in flat)
    # a second step should move the loss
    loss2, _ = step(new_params, batch)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("name", ALL)
def test_decode_step_shapes(name):
    cfg = get_config(name).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, ctx = 2, 32
    state = api.init_decode_state(B, ctx)
    logits, new_state = api.decode_step(
        params, state, jnp.zeros((B, 1), jnp.int32), jnp.int32(3))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert jax.tree.structure(new_state) == jax.tree.structure(state)


@pytest.mark.parametrize("name", ["smollm-135m", "qwen3-32b", "qwen1.5-4b",
                                  "whisper-medium", "llama-3.2-vision-90b"])
def test_prefill_decode_agreement_exact_families(name):
    """Families without capacity-dropping MoE/bf16 SSD reordering must
    agree bit-for-bit between full forward and token-by-token decode.
    (VLM/enc-dec cross K/V start zeroed in both paths here.)"""

    cfg = get_config(name).reduced().replace(logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = make_batch(cfg, B, S, seed=3)
    if cfg.family == "vlm":
        batch["img_embeds"] = jnp.zeros_like(batch["img_embeds"])
    if cfg.is_encdec:
        pytest.skip("enc-dec decode needs prefilled cross-K/V "
                    "(covered by test_serving)")
    full = api.forward(params, batch)
    state = api.init_decode_state(B, S)
    outs = []
    for t in range(S):
        lg, state = api.decode_step(params, state,
                                    batch["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(dec))


@pytest.mark.parametrize("name", ["mamba2-2.7b", "hymba-1.5b"])
def test_prefill_decode_agreement_ssm(name):
    cfg = get_config(name).reduced().replace(logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = make_batch(cfg, B, S, seed=4)
    full = api.forward(params, batch)
    state = api.init_decode_state(B, S)
    outs = []
    for t in range(S):
        lg, state = api.decode_step(params, state,
                                    batch["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    # bf16 layer outputs reorder the f32 SSD math between the two paths
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               rtol=0.1, atol=0.1)


@pytest.mark.parametrize("name", ["mixtral-8x22b", "llama4-maverick-400b-a17b"])
def test_prefill_decode_agreement_moe_no_drops(name):
    """With a capacity factor high enough that nothing drops, the MoE
    paths must agree exactly (the earlier mismatch is capacity drops,
    which is expected train/serve behaviour)."""

    cfg = get_config(name).reduced().replace(logits_dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = make_batch(cfg, B, S, seed=5)
    full = api.forward(params, batch)
    state = api.init_decode_state(B, S)
    outs = []
    for t in range(S):
        lg, state = api.decode_step(params, state,
                                    batch["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    # bf16 scatter-add ordering differs between T=B*S and T=B dispatch:
    # allow 1-2 ulp; mixtral (no shared expert) is in fact bit-exact.
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               rtol=0, atol=0.02)


def test_moe_scatter_matches_einsum_oracle():
    from repro.models.moe import moe_forward, moe_forward_einsum, moe_specs
    from repro.models.common import init_params
    cfg = get_config("mixtral-8x22b").reduced()
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(2))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, cfg.d_model)),
                    jnp.float32)
    a = moe_forward(p, cfg, x)
    b = moe_forward_einsum(p, cfg, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


def test_ssd_chunk_size_invariance():
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    B, S, H, P, N = 2, 24, 3, 4, 5
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, (B, S, H)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.1, 1.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    base = ssd_chunked(x, dt, A, Bm, Cm, 24)
    for q in (4, 6, 8, 12):
        np.testing.assert_allclose(np.asarray(ssd_chunked(x, dt, A, Bm, Cm, q)),
                                   np.asarray(base), rtol=1e-4, atol=1e-5)


def test_supports_matrix():
    """DESIGN.md §4: long_500k only for sub-quadratic archs."""

    runs_500k = {n for n in ALL if supports(ARCHS[n], SHAPES["long_500k"])[0]}
    assert runs_500k == {"mamba2-2.7b", "hymba-1.5b", "mixtral-8x22b"}
    for n in ALL:  # every other shape applies everywhere
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports(ARCHS[n], SHAPES[s])[0]


def test_exact_assigned_configs():
    """The configs must match the assignment table exactly."""

    a = ARCHS
    assert (a["minitron-8b"].n_layers, a["minitron-8b"].d_model,
            a["minitron-8b"].n_heads, a["minitron-8b"].n_kv_heads,
            a["minitron-8b"].d_ff, a["minitron-8b"].vocab) == \
        (32, 4096, 32, 8, 16384, 256000)
    assert (a["qwen3-32b"].n_layers, a["qwen3-32b"].d_model,
            a["qwen3-32b"].d_ff, a["qwen3-32b"].vocab,
            a["qwen3-32b"].qk_norm) == (64, 5120, 25600, 151936, True)
    assert a["qwen1.5-4b"].qkv_bias and a["qwen1.5-4b"].n_kv_heads == 20
    assert a["smollm-135m"].d_model == 576 and a["smollm-135m"].vocab == 49152
    assert a["mamba2-2.7b"].ssm.state == 128 and a["mamba2-2.7b"].d_ff == 0
    assert a["mixtral-8x22b"].moe.num_experts == 8 and \
        a["mixtral-8x22b"].moe.top_k == 2 and a["mixtral-8x22b"].window
    m = a["llama4-maverick-400b-a17b"]
    assert m.moe.num_experts == 128 and m.moe.top_k == 1 and m.vocab == 202048
    v = a["llama-3.2-vision-90b"]
    assert v.n_layers == 100 and v.d_model == 8192 and v.cross_attn_every == 5
    h = a["hymba-1.5b"]
    assert h.ssm.state == 16 and h.n_heads == 25 and h.n_kv_heads == 5
    w = a["whisper-medium"]
    assert w.encoder_layers == 24 and w.vocab == 51865
