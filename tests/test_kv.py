"""Paged KV-cache subsystem tests: allocator invariants, paged-vs-
contiguous decode numerics (dense / GQA / MHA / SWA / hybrid, staggered
mixed-phase admissions), page-exhaustion deferral, and the page-size
tunable's plan/cache integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.models.attention import (attn_specs, decode_attention,
                                    decode_attention_paged)
from repro.models.common import init_params
from repro.runtime.kv import NO_PAGE, PagedKVAllocator, PagedKVSpec
from repro.runtime.serve import KVPageTunable, Server


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------


def make_alloc(n_pages=8, page_size=4, pages_per_slot=4, n_slots=3):
    spec = PagedKVSpec(n_pages=n_pages, page_size=page_size,
                       pages_per_slot=pages_per_slot)
    return PagedKVAllocator(spec, n_slots)


def test_allocator_pages_never_shared_between_live_slots():
    alloc = make_alloc()
    assert alloc.ensure(0, 10) and alloc.ensure(1, 7) and alloc.ensure(2, 3)
    owned = {}
    for s in range(3):
        for p in alloc.slot_pages(s):
            assert p not in owned, f"page {p} owned by {owned[p]} and {s}"
            owned[p] = s
    # ownership array agrees with the page tables
    for p, s in owned.items():
        assert alloc.owner[p] == s
    assert len(owned) == alloc.used_pages == 3 + 2 + 1


def test_allocator_ensure_is_all_or_nothing():
    alloc = make_alloc(n_pages=4)
    assert alloc.ensure(0, 9)                  # 3 pages
    free_before = alloc.free_pages
    assert not alloc.ensure(1, 9)              # needs 3, only 1 free
    assert alloc.free_pages == free_before     # nothing leaked
    assert alloc.slot_pages(1) == []
    assert alloc.ensure(1, 4)                  # 1 page still fits


def test_allocator_free_list_reuse_after_release():
    alloc = make_alloc()
    alloc.ensure(0, 16)                        # 4 pages
    released = set(alloc.slot_pages(0))
    assert alloc.release(0) == 4
    assert alloc.page_table[0].tolist() == [NO_PAGE] * 4
    assert alloc.free_pages == 8
    # a fresh slot reuses the just-released pages (LIFO free list)
    alloc.ensure(1, 16)
    assert set(alloc.slot_pages(1)) == released
    # and release is idempotent on an empty slot
    assert alloc.release(0) == 0


def test_allocator_ensure_grows_monotonically():
    alloc = make_alloc()
    alloc.ensure(0, 3)                         # 1 page
    first = alloc.slot_pages(0)
    alloc.ensure(0, 4)                         # same page covers it
    assert alloc.slot_pages(0) == first
    alloc.ensure(0, 5)                         # needs a second page
    assert len(alloc.slot_pages(0)) == 2
    assert alloc.slot_pages(0)[0] == first[0]  # prefix untouched


def test_allocator_trim_frees_only_whole_dead_pages():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 16)                        # pages for positions 0..15
    assert alloc.trim(0, 3) == 0               # page 0 still partly live
    assert alloc.trim(0, 4) == 1               # positions 0..3 dead
    assert alloc.page_table[0, 0] == NO_PAGE
    assert alloc.trim(0, 11) == 1              # page 1 dead, page 2 not
    # trimmed logical pages are never re-backed: the high-water mark
    # keeps ensure() from resurrecting positions already written
    assert alloc.ensure(0, 16)
    assert alloc.page_table[0, 0] == NO_PAGE
    assert alloc.page_table[0, 1] == NO_PAGE


def test_allocator_rewind_frees_pages_above_keep():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 14)                        # 4 pages (positions 0..13)
    kept = alloc.slot_pages(0)[:2]
    assert alloc.rewind(0, 7) == 2             # keep 2 pages (0..6)
    assert alloc.slot_pages(0) == kept
    assert alloc.free_pages == 8 - 2
    # unlike trim, rewind LOWERS the high-water mark: the freed logical
    # pages can be re-backed by a later ensure (draft rejected, decode
    # continues through those positions)
    assert alloc.ensure(0, 14)
    assert len(alloc.slot_pages(0)) == 4
    assert alloc.slot_pages(0)[:2] == kept     # kept prefix untouched


def test_allocator_rewind_noop_within_kept_pages():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 8)                         # 2 pages
    before = alloc.slot_pages(0)
    assert alloc.rewind(0, 8) == 0             # boundary: nothing above
    assert alloc.rewind(0, 5) == 0             # page 1 still partly kept
    assert alloc.slot_pages(0) == before
    assert alloc.rewind(0, 4) == 1             # positions 4..7 dropped
    assert alloc.slot_pages(0) == before[:1]
    # empty slot: rewind to zero frees everything and is idempotent
    assert alloc.rewind(0, 0) == 1
    assert alloc.rewind(0, 0) == 0
    assert alloc.slot_pages(0) == [] and alloc.free_pages == 8


def test_allocator_overflowing_page_table_raises():
    alloc = make_alloc(pages_per_slot=2, page_size=4)
    with pytest.raises(ValueError, match="exceed the page table"):
        alloc.ensure(0, 9)


def test_paged_spec_rejects_pool_smaller_than_one_slot():
    with pytest.raises(ValueError, match="single request could deadlock"):
        PagedKVSpec.for_server(context=64, page_size=8, n_pages=4)


def test_allocator_stats_fragmentation():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 5)                         # 2 pages = 8 token capacity
    st = alloc.stats(live_tokens=5)
    assert st["used_pages"] == 2 and st["occupancy"] == 2 / 8
    assert st["fragmentation"] == pytest.approx(3 / 8)


# ---------------------------------------------------------------------------
# copy-on-write sharing invariants
# ---------------------------------------------------------------------------


def test_allocator_share_maps_pages_and_bumps_refcounts():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 10)                        # 3 pages
    used_before = alloc.used_pages
    assert alloc.share(0, 1, 8) == 2           # map the 2 full pages
    assert alloc.slot_pages(1) == alloc.slot_pages(0)[:2]
    assert alloc.used_pages == used_before     # no new physical pages
    assert alloc.shared_pages == 2
    for p in alloc.slot_pages(1):
        assert alloc.refcount[p] == 2
    assert alloc.refcount[alloc.slot_pages(0)[2]] == 1  # unshared page
    # sharing covers the table: the sharer grows ABOVE the prefix only
    assert alloc.ensure(1, 12)
    assert len(alloc.slot_pages(1)) == 3
    assert alloc.slot_pages(1)[2] != alloc.slot_pages(0)[2]


def test_allocator_share_rejects_bad_src_or_dst():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 10)
    alloc.ensure(1, 4)
    with pytest.raises(ValueError, match="not empty"):
        alloc.share(0, 1, 8)                   # dst already holds pages
    with pytest.raises(ValueError, match="does not back"):
        alloc.share(0, 2, 16)                  # src backs only 10 tokens
    assert alloc.share(0, 2, 0) == 0           # degenerate share is a noop
    assert alloc.slot_pages(2) == []


def test_allocator_shared_pages_survive_source_release():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 10)                        # 3 pages
    alloc.share(0, 1, 8)
    shared = alloc.slot_pages(1)
    assert alloc.release(0) == 3               # src lets go of all three
    assert alloc.free_pages == 8 - 2           # only the unshared one freed
    for p in shared:
        assert alloc.refcount[p] == 1          # now exclusive to slot 1
        assert alloc.owner[p] == 1             # ownership reassigned
    assert alloc.release(1) == 2
    assert alloc.free_pages == 8
    assert not alloc.refcount.any()


def test_allocator_sharer_release_keeps_source_pages():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 8)
    alloc.share(0, 1, 8)
    assert alloc.release(1) == 2
    assert alloc.free_pages == 8 - 2           # source still holds them
    assert alloc.shared_pages == 0
    for p in alloc.slot_pages(0):
        assert alloc.refcount[p] == 1 and alloc.owner[p] == 0


def test_allocator_cow_breaks_exactly_the_shared_pages_in_range():
    alloc = make_alloc(page_size=4, n_pages=8)
    alloc.ensure(0, 8)                         # 2 pages
    alloc.share(0, 1, 6)                       # both pages, 2nd partial
    # the sharer writes positions [6, 9): page 1 is shared (COW), page 2
    # is unmapped (plain ensure territory, not COW's business)
    pairs = alloc.cow_pages(1, 6, 9)
    assert len(pairs) == 1
    old, new = pairs[0]
    assert old == alloc.slot_pages(0)[1]       # src keeps the original
    assert alloc.page_table[1, 1] == new
    assert alloc.refcount[old] == 1 and alloc.refcount[new] == 1
    assert alloc.owner[new] == 1
    assert alloc.shared_pages == 1             # page 0 still shared
    # a second write to the now-private page needs no copy
    assert alloc.cow_pages(1, 6, 9) == []


def test_allocator_cow_is_all_or_nothing_under_pressure():
    alloc = make_alloc(page_size=4, n_pages=2, n_slots=2,
                       pages_per_slot=2)
    alloc.ensure(0, 8)                         # both pages taken
    alloc.share(0, 1, 6)
    table_before = alloc.page_table.copy()
    assert alloc.cow_pages(1, 4, 6) is None    # no free page for the copy
    assert (alloc.page_table == table_before).all()
    assert alloc.free_pages == 0


def test_allocator_rewind_and_trim_deref_shared_pages():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 8)
    alloc.share(0, 1, 8)
    free_before = alloc.free_pages
    # the source trims its low page: still mapped by the sharer, so the
    # page must NOT hit the free list (freed count is 0)
    assert alloc.trim(0, 4) == 0
    assert alloc.free_pages == free_before
    assert alloc.refcount[alloc.slot_pages(1)[0]] == 1
    # the sharer rewinds off its top page (also shared): same deal
    assert alloc.rewind(1, 4) == 0
    assert alloc.free_pages == free_before
    # last holders letting go really free them
    assert alloc.release(0) == 1
    assert alloc.release(1) == 1
    assert alloc.free_pages == 8


def test_allocator_stats_reports_shared_pages():
    alloc = make_alloc(page_size=4)
    alloc.ensure(0, 8)
    alloc.share(0, 1, 8)
    assert alloc.stats()["shared_pages"] == 2.0


# ---------------------------------------------------------------------------
# paged attention numerics (unit level: shuffled physical pages)
# ---------------------------------------------------------------------------


def test_decode_attention_paged_matches_contiguous_unit():
    """The paged gather/scatter is pure indirection: with the same K/V
    laid out through an arbitrary (shuffled) page table, one-token
    attention must reproduce the contiguous path allclose."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    p = init_params(attn_specs(cfg), jax.random.PRNGKey(1))
    B, C, ps = 3, 32, 8
    M, P = C // ps, 3 * (C // ps)
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    cur_len = np.array([5, 0, 17], np.int32)

    rng = np.random.default_rng(0)
    k_cont = np.zeros((B, Hkv, C, hd), np.float32)
    v_cont = np.zeros((B, Hkv, C, hd), np.float32)
    for b, n in enumerate(cur_len):
        k_cont[b, :, :n] = rng.standard_normal((Hkv, n, hd))
        v_cont[b, :, :n] = rng.standard_normal((Hkv, n, hd))

    # shuffled physical layout of the same data
    perm = rng.permutation(P)
    page_table = np.full((B, M), -1, np.int32)
    pool_k = np.zeros((P, Hkv, ps, hd), np.float32)
    pool_v = np.zeros((P, Hkv, ps, hd), np.float32)
    next_page = 0
    for b, n in enumerate(cur_len):
        for m in range(-(-int(n + 1) // ps)):   # cover the write position
            page = int(perm[next_page])
            next_page += 1
            page_table[b, m] = page
            pool_k[page] = k_cont[b, :, m * ps:(m + 1) * ps]
            pool_v[page] = v_cont[b, :, m * ps:(m + 1) * ps]

    x = rng.standard_normal((B, 1, cfg.d_model)).astype(np.float32)
    out_c, new_c = decode_attention(
        p, cfg, jnp.asarray(x), {"k": jnp.asarray(k_cont),
                                 "v": jnp.asarray(v_cont)},
        jnp.asarray(cur_len))
    out_p, new_p = decode_attention_paged(
        p, cfg, jnp.asarray(x), {"k": jnp.asarray(pool_k),
                                 "v": jnp.asarray(pool_v)},
        jnp.asarray(page_table), jnp.asarray(cur_len))
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_p),
                               atol=1e-5, rtol=1e-5)

    # the new token landed at its page-table target, matching the
    # contiguous write at index cur_len
    for b, n in enumerate(cur_len):
        page = page_table[b, n // ps]
        np.testing.assert_allclose(
            np.asarray(new_p["k"])[page, :, n % ps],
            np.asarray(new_c["k"])[b, :, n], atol=1e-6)


def test_decode_attention_paged_inactive_slots_write_nothing():
    """``active`` gates pool writes per slot: the pool is shared, so an
    idle/prefilling neighbour's garbage token must not land."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    p = init_params(attn_specs(cfg), jax.random.PRNGKey(1))
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    pool = {"k": jnp.zeros((4, Hkv, 4, hd)), "v": jnp.zeros((4, Hkv, 4, hd))}
    page_table = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    x = jnp.ones((2, 1, cfg.d_model))
    _, new_pool = decode_attention_paged(
        p, cfg, x, pool, page_table, jnp.asarray([0, 0]),
        active=jnp.asarray([True, False]))
    assert np.asarray(new_pool["k"])[0].any()          # slot 0 wrote
    assert not np.asarray(new_pool["k"])[2:].any()     # slot 1 did not


# ---------------------------------------------------------------------------
# paged serving end-to-end vs contiguous
# ---------------------------------------------------------------------------


def _solo_out(api, params, prompt, max_new, **kw):
    solo = Server(api, params, batch=1, context=32, **kw)
    ref = solo.submit(prompt, max_new=max_new)
    solo.run_until_drained()
    return ref.out


@pytest.mark.parametrize("arch,extra", [
    ("smollm-135m", {}),                       # dense GQA (4 heads / 2 kv)
    ("qwen1.5-4b", {}),                        # dense MHA + qkv bias
    ("smollm-135m", {"window": 8}),            # sliding window (ring vs
                                               # paged trim reclamation)
    ("hymba-1.5b", {}),                        # hybrid attn + SSM state
])
def test_paged_matches_contiguous_staggered_mixed_phase(arch, extra):
    """Paged mode is an allocation change, not a semantics change: under
    staggered admissions with mixed prefill/decode phases in one tick,
    every request must decode exactly as it would through the contiguous
    ring (which itself matches the solo drain)."""

    cfg = get_config(arch).reduced().replace(logits_dtype="float32",
                                             **extra)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompt_a = rng.integers(0, cfg.vocab, 14).tolist()
    prompt_b = rng.integers(0, cfg.vocab, 6).tolist()

    srv = Server(api, params, batch=2, context=32, prefill_chunk=4,
                 paged=True, page_size=8)
    req_a = srv.submit(prompt_a, max_new=4)
    for _ in range(2):
        srv.tick()               # A mid-prefill when B arrives
    req_b = srv.submit(prompt_b, max_new=4)
    srv.run_until_drained()
    assert req_a.done and req_b.done

    for prompt, req in ((prompt_a, req_a), (prompt_b, req_b)):
        assert req.out == _solo_out(api, params, prompt, 4,
                                    prefill_chunk=4)


def test_paged_admission_waits_for_free_pages():
    """A pool that holds one request's pages at a time: the second
    request queues until the first retires and releases, then reuses
    the freed pages — and still decodes correctly."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    srv = Server(api, params, batch=2, context=32, paged=True,
                 page_size=8, kv_pages=4)        # pool == one full slot
    p1, p2 = list(range(1, 21)), list(range(3, 20))
    r1 = srv.submit(p1, max_new=4)
    r2 = srv.submit(p2, max_new=4)
    srv.tick()
    assert srv.queue and srv.queue[0] is r2      # no pages -> not admitted
    srv.run_until_drained()
    assert r1.done and r2.done
    assert r1.out == _solo_out(api, params, p1, 4)
    assert r2.out == _solo_out(api, params, p2, 4)


def test_paged_oom_at_tick_defers_youngest_and_restarts():
    """Decode growth exhausting the pool mid-flight defers the YOUNGEST
    slot (pages released, request requeued with its progress kept and
    re-prefilled on resume); the oldest keeps progressing, both finish
    with solo-exact outputs."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    srv = Server(api, params, batch=2, context=32, paged=True,
                 page_size=8, kv_pages=4)
    p1, p2 = list(range(1, 21)), list(range(1, 15))
    r1 = srv.submit(p1, max_new=4)
    r2 = srv.submit(p2, max_new=4)
    srv.run_until_drained()
    assert r1.done and r2.done
    assert srv.deferrals >= 1                    # the pool really choked
    assert r1.out == _solo_out(api, params, p1, 4)
    assert r2.out == _solo_out(api, params, p2, 4)


def test_paged_sliding_window_trims_dead_pages():
    """SWA reclamation: pages that fell wholly out of the window free
    mid-request, so a long SWA request occupies O(window), not O(len)."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32", window=8)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    srv = Server(api, params, batch=1, context=32, paged=True,
                 page_size=4, prefill_chunk=4)
    prompt = list(range(1, 25))
    req = srv.submit(prompt, max_new=4)
    peak = 0
    while not req.done:
        srv.tick()
        peak = max(peak, srv.alloc.used_pages)
    # window=8 at page_size=4 needs at most 3 live pages (window spans
    # at most ceil(w/ps)+1 partially-filled pages)
    assert peak <= 3
    assert req.out == _solo_out(api, params, prompt, 4, prefill_chunk=4)


def test_paged_slot_reuse_after_retire():
    """Retired slots release pages and a reused slot starts clean."""

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    srv = Server(api, params, batch=1, context=32, paged=True, page_size=8)
    prompt = list(range(1, 13))
    r1 = srv.submit(prompt, max_new=3)
    srv.run_until_drained()
    assert srv.alloc.used_pages == 0             # retire released all
    r2 = srv.submit(prompt, max_new=3)
    srv.run_until_drained()
    assert r1.out == r2.out


# ---------------------------------------------------------------------------
# KVPageTunable
# ---------------------------------------------------------------------------


def test_kv_page_tunable_space_and_cost_tradeoff():
    tb = KVPageTunable(param_bytes=1 << 22, layers=2, d_model=64,
                       kv_width=32, context=256, prompt_lens=(16, 200),
                       requests=16, mean_new=16, batch=8, pool_tokens=512)
    pages = [c["page"] for c in tb.space()]
    assert pages == [4, 8, 16, 32, 64, 128]
    costs = {ps: tb.cost({"page": ps}) for ps in pages}
    best = min(costs, key=costs.get)
    # a genuine tradeoff: the optimum is interior — tiny pages lose to
    # gather overhead, huge pages lose to fragmentation waste
    assert best not in (pages[0], pages[-1])
    fp = tb.fingerprint()
    assert fp["tunable"] == "serve.kv_page" and fp["unit"] == "us"
    assert fp["prompt_lens"] == [16, 200]
    assert "api" not in fp and "params" not in fp


def test_kv_page_tunable_measure_requires_model():
    tb = KVPageTunable(param_bytes=1 << 20, layers=2, d_model=64,
                       kv_width=32, context=32, prompt_lens=(8,),
                       requests=2, mean_new=2, batch=1)
    with pytest.raises(RuntimeError, match="api=/params="):
        tb.measure({"page": 8})


def test_kv_page_plan_roundtrip_zero_engine_runs(tmp_path):
    """Acceptance slice: ``serve.kv_page`` resolves from a warmed cache
    through a pure-JSON plan spec with ZERO engine runs (api/params
    handles excluded from the fingerprint)."""

    from repro.runtime.serve import kv_page_tunable
    from repro.tune import TuningCache, TuningPlan, tune

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = TuningCache(tmp_path / "c.json")

    tb = kv_page_tunable(api, context=32, prompt_lens=[4, 12], requests=2,
                         max_new=2, batch=2, params=params)
    res = tune(tb, engine="measure", cache=cache, budget=1, repeats=1)
    assert res.stats["provenance"] == "measured"

    spec = {"name": "kv-warmup", "jobs": [
        {"tunable": "serve.kv_page",
         "params": {"param_bytes": api.param_count() * 2,
                    "layers": cfg.n_layers, "d_model": cfg.d_model,
                    "kv_width": cfg.n_kv_heads * cfg.hd, "context": 32,
                    "prompt_lens": [4, 12], "requests": 2, "mean_new": 2,
                    "batch": 2},
         "engine": "measure",
         "engine_kwargs": {"budget": 1, "repeats": 1}}]}
    report = TuningPlan.from_spec(spec).run(cache=cache)
    assert report.ok and report.results[0].status == "hit"
    assert report.results[0].provenance == "measured"
    assert report.results[0].best_config == dict(res.best_config)
