"""Unified ``repro.tune`` API tests: engine registry, persistent cache,
``@autotune`` fast path, and cross-engine agreement."""

import pytest

from repro.core import PlatformSpec
from repro.core.search_space import Param, SearchSpace
from repro.core.tpu_machine import (DistributedTunable, hbm_fits,
                                    tune_distributed, workload_from_arch)
from repro.kernels.matmul_tuned import ops as mm
from repro.tune import (Engine, PlatformTunable, Tunable, TuningCache,
                        autotune, available_engines, cache_key, get_engine,
                        register_engine, set_default_cache, tune)
from repro.tune.engines import _REGISTRY, EngineError

QUICKSTART = PlatformSpec(size=16, NP=4, GMT=4, kind="minimum")


class CountingTunable:
    """Tiny tunable that counts cost evaluations (cache-hit probe)."""

    name = "test.counting"

    def __init__(self, ident="a"):
        self.ident = ident
        self.cost_calls = 0

    def space(self):
        return SearchSpace(params=[Param("block", (1, 2, 4))])

    def cost(self, cfg):
        self.cost_calls += 1
        return 10 // cfg["block"]

    def fingerprint(self):
        return {"tunable": self.name, "ident": self.ident}


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

def test_registry_has_all_engines():
    names = available_engines()
    for n in ("sweep", "explorer", "swarm", "bnb", "grid", "bisect",
              "measure"):
        assert n in names
    eng = get_engine("sweep")
    assert isinstance(eng, Engine) and eng.name == "sweep"


def test_unknown_engine_error_lists_registered():
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("does-not-exist")
    with pytest.raises(ValueError, match="sweep"):
        get_engine("does-not-exist")


def test_register_engine_plugs_in():
    @register_engine("test-constant")
    class ConstantEngine(Engine):
        def run(self, tunable, *, budget=None, **kw):
            from repro.tune import TuneResult
            return TuneResult(best_config={"block": 1}, t_min=42,
                              engine=self.name)
    try:
        res = tune(CountingTunable(), engine="test-constant", cache=None)
        assert res.t_min == 42 and res.engine == "test-constant"
    finally:
        _REGISTRY.pop("test-constant")


def test_platform_engine_rejects_plain_tunable():
    with pytest.raises(EngineError, match="platform tunable"):
        tune(CountingTunable(), engine="explorer", cache=None)


# ---------------------------------------------------------------------------
# cross-engine agreement (the old==new parity tests retired with the
# AutoTuner/FunctionTuner shims; the engines now pin each other)
# ---------------------------------------------------------------------------

def test_engines_agree_quickstart():
    """Every engine the seed exposed finds the same minimal time on the
    quickstart platform (sweep is deterministic and exact)."""

    tunable = PlatformTunable(QUICKSTART)
    exact = tune(tunable, engine="sweep", cache=None)
    from repro.core import WaveParams, model_time, wg_ts_space
    wp = WaveParams(size=16, NP=4, GMT=4, kind="minimum")
    assert exact.t_min == min(model_time(wp, c["WG"], c["TS"])
                              for c in wg_ts_space(16))
    for engine in ("explorer", "swarm"):
        assert tune(tunable, engine=engine, cache=None).t_min == \
            exact.t_min, engine


def test_grid_engine_matches_exhaustive_matmul_cost_model():
    M, N, K = 256, 256, 512
    space = mm.tuning_space(M, N, K)
    truth = min(mm.cost_model(c, M=M, N=N, K=K) for c in space)
    new = tune(mm.MatmulTunable(M, N, K), engine="grid", cache=None)
    assert new.t_min == truth
    assert mm.cost_model(new.best_config, M=M, N=N, K=K) == truth


def test_bisect_engine_agrees_with_sweep():
    t = PlatformTunable(QUICKSTART)
    assert tune(t, engine="bisect", cache=None).t_min == \
        tune(t, engine="sweep", cache=None).t_min


def test_tpu_workload_is_tunable():
    w = workload_from_arch("qwen3-32b", "train_4k")
    assert isinstance(w, Tunable)
    tb = w.tunable(chips_per_pod=256, pods=1)
    res = tune(tb, engine="grid", cache=None)
    best, t, ranked = tune_distributed(w, chips_per_pod=256, pods=1)
    assert res.t_min == t["total"]
    assert hbm_fits(w, tb.to_config(res.best_config))


# ---------------------------------------------------------------------------
# TuningCache
# ---------------------------------------------------------------------------

def test_cache_roundtrip_and_hit_skips_engine(tmp_path):
    cache = TuningCache(tmp_path / "cache.json")
    t = CountingTunable()
    r1 = tune(t, engine="grid", cache=cache)
    assert r1.best_config == {"block": 4} and r1.stats["cache"] == "miss"
    calls_after_first = t.cost_calls
    assert calls_after_first == 3

    r2 = tune(t, engine="grid", cache=cache)
    assert r2.stats["cache"] == "hit"
    assert t.cost_calls == calls_after_first          # engine did not re-run
    assert r2.best_config == r1.best_config and r2.t_min == r1.t_min
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 1

    # persistent across instances: after a flush, a fresh cache object
    # reloads the file (puts are deferred — save() writes them out)
    cache.save()
    fresh = TuningCache(tmp_path / "cache.json")
    t2 = CountingTunable()
    r3 = tune(t2, engine="grid", cache=fresh)
    assert r3.stats["cache"] == "hit" and t2.cost_calls == 0


def test_cache_put_defers_write_until_save(tmp_path):
    """``put`` is O(1): it marks the store dirty and the JSON file is
    only (re)written on explicit ``save()`` (and at interpreter exit) —
    a sweep storing N entries costs one serialization, not N."""

    path = tmp_path / "cache.json"
    cache = TuningCache(path)
    for ident in ("a", "b", "c"):
        tune(CountingTunable(ident), engine="grid", cache=cache)
    assert cache.dirty and not path.exists()
    cache.save()
    assert not cache.dirty and path.exists()
    assert len(TuningCache(path)) == 3


def test_cache_invalidates_on_shape_change(tmp_path):
    cache = TuningCache(tmp_path / "cache.json")
    tune(mm.MatmulTunable(256, 256, 512), engine="grid", cache=cache)
    res = tune(mm.MatmulTunable(512, 256, 512), engine="grid", cache=cache)
    assert res.stats["cache"] == "miss"               # different fingerprint
    assert len(cache) == 2


def test_cache_invalidates_on_platform_change(tmp_path, monkeypatch):
    t = mm.MatmulTunable(256, 256, 512)
    k1, _ = cache_key(t, "grid")
    monkeypatch.setattr("repro.tune.cache.platform_fingerprint",
                        lambda: {"backend": "tpu", "device_kind": "v5e"})
    k2, _ = cache_key(t, "grid")
    assert k1 != k2


def test_cache_keyed_by_engine_kwargs(tmp_path):
    """Runs with different search settings must not collide on one
    cache entry (e.g. a measure-based run after a cost-model run)."""

    class Measured(CountingTunable):
        def __init__(self):
            super().__init__()
            self.measure_calls = 0

        def measure(self, cfg):
            self.measure_calls += 1
            return float(cfg["block"])          # opposite optimum: block=1

    cache = TuningCache(tmp_path / "cache.json")
    t = Measured()
    r1 = tune(t, engine="grid", cache=cache)
    assert r1.best_config == {"block": 4} and t.measure_calls == 0
    r2 = tune(t, engine="grid", cache=cache, use_measure=True)
    assert r2.stats["cache"] == "miss"          # distinct key, not a hit
    assert t.measure_calls == 3
    assert r2.best_config == {"block": 1}
    k1, _ = cache_key(t, "grid")
    k2, _ = cache_key(t, "grid", params={"use_measure": True})
    assert k1 != k2


def test_autotune_pins_explicit_params():
    """Tuning with a subset of params given must pin them into the
    lattice, so injected values respect the space's joint constraints."""

    M = N = K = 2048
    big = mm.MatmulTunable(M, N, K)
    joint = tune(big, engine="grid", cache=None).best_config

    import jax.numpy as jnp
    a = jnp.zeros((M, K), jnp.bfloat16)
    b = jnp.zeros((K, N), jnp.bfloat16)
    pinned = mm.matmul_tuned.tune(a, b, bm=2048)
    assert pinned.best_config["bm"] == 2048
    # the combined config must satisfy the VMEM constraint of the space
    space = mm.tuning_space(M, N, K)
    assert all(c(pinned.best_config) for c in space.constraints)
    # sanity: the unpinned joint optimum here picks a different bm, so
    # naive "tune jointly, then overwrite bm" would have violated it
    if joint["bm"] != 2048:
        joint_overwritten = {**joint, "bm": 2048}
        assert not all(c(joint_overwritten) for c in space.constraints)


def test_function_tunable_fingerprint_keys_cost_fn(tmp_path):
    """Same space + different cost functions must not share an entry."""

    from repro.tune import FunctionTunable
    space = SearchSpace(params=[Param("b", (1, 2, 4))])
    cache = TuningCache(tmp_path / "cache.json")
    r1 = tune(FunctionTunable(lambda c: c["b"], space), "grid", cache=cache)
    r2 = tune(FunctionTunable(lambda c: -c["b"], space), "grid", cache=cache)
    assert r1.best_config == {"b": 1}
    assert r2.best_config == {"b": 4} and r2.stats["cache"] == "miss"


def test_platform_tunable_fingerprint_keys_custom_space():
    full = PlatformTunable(QUICKSTART)
    restricted = PlatformTunable(
        QUICKSTART, space=SearchSpace(params=[Param("WG", (1,)),
                                              Param("TS", (1,))]))
    assert cache_key(full, "grid")[0] != cache_key(restricted, "grid")[0]


def test_engine_rejects_unknown_kwargs():
    """Typo'd engine kwargs must raise, not silently run defaults."""

    with pytest.raises(TypeError):
        tune(PlatformTunable(QUICKSTART), engine="swarm", cache=None,
             nwalks=64)      # typo for n_walks


def test_cache_hit_preserves_witness(tmp_path):
    """Step-4 counterexample analysis must survive a cache round-trip."""

    from repro.core import build_model
    cache = TuningCache(tmp_path / "cache.json")
    t = PlatformTunable(QUICKSTART)
    r1 = tune(t, engine="explorer", cache=cache)
    r2 = tune(t, engine="explorer", cache=cache)
    assert r2.stats["cache"] == "hit"
    assert r2.witness is not None
    assert r2.witness.config == r1.witness.config
    assert r2.witness.validate(build_model(QUICKSTART))


def test_flash_tunable_keys_window():
    from repro.kernels.flash_attention.ops import FlashAttentionTunable
    a = FlashAttentionTunable(S=4096, D=64, BH=8)
    b = FlashAttentionTunable(S=4096, D=64, BH=8, window=256)
    assert cache_key(a, "grid")[0] != cache_key(b, "grid")[0]
    cfg = {"block_q": 128, "block_k": 128}
    assert b.cost(cfg) < a.cost(cfg)    # window skips most KV blocks


def test_cache_force_reruns(tmp_path):
    cache = TuningCache(tmp_path / "cache.json")
    t = CountingTunable()
    tune(t, engine="grid", cache=cache)
    n = t.cost_calls
    res = tune(t, engine="grid", cache=cache, force=True)
    # a forced re-run over an existing entry is tagged "force" so
    # rollout reports distinguish re-tunes from cold misses
    assert t.cost_calls == 2 * n and res.stats["cache"] == "force"


def test_force_on_cold_cache_is_a_plain_miss(tmp_path):
    cache = TuningCache(tmp_path / "cache.json")
    res = tune(CountingTunable(), engine="grid", cache=cache, force=True)
    assert res.stats["cache"] == "miss"         # nothing was overwritten


# ---------------------------------------------------------------------------
# measure engine (cost-model shortlist -> wall-clock verdict)
# ---------------------------------------------------------------------------


class MeasuredTunable(CountingTunable):
    """cost says block=4 is best (10//block); measure says block=2 is
    (measured time = |block - 2|) — the model and the hardware disagree,
    which is exactly what the measure engine exists to resolve."""

    def __init__(self, ident="a"):
        super().__init__(ident)
        self.measure_calls = 0

    def measure(self, cfg):
        self.measure_calls += 1
        return float(abs(cfg["block"] - 2))


def test_measure_engine_returns_wallclock_winner(tmp_path):
    cache = TuningCache(tmp_path / "cache.json")
    t = MeasuredTunable()
    res = tune(t, engine="measure", cache=cache, repeats=1)
    # cost ranks 4 < 2 < 1; full shortlist (top_k=4 >= 3) measured;
    # wall-clock picks block=2 over the model's block=4
    assert res.best_config == {"block": 2}
    assert res.t_min == 0.0
    assert res.stats["provenance"] == "measured"
    assert t.measure_calls == 3

    # both rankings recorded: the modeled pick and its measured time
    assert res.stats["modeled_pick"]["config"] == {"block": 4}
    assert res.stats["measured_pick"]["config"] == {"block": 2}
    assert res.stats["measured_pick"]["measured"] <= \
        res.stats["modeled_pick"]["measured"]

    # ... and they survive the cache round-trip
    r2 = tune(t, engine="measure", cache=cache, repeats=1)
    assert r2.stats["cache"] == "hit"
    assert r2.stats["provenance"] == "measured"
    assert r2.stats["modeled_pick"]["measured"] == \
        res.stats["modeled_pick"]["measured"]
    assert t.measure_calls == 3                 # hit: no re-measurement


def test_measure_engine_budget_bounds_shortlist():
    t = MeasuredTunable()
    res = tune(t, engine="measure", cache=None, budget=1, repeats=1)
    # shortlist of 1 = the pure cost-model pick, measured
    assert t.measure_calls == 1
    assert res.best_config == {"block": 4}
    assert res.stats["shortlist"] == 1 and res.stats["evaluated"] == 3


def test_measure_engine_median_of_repeats():
    class Noisy(MeasuredTunable):
        def measure(self, cfg):
            self.measure_calls += 1
            # one wild outlier per config; median must shrug it off
            if self.measure_calls % 3 == 1:
                return 1e9
            return float(abs(cfg["block"] - 2))

    t = Noisy()
    res = tune(t, engine="measure", cache=None, repeats=3)
    assert t.measure_calls == 9
    assert res.best_config == {"block": 2} and res.t_min == 0.0


def test_measure_engine_true_median_even_repeats():
    """``times[len // 2]`` picked the upper-middle sample: repeats=2
    returned the WORSE of the two times.  A true median averages the
    middle pair."""

    class TwoSample(MeasuredTunable):
        def measure(self, cfg, **kw):
            self.measure_calls += 1
            # per config: samples alternate base and base + 2.0
            base = float(abs(cfg["block"] - 2))
            return base if self.measure_calls % 2 else base + 2.0

    t = TwoSample()
    res = tune(t, engine="measure", cache=None, repeats=2)
    assert t.measure_calls == 6
    # block=2: samples {0.0, 2.0} -> median 1.0 (NOT the worse 2.0)
    assert res.best_config == {"block": 2}
    assert res.t_min == pytest.approx(1.0)


def test_measure_engine_median_odd_repeats_is_middle_sample():
    class ThreeSample(MeasuredTunable):
        def measure(self, cfg, **kw):
            self.measure_calls += 1
            base = float(abs(cfg["block"] - 2))
            return base + [0.0, 5.0, 1.0][self.measure_calls % 3]

    t = ThreeSample()
    res = tune(t, engine="measure", cache=None, repeats=3)
    # per config the samples are {base, base+5, base+1}: median base+1
    assert res.best_config == {"block": 2}
    assert res.t_min == pytest.approx(1.0)


def test_measure_engine_requires_measure_method():
    with pytest.raises(EngineError, match="measure"):
        tune(CountingTunable(), engine="measure", cache=None)


def test_measure_engine_kernel_end_to_end(tmp_path):
    """The full vertical slice on CPU interpret mode: a real Pallas
    kernel tunable measured for real, winner cached with provenance."""

    cache = TuningCache(tmp_path / "cache.json")
    t = mm.MatmulTunable(128, 128, 128)         # one-point lattice: fast
    res = tune(t, engine="measure", cache=cache, repeats=1)
    assert res.stats["provenance"] == "measured"
    assert res.t_min > 0.0                      # a real wall-clock time
    assert res.best_config == {"bm": 128, "bn": 128, "bk": 128}
    entry = list(cache._entries.values())[0]
    assert entry["provenance"] == "measured"
    assert entry["stats"]["modeled_pick"]["modeled"] > 0.0
    assert entry["stats"]["measured_pick"]["measured"] == res.t_min


def test_force_overwrites_hit_with_fresh_provenance(tmp_path):
    cache = TuningCache(tmp_path / "cache.json")
    t = MeasuredTunable()
    tune(t, engine="measure", cache=cache, repeats=1)
    key, _ = cache_key(t, "measure", params={"repeats": 1})
    first = dict(cache._entries[key])
    assert first["provenance"] == "measured"

    res = tune(t, engine="measure", cache=cache, repeats=1, force=True)
    assert res.stats["cache"] == "force"        # engine re-ran, overwrote
    assert t.measure_calls == 6
    second = cache._entries[key]
    assert second["provenance"] == "measured"
    assert second["created"] >= first["created"]


# ---------------------------------------------------------------------------
# @autotune
# ---------------------------------------------------------------------------

def test_autotune_decorator_tunes_then_hits_cache(tmp_path):
    cache = TuningCache(tmp_path / "cache.json")
    probe = CountingTunable()

    @autotune(lambda x, **kw: probe, params=("block",), cache=cache)
    def f(x, *, block=None):
        return x * block

    assert f(10) == 40                  # tuned: best block == 4
    n = probe.cost_calls
    assert n == 3
    assert f(7) == 28                   # second call: in-process memo
    assert probe.cost_calls == n        # fast path — engine not re-run

    assert f(10, block=2) == 20         # explicit param bypasses tuning
    assert probe.cost_calls == n

    res = f.tune(10)                    # .tune bypasses the memo ...
    assert res.best_config == {"block": 4}
    assert res.stats["cache"] == "hit"  # ... and hits the persistent cache
    assert probe.cost_calls == n
    assert cache.stats["hits"] == 1
    assert f.tuned_params == ("block",)


def test_autotune_memo_survives_unhashable_tunable():
    """An unhashable tunable (dict-holding dataclass) must skip the
    in-process memo cleanly — the lookup's TypeError used to leave
    ``memo_key`` set, so the later store crashed uncaught."""

    from dataclasses import dataclass as _dc

    @_dc                                     # eq without hash: unhashable
    class DictTunable:
        payload: dict
        name = "test.dict-tunable"

        def space(self):
            return SearchSpace(params=[Param("block", (1, 2, 4))])

        def cost(self, cfg):
            return cfg["block"]

        def fingerprint(self):
            return {"tunable": self.name, "payload": dict(self.payload)}

    with pytest.raises(TypeError):
        hash(DictTunable({"n": 1}))          # precondition of the test

    @autotune(lambda x, **kw: DictTunable({"n": x}), params=("block",),
              cache=None)
    def f(x, *, block=None):
        return x * block

    assert f(5) == 5                         # tuned: best block == 1
    assert f(5) == 5                         # memo-less second call


def test_kernel_autotune_cache_hit_fast_path():
    """matmul with omitted blocks resolves via the (session) cache; the
    second call must be a hit."""

    import jax.numpy as jnp
    import numpy as np
    a = jnp.asarray(np.ones((128, 128)), jnp.float32)
    b = jnp.asarray(np.ones((128, 128)), jnp.float32)
    r1 = mm.matmul_tuned.tune(a, b)
    r2 = mm.matmul_tuned.tune(a, b)
    assert r2.stats["cache"] == "hit"
    assert r2.best_config == r1.best_config
    got = mm.matmul_tuned(a, b)         # uses the cached blocks
    np.testing.assert_allclose(np.asarray(got), 128.0)


def test_distributed_tunable_infeasible_is_inf():
    w = workload_from_arch("llama4-maverick-400b-a17b", "train_4k")
    tb = DistributedTunable(w, chips_per_pod=256, pods=1)
    costs = [tb.cost(c) for c in tb.space()]
    assert all(c == float("inf") for c in costs)
    with pytest.raises(RuntimeError, match="fits HBM"):
        tune_distributed(w, chips_per_pod=256, pods=1)
