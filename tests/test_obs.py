"""Observability subsystem tests: trace recorder round-trip and span
nesting, metrics registry semantics, the stats_out shim parity, the
trace-event-backed workload records, and the online conformance monitor
end-to-end — a planted allocator mutant trips it mid-drain and the
dumped trail replays to a real failure through ``repro.verify``."""

import json

import jax
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.obs import (Histogram, MetricsRegistry, Observability,
                       TraceRecorder, export_trace, parse_trace,
                       spans_from_events, validate_trace)
from repro.runtime.serve import Server
from repro.runtime.tunables import timed_server_drain, timed_trace_drain
from repro.runtime.workload import (TraceConfig, drive_trace,
                                    generate_trace, records_from_events,
                                    summarize)


@pytest.fixture(scope="module")
def model():
    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    return api, api.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("serve.retired", "done")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError, match="must be >= 0"):
        c.inc(-1)
    g = reg.gauge("serve.queue_depth")
    g.set(7)
    g.inc(2)
    g.dec()
    assert g.value == 8
    # same (name, labels) returns the same instrument
    assert reg.counter("serve.retired") is c


def test_registry_kind_conflict_and_labels():
    reg = MetricsRegistry()
    reg.counter("serve.preemptions", reason="slo-preempt").inc()
    reg.counter("serve.preemptions", reason="oom-defer").inc(2)
    with pytest.raises(ValueError, match="registered as"):
        reg.gauge("serve.preemptions")
    snap = reg.snapshot()
    assert snap["counters"]['serve.preemptions{reason="oom-defer"}'] == 2
    assert snap["counters"]['serve.preemptions{reason="slo-preempt"}'] == 1


def test_histogram_log_buckets_and_quantiles():
    assert Histogram.bucket_of(0) == 0
    assert Histogram.bucket_of(1) == 0
    assert Histogram.bucket_of(2) == 1
    assert Histogram.bucket_of(3) == 2
    assert Histogram.bucket_of(1024) == 10
    h = Histogram()
    for v in (1, 1, 2, 4, 100):
        h.observe(v)
    assert h.count == 5 and h.sum == 108
    assert h.mean() == pytest.approx(108 / 5)
    # quantiles come back as bucket upper edges
    assert h.quantile(0.5) == 2
    assert h.quantile(0.99) == 128


def test_collect_prefix_and_prometheus():
    reg = MetricsRegistry()
    reg.gauge("traffic.ticks").set(42)
    reg.gauge("traffic.mean_active").set(2.5)
    reg.gauge("other.thing").set(9)
    got = reg.collect("traffic")
    assert got == {"ticks": 42.0, "mean_active": 2.5}
    reg.counter("serve.retired", "completed requests").inc(3)
    reg.histogram("serve.latency_ticks", slo="interactive").observe(5)
    text = reg.to_prometheus()
    assert "# TYPE serve_retired counter" in text
    assert "serve_retired 3" in text
    assert '# HELP serve_retired completed requests' in text
    assert 'serve_latency_ticks_bucket{slo="interactive",le="8"} 1' in text
    assert 'serve_latency_ticks_count{slo="interactive"} 1' in text


# ---------------------------------------------------------------------------
# trace recorder round-trip, nesting, validation
# ---------------------------------------------------------------------------


def _tiny_recording() -> TraceRecorder:
    rec = TraceRecorder()
    rec.begin("tick", tick=1)
    rec.begin("phase.decode", tick=1)
    rec.end("phase.decode", tick=1, slots=2)
    rec.end("tick", tick=1, decode=2)
    rec.begin("request", track=("request", 0), tick=1, slo="batch")
    rec.instant("workload.submitted", track=("request", 0), tick=1,
                rid=0, arrival=0, slo="batch", deadline=0.0)
    rec.counter("active_slots", 2, tick=1)
    rec.end("request", track=("request", 0), tick=3, tokens=4)
    return rec


def test_trace_export_parse_roundtrip(tmp_path):
    rec = _tiny_recording()
    path = tmp_path / "t.json"
    doc = export_trace(rec.events, str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk["kind"] == doc["kind"]
    assert validate_trace(doc) == []
    assert parse_trace(doc) == rec.events
    # same round-trip through the file
    assert parse_trace(on_disk) == rec.events


def test_span_pairing_and_open_span_truncation():
    rec = TraceRecorder()
    rec.begin("request", track=("request", 1), tick=2)
    rec.begin("queued", track=("request", 1), tick=2)
    rec.end("queued", track=("request", 1), tick=4)
    rec.begin("running", track=("request", 1), tick=4)
    # drain aborted mid-flight: running and request are still open
    assert rec.open_spans(("request", 1)) == ["request", "running"]
    assert rec.close_open_spans() == 2
    spans = spans_from_events(rec.events)
    (req,) = spans
    assert req.name == "request"
    assert [c.name for c in req.children] == ["queued", "running"]
    ends = [ev for ev in rec.events if ev["ph"] == "E"]
    assert all(ev["args"].get("truncated") for ev in ends[-2:])
    # innermost closes first, and closing ticks stay monotone
    assert ends[-2]["name"] == "running" and ends[-1]["name"] == "request"
    assert validate_trace(export_trace(rec.events)) == []


def test_validate_trace_flags_problems():
    rec = _tiny_recording()
    doc = export_trace(rec.events)
    assert validate_trace(doc) == []
    bad = dict(doc, kind="something-else")
    assert any("kind" in p for p in validate_trace(bad))
    # tick running backwards on a track
    rec2 = TraceRecorder()
    rec2.begin("tick", tick=5)
    rec2.end("tick", tick=5)
    rec2.begin("tick", tick=3)
    rec2.end("tick", tick=3)
    assert any("tick" in p for p in
               validate_trace(export_trace(rec2.events)))
    # unbalanced nesting
    rec3 = TraceRecorder()
    rec3.begin("a", tick=1)
    rec3.begin("b", tick=1)
    rec3.end("a", tick=1)
    assert validate_trace(export_trace(rec3.events))


def test_records_from_events_rebuilds_workload_records():
    rec = TraceRecorder()
    rec.instant("workload.submitted", track=("request", 0), tick=0,
                rid=0, arrival=2, slo="interactive", deadline=10.0)
    rec.instant("workload.retired", track=("request", 0), tick=5,
                rid=0, finish=7, tokens=4)
    rec.instant("workload.submitted", track=("request", 1), tick=1,
                rid=1, arrival=3, slo="batch", deadline=4.0)
    rec.instant("workload.retired", track=("request", 1), tick=6,
                rid=1, finish=9, tokens=2)
    records = records_from_events(rec.events)
    assert records[0] == {"arrival": 2, "slo": "interactive",
                          "deadline": 10.0, "finish": 7, "latency": 5,
                          "met": True, "tokens": 4}
    assert records[1]["met"] is False and records[1]["latency"] == 6
    s = summarize(records, ticks=9)
    assert s["requests"] == 2 and s["goodput_tokens"] == 4.0


# ---------------------------------------------------------------------------
# attached observability: parity, nesting, shim — real drains
# ---------------------------------------------------------------------------


def _outs(records):
    return {rid: tuple(rec["request"].out) for rid, rec in records.items()}


def test_traced_drain_parity_and_valid_trace(model):
    """Attaching trace+metrics+monitor changes neither the outputs nor
    the summarize numbers; the exported doc passes validation and the
    monitor accepts the allocator op stream."""

    api, params = model
    tc = TraceConfig(requests=6, arrival="bursty", burst=3, burst_every=4,
                     prompt_len=(6, 12), max_new=(3, 5), shared_frac=0.5,
                     prefix_len=8, seed=11)
    trace = generate_trace(tc)

    def drain(obs):
        srv = Server(api, params, batch=2, context=48, prefill_chunk=8,
                     paged=True, page_size=4, scheduler="prefix",
                     share_prefix=True, obs=obs)
        records = drive_trace(srv, trace)
        return srv, records

    _, plain = drain(None)
    obs = Observability(trace=True, metrics=True, monitor=True)
    srv, traced = drain(obs)
    assert _outs(traced) == _outs(plain)
    base = {k: {kk: vv for kk, vv in r.items() if kk != "request"}
            for k, r in plain.items()}
    got = {k: {kk: vv for kk, vv in r.items() if kk != "request"}
          for k, r in traced.items()}
    assert got == base
    assert summarize(traced, srv.ticks) == summarize(plain, srv.ticks)

    assert obs.monitor.accepted and obs.monitor.ops_checked > 0
    doc = obs.export()
    assert validate_trace(doc) == []
    assert doc["monitor"]["status"] == "accepted"
    # the trace events alone reproduce the workload records
    parsed = records_from_events(parse_trace(doc))
    assert parsed == base
    # every retired request nests queued -> running inside its span
    by_track = {}
    for ev in parse_trace(doc):
        by_track.setdefault(tuple(ev["track"]), []).append(ev)
    ran = 0
    for track, evs in by_track.items():
        if track[0] != "request":
            continue
        names = [sp.name for sp in spans_from_events(evs)]
        assert names == ["request"]
        kids = [c.name for c in spans_from_events(evs)[0].children]
        assert kids[0] == "queued" and "running" in kids
        ran += 1
    assert ran == 6
    # registry carried the lifecycle counters
    snap = obs.registry.snapshot()
    assert snap["counters"]["serve.retired"] == 6
    assert any(k.startswith("serve.latency_ticks")
               for k in snap["histograms"])


def test_preemption_spans_nest_queued_running_cycles(model):
    """A preempted request's track reads queued -> running ->
    queued(resumed) -> running inside one request span, and the slot
    track shows both occupancies."""

    api, params = model
    obs = Observability(trace=True, metrics=True, monitor=True)
    srv = Server(api, params, batch=1, context=48, paged=True, page_size=4,
                 prefill_chunk=8, scheduler="priority", obs=obs)
    rb = srv.submit(list(range(1, 17)), max_new=6, slo="batch")
    for _ in range(4):
        srv.tick()
    ri = srv.submit([7, 5, 3, 2], max_new=4, slo="interactive",
                    deadline=20.0)
    srv.run_until_drained()
    assert rb.preempted >= 1 and rb.done and ri.done

    doc = obs.export()
    assert validate_trace(doc) == []
    evs = [ev for ev in parse_trace(doc)
           if tuple(ev["track"]) == ("request", rb.rid)]
    (req_span,) = spans_from_events(evs)
    kids = [c.name for c in req_span.children]
    assert kids == ["queued", "running"] * (1 + rb.preempted)
    resumed = [c for c in req_span.children
               if c.name == "queued" and c.args.get("resumed")]
    assert len(resumed) == rb.preempted
    # slot 0 hosted the batch request twice and the interactive one once
    slot_spans = spans_from_events(
        [ev for ev in parse_trace(doc)
         if tuple(ev["track"]) == ("slot", 0)])
    occupants = [sp.args["rid"] for sp in slot_spans]
    assert occupants.count(rb.rid) == 1 + rb.preempted
    assert occupants.count(ri.rid) == 1
    snap = obs.registry.snapshot()
    assert snap["counters"][
        'serve.preemptions{reason="slo-preempt"}'] == rb.preempted
    assert obs.monitor.accepted


def test_timed_drain_stats_out_shim_parity(model):
    """Both drain harnesses now route stats through the metrics
    registry; the stats_out dict is rebuilt from it, so the two views
    must agree key for key."""

    api, params = model
    reg = MetricsRegistry()
    stats: dict = {}
    timed_server_drain(api, params, batch=2, context=32,
                       prompts=[[1, 2, 3], [4, 5, 6, 7]], max_new=3,
                       registry=reg, stats_out=stats, warmup=0, iters=1)
    assert stats and stats == reg.collect("serve")
    assert "ticks" in stats

    tc = TraceConfig(requests=4, prompt_len=(4, 8), max_new=(2, 3),
                     seed=5)
    reg2 = MetricsRegistry()
    stats2: dict = {}
    timed_trace_drain(api, params, generate_trace(tc), batch=2,
                      context=48, prefill_chunk=8, paged=True,
                      page_size=4, registry=reg2, stats_out=stats2,
                      warmup=0, iters=1)
    records = stats2.pop("records")
    assert len(records) == 4
    assert stats2 == reg2.collect("traffic")
    for key in ("p99_all", "slo_attainment", "goodput_per_tick",
                "prefill_chunks", "preemptions"):
        assert key in stats2


# ---------------------------------------------------------------------------
# online conformance monitor: clean pass + planted mutant end-to-end
# ---------------------------------------------------------------------------


def test_mutant_trips_monitor_and_trail_replays(model, tmp_path,
                                                capsys):
    """Planting ``release-leaks-shared`` into a live drain trips the
    online monitor mid-drain, and the dumped counterexample trail
    replays to a concrete divergence via ``python -m repro.verify
    replay`` (exit 1)."""

    from repro.verify.cli import main as verify_main
    from repro.verify.mutants import MUTANTS

    api, params = model
    obs = Observability(trace=True, metrics=True, monitor=True,
                        monitor_window=64)
    srv = Server(api, params, batch=3, context=48, prefill_chunk=8,
                 paged=True, page_size=4, kv_pages=24,
                 scheduler="prefix", share_prefix=True, obs=obs)
    srv.alloc.__class__ = MUTANTS["release-leaks-shared"]

    tc = TraceConfig(requests=10, arrival="bursty", burst=3,
                     burst_every=4, prompt_len=(6, 14), max_new=(3, 6),
                     shared_frac=1.0, prefix_len=8, seed=3)
    pending = iter(sorted(generate_trace(tc),
                          key=lambda r: (r.arrival, r.rid)))
    nxt = next(pending, None)
    clock = 0
    while (nxt is not None or srv.queue
           or any(r is not None for r in srv.slot_req)):
        while nxt is not None and nxt.arrival <= clock:
            srv.submit(list(nxt.prompt), max_new=nxt.max_new,
                       slo=nxt.slo)
            nxt = next(pending, None)
        srv.tick()
        clock += 1
        if obs.monitor.violation is not None:
            break                       # tripped mid-drain
        assert clock < 2000, "mutant never tripped the monitor"
    assert obs.monitor.violation is not None
    assert not obs.monitor.accepted
    assert obs.monitor.allocator_name == "release-leaks-shared"
    assert "divergence" in obs.monitor.violation["message"]
    # the buggy op itself made it into the recorded stream
    assert any(op[0] == "release" for op in obs.monitor.ops)

    trail = tmp_path / "trail.json"
    payload = obs.monitor.dump_trail(str(trail))
    assert payload["allocator"] == "release-leaks-shared"
    assert payload["replayable"]
    assert json.loads(trail.read_text()) == payload
    rc = verify_main(["replay", "--trail", str(trail)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "REPRODUCED" in out and "release" in out

    # the violation is stamped into the exported doc, and the offline
    # re-check agrees with the online verdict
    doc = obs.export()
    assert doc["monitor"]["status"] == "violation"
    assert any(ev["name"] == "conformance.violation"
               for ev in parse_trace(doc))


def test_obs_cli_summarize_check_export(model, tmp_path, capsys):
    """The ``python -m repro.obs`` surface: summarize prints a digest,
    check passes a clean monitored trace (including the offline
    conformance re-run), export strips to pure Chrome JSON."""

    from repro.obs.cli import main as obs_main

    api, params = model
    tc = TraceConfig(requests=4, prompt_len=(4, 8), max_new=(2, 3),
                     shared_frac=0.5, prefix_len=4, seed=7)
    obs = Observability(trace=True, metrics=True, monitor=True)
    srv = Server(api, params, batch=2, context=48, prefill_chunk=8,
                 paged=True, page_size=4, scheduler="prefix",
                 share_prefix=True, obs=obs)
    drive_trace(srv, generate_trace(tc))
    path = tmp_path / "trace.json"
    obs.export(str(path))

    assert obs_main(["summarize", str(path)]) == 0
    out = capsys.readouterr().out
    assert "workload: 4 requests" in out
    assert "monitor: accepted" in out

    assert obs_main(["check", str(path), "--json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["ok"] and report["problems"] == []
    assert report["monitor"] == "accepted"
    assert report["monitor_recheck"] == "accepted"

    chrome = tmp_path / "chrome.json"
    assert obs_main(["export", str(path), "--out", str(chrome)]) == 0
    capsys.readouterr()
    stripped = json.loads(chrome.read_text())
    assert set(stripped) == {"displayTimeUnit", "traceEvents"}
    assert stripped["traceEvents"] == json.loads(
        path.read_text())["traceEvents"]

    # a tampered monitor section fails the offline re-check
    doc = json.loads(path.read_text())
    doc["monitor"]["records"][0][2] = False
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(doc))
    assert obs_main(["check", str(bad)]) == 1
    assert "FAILED" in capsys.readouterr().out
