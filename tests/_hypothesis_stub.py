"""Graceful degradation when ``hypothesis`` is not installed.

The property-based tests use ``hypothesis`` (declared as a dev
dependency in pyproject.toml), but the suite must still *collect and
run* without it — the equivalent of a per-test
``pytest.importorskip("hypothesis")``, without sacrificing the
non-property tests in the same modules.  When the real package is
missing this exposes shims with the same surface: ``@hypothesis.given``
turns the test into a skip, ``hypothesis.settings`` becomes a no-op
decorator, and ``st.*`` strategy constructors return placeholders.
"""

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        def __getattr__(self, _name):
            def _strategy(*_a, **_k):
                return None
            return _strategy

    class _HypothesisStub:
        HealthCheck = ()

        @staticmethod
        def settings(*_a, **_k):
            def deco(fn):
                return fn
            return deco

        @staticmethod
        def given(*_a, **_k):
            def deco(_fn):
                def skipper():
                    pytest.skip("hypothesis not installed")
                skipper.__name__ = _fn.__name__
                skipper.__doc__ = _fn.__doc__
                return skipper
            return deco

    hypothesis = _HypothesisStub()
    st = _StrategyStub()

__all__ = ["hypothesis", "st", "HAVE_HYPOTHESIS"]
