"""The verify lint rules on synthetic snippets, and the gate condition:
the shipped runtime tree lints clean (waivers audited)."""

import textwrap
from pathlib import Path

from repro.verify.lint import lint_paths, lint_source

RUNTIME = Path(__file__).resolve().parent.parent / "src" / "repro" / "runtime"


def _lint(src, path="mod.py"):
    return lint_source(textwrap.dedent(src), path)


# ---------------------------------------------------------------------------
# alias-dispatch
# ---------------------------------------------------------------------------


def test_asarray_on_attribute_flagged():
    rep = _lint("""
        def f(self):
            return jnp.asarray(self.slot_pos)
    """)
    assert [f.rule for f in rep.findings] == ["alias-dispatch"]


def test_asarray_on_unproven_name_flagged():
    rep = _lint("""
        def f(self, req):
            frames = getattr(req, "_frames", None)
            return jnp.asarray(frames)
    """)
    assert [f.rule for f in rep.findings] == ["alias-dispatch"]


def test_asarray_on_fresh_np_buffer_ok():
    rep = _lint("""
        def f(self):
            tokens = np.zeros((4, 1), np.int32)
            tokens[0, 0] = 7
            return jnp.asarray(tokens)
    """)
    assert rep.findings == []


def test_asarray_on_direct_np_call_and_snapshot_ok():
    rep = _lint("""
        def f(self, a):
            x = jnp.asarray(np.array(a))
            y = jnp.asarray(_snapshot(self.slot_pos))
            return x, y
    """)
    assert rep.findings == []


def test_tainted_reassignment_flags():
    rep = _lint("""
        def f(self, view):
            buf = np.zeros(4)
            buf = view
            return jnp.asarray(buf)
    """)
    assert [f.rule for f in rep.findings] == ["alias-dispatch"]


def test_raw_host_buffer_into_dispatch_flagged():
    rep = _lint("""
        def f(self):
            out, _ = self._step(self.params, self.state,
                                self.alloc.page_table)
            return out
    """)
    assert [f.rule for f in rep.findings] == ["alias-dispatch"]


# ---------------------------------------------------------------------------
# pool-write
# ---------------------------------------------------------------------------


def test_pool_kv_write_flagged():
    rep = _lint("""
        def f(entry, new):
            entry["kv"] = new
    """)
    assert [f.rule for f in rep.findings] == ["pool-write"]


def test_other_key_writes_ok():
    rep = _lint("""
        def f(entry, new):
            entry["meta"] = new
    """)
    assert rep.findings == []


# ---------------------------------------------------------------------------
# ordered-policy (scheduler modules only)
# ---------------------------------------------------------------------------


def test_dict_iteration_in_scheduler_flagged():
    src = """
        def pick(self, server):
            for req in self.pending.values():
                if req.ready:
                    return req
    """
    assert [f.rule for f in _lint(src, "my_scheduler.py").findings] == \
        ["ordered-policy"]
    # same source outside a scheduler module: no finding
    assert _lint(src, "workload.py").findings == []


def test_minmax_key_over_dict_values_flagged():
    rep = _lint("""
        def victim(self, server):
            return max(self.slots.values(), key=lambda s: s.age)
    """, "scheduler.py")
    assert [f.rule for f in rep.findings] == ["ordered-policy"]


def test_sorted_wrap_ok():
    rep = _lint("""
        def pick(self, server):
            for k, req in sorted(self.pending.items()):
                return k
    """, "scheduler.py")
    assert rep.findings == []


# ---------------------------------------------------------------------------
# waivers
# ---------------------------------------------------------------------------


def test_waiver_with_reason_waives():
    rep = _lint("""
        def f(self):
            # verify: waive(alias-dispatch) -- audited: x is immutable
            return jnp.asarray(self.slot_pos)
    """)
    assert rep.findings == [] and len(rep.waived) == 1
    assert rep.ok


def test_waiver_block_above_finding_waives():
    rep = _lint("""
        def f(self):
            # verify: waive(alias-dispatch) -- audited: frozen at
            # submit time, never written afterwards
            return jnp.asarray(self.slot_pos)
    """)
    assert rep.findings == [] and rep.ok


def test_reasonless_waiver_rejected():
    rep = _lint("""
        def f(self):
            # verify: waive(alias-dispatch)
            return jnp.asarray(self.slot_pos)
    """)
    assert not rep.ok
    assert len(rep.findings) == 1 and len(rep.bad_waivers) == 1


def test_waiver_for_wrong_rule_does_not_waive():
    rep = _lint("""
        def f(self):
            # verify: waive(pool-write) -- wrong rule entirely
            return jnp.asarray(self.slot_pos)
    """)
    assert [f.rule for f in rep.findings] == ["alias-dispatch"]


# ---------------------------------------------------------------------------
# the gate condition
# ---------------------------------------------------------------------------


def test_runtime_tree_lints_clean():
    rep = lint_paths([RUNTIME])
    assert rep.ok, "\n".join(str(f) for f in
                             rep.findings + rep.bad_waivers)
    # the two audited waivers in serve.py stay visible, not silent
    assert len(rep.waived) >= 2
