"""Attention-path tests: the Pallas flash runtime path
(``use_flash=True``) against the pure-JAX math, chunked-prefill
position handling, and per-slot decode positions."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A
from repro.models.common import init_params


def _setup(S=128, B=2, seed=0, **cfg_overrides):
    cfg = get_config("smollm-135m").reduced()
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    p = init_params(A.attn_specs(cfg), jax.random.PRNGKey(seed))
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)) * 0.3,
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return cfg, p, x, pos


# ---------------------------------------------------------------------------
# use_flash runtime path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 32])
def test_attention_use_flash_matches_pure_jax(window):
    """The @autotune'd Pallas flash kernel (interpret mode on CPU) must
    agree with the pure-JAX math at fp32 tolerance."""

    cfg, p, x, pos = _setup(S=128)
    ref = A.attention(p, cfg, x, pos, causal=True, window=window)
    got = A.attention(p, cfg, x, pos, causal=True, window=window,
                      use_flash=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-4)


def test_attention_use_flash_non_causal():
    cfg, p, x, pos = _setup(S=128)
    ref = A.attention(p, cfg, x, pos, causal=False)
    got = A.attention(p, cfg, x, pos, causal=False, use_flash=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-4)


def test_forward_lm_threads_use_flash():
    """``cfg.use_flash`` routes whole-model self-attention through the
    flash path (the per-call-site flag threaded via the config); the
    logits must match the pure-JAX default at fp32 tolerance."""

    from repro.configs import get_config as _gc
    from repro.models import build_model
    cfg = _gc("smollm-135m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 128)), jnp.int32)
    ref = api.forward(params, {"tokens": toks})
    got = build_model(cfg.replace(use_flash=True)).forward(
        params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-4)


def test_registry_flash_default_and_smoke_fallback():
    """qwen1.5-4b opts into the flash path by default; its reduced
    smoke shapes are untileable so forward still runs (pure-JAX
    fallback per call site)."""

    from repro.configs import get_config as _gc
    from repro.models import build_model
    cfg = _gc("qwen1.5-4b")
    assert cfg.use_flash
    api = build_model(cfg.reduced())
    assert api.cfg.use_flash                     # survives reduced()
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, api.cfg.vocab, (1, 20)), jnp.int32)   # 20 % 128 != 0 -> fallback
    out = api.forward(params, {"tokens": toks})
    assert np.isfinite(np.asarray(out)).all()


def test_attention_use_flash_falls_back_on_untileable_seq():
    """S not divisible by the 128-lane block cannot go through the
    kernel; use_flash must silently take the pure-JAX path."""

    cfg, p, x, pos = _setup(S=100)
    assert not A._flash_supported(100)
    ref = A.attention(p, cfg, x, pos)
    got = A.attention(p, cfg, x, pos, use_flash=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# chunked-prefill positions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("offset", [0, 7])
@pytest.mark.parametrize("window,chunk,S", [(None, 16, 64), (24, 16, 60),
                                            (None, 32, 50)])
def test_qchunked_honors_caller_positions(offset, window, chunk, S):
    """The q-chunked path must mask with the caller's ``positions``
    (offset prefill), exactly like the un-chunked path — it used to
    assume 0-based contiguous query indices."""

    B, H, hd = 1, 2, 16
    rng = np.random.default_rng(offset * 31 + S)
    q, k, v = (jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
               for _ in range(3))
    positions = (jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
                 + offset)

    qi = positions[:, None, :, None]
    ki = positions[:, None, None, :]
    mask = ki <= qi
    if window is not None:
        mask &= ki >= qi - window + 1
    ref = A._sdpa(q, k, v, mask, hd ** -0.5)
    got = A._sdpa_qchunked(q, k, v, positions, hd ** -0.5, causal=True,
                           window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-4)


# ---------------------------------------------------------------------------
# per-slot decode positions
# ---------------------------------------------------------------------------

def test_decode_attention_per_slot_positions_match_scalar():
    """A (B,) vector of per-slot cache lengths must decode each row
    exactly as a solo scalar-position call would."""

    cfg, p, _, _ = _setup()
    B, C = 3, 16
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)) * 0.3,
                    jnp.float32)
    cache = {
        "k": jnp.asarray(rng.standard_normal(
            (B, cfg.n_kv_heads, C, cfg.hd)) * 0.3, jnp.float32),
        "v": jnp.asarray(rng.standard_normal(
            (B, cfg.n_kv_heads, C, cfg.hd)) * 0.3, jnp.float32),
    }
    cur = [5, 0, 2]
    out_vec, cache_vec = A.decode_attention(p, cfg, x, cache,
                                            jnp.asarray(cur, jnp.int32))
    for b, c in enumerate(cur):
        sliced = {k: v[b:b + 1] for k, v in cache.items()}
        out_b, cache_b = A.decode_attention(p, cfg, x[b:b + 1], sliced,
                                            jnp.int32(c))
        np.testing.assert_allclose(np.asarray(out_vec[b]),
                                   np.asarray(out_b[0]),
                                   rtol=1e-5, atol=1e-5)
        for key in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(cache_vec[key][b]),
                                          np.asarray(cache_b[key][0]))


# ---------------------------------------------------------------------------
# chunked cached prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,C", [(None, 32), (8, 8)])
def test_decode_attention_chunked_matches_sequential(window, C):
    """One chunked call over T tokens must produce the same outputs and
    final cache as T sequential decode_attention steps — including the
    SWA ring case where the chunk (12) exceeds the ring (C=8), so
    in-chunk tokens overwrite slots earlier queries still need."""

    cfg, p, _, _ = _setup()
    B, T = 2, 12
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)) * 0.3,
                    jnp.float32)
    zero = {
        "k": jnp.zeros((B, cfg.n_kv_heads, C, cfg.hd), jnp.float32),
        "v": jnp.zeros((B, cfg.n_kv_heads, C, cfg.hd), jnp.float32),
    }
    cur = jnp.asarray([0, 3], jnp.int32)        # mixed-progress slots
    lengths = jnp.asarray([T, T], jnp.int32)

    out_c, cache_c = A.decode_attention_chunked(p, cfg, x, zero, cur,
                                                lengths, window=window)

    cache_s = {k: v for k, v in zero.items()}
    outs = []
    for t in range(T):
        o, cache_s = A.decode_attention(p, cfg, x[:, t:t + 1], cache_s,
                                        cur + t, window=window)
        outs.append(o)
    out_s = jnp.concatenate(outs, axis=1)

    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               rtol=2e-5, atol=2e-5)
    for key in ("k", "v"):
        np.testing.assert_allclose(np.asarray(cache_c[key]),
                                   np.asarray(cache_s[key]),
                                   rtol=2e-5, atol=2e-5)


def test_decode_attention_chunked_length_gating():
    """Rows past a slot's chunk length are padding: they must not write
    the cache, and a zero-length slot's cache must be untouched."""

    cfg, p, _, _ = _setup()
    B, T, C = 2, 8, 16
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((B, T, cfg.d_model)) * 0.3,
                    jnp.float32)
    cache = {
        "k": jnp.asarray(rng.standard_normal(
            (B, cfg.n_kv_heads, C, cfg.hd)) * 0.3, jnp.float32),
        "v": jnp.asarray(rng.standard_normal(
            (B, cfg.n_kv_heads, C, cfg.hd)) * 0.3, jnp.float32),
    }
    cur = jnp.asarray([2, 5], jnp.int32)
    lengths = jnp.asarray([3, 0], jnp.int32)    # slot 1 inert

    out, new_cache = A.decode_attention_chunked(p, cfg, x, cache, cur,
                                                lengths)
    for key in ("k", "v"):
        got = np.asarray(new_cache[key])
        ref = np.asarray(cache[key])
        # slot 0: exactly positions 2..4 rewritten, everything else kept
        changed = np.any(got[0] != ref[0], axis=(0, 2))
        np.testing.assert_array_equal(changed.nonzero()[0], [2, 3, 4])
        # slot 1 (length 0): bit-identical cache
        np.testing.assert_array_equal(got[1], ref[1])

    # the valid prefix must equal the same tokens chunked at full length
    out3, _ = A.decode_attention_chunked(p, cfg, x[:1, :3],
                                         {k: v[:1] for k, v in cache.items()},
                                         cur[:1], jnp.asarray([3], jnp.int32))
    np.testing.assert_allclose(np.asarray(out[0, :3]), np.asarray(out3[0]),
                               rtol=2e-5, atol=2e-5)
