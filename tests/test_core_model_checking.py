"""Core model-checking auto-tuner tests: runtime semantics, explorer,
properties, bisection, swarm, sweep, counterexample validity."""

import numpy as np
import pytest

from _hypothesis_stub import hypothesis, st  # skips property tests if absent

from repro.core import (
    Counterexample, NonTermination, OverTime, PlatformSpec,
    WaveParams, build_model, explore, find_minimal_time, model_time,
    model_time_jnp, replay, swarm_search, sweep_times, trace_satisfies,
    wg_ts_space,
)
from repro.core.sweep import cex_oracle
from repro.tune import PlatformTunable, tune

settings = hypothesis.settings(max_examples=20, deadline=None,
                               suppress_health_check=list(hypothesis.HealthCheck))


def sim_time(kind, size, NP, GMT, WG, TS, L=0):
    spec = PlatformSpec(size=size, NP=NP, GMT=GMT, L=L, kind=kind,
                        fixed_WG=WG, fixed_TS=TS)
    m = build_model(spec)
    r = explore(m, NonTermination().violates, schedule="por")
    assert r.counterexample is not None, "model deadlocked"
    return r.counterexample.globals["time"]


# ---------------------------------------------------------------------------
# simulator <-> wave model equivalence (the key semantic invariant)
# ---------------------------------------------------------------------------

@settings
@hypothesis.given(
    kind=st.sampled_from(["abstract", "minimum"]),
    size_exp=st.integers(2, 4), np_exp=st.integers(1, 2),
    gmt=st.sampled_from([2, 4, 8]), wg_exp=st.integers(0, 4),
    ts_exp=st.integers(0, 4))
def test_sim_equals_wave_model(kind, size_exp, np_exp, gmt, wg_exp, ts_exp):
    size = 1 << size_exp
    WG, TS = 1 << min(wg_exp, size_exp), 1 << min(ts_exp, size_exp)
    NP = 1 << np_exp
    got = sim_time(kind, size, NP, gmt, WG, TS)
    want = model_time(WaveParams(size=size, NP=NP, GMT=gmt, kind=kind), WG, TS)
    assert got == want


def test_paper_table1_row1():
    """Paper Table 1 row 1: size=8, TS=4, WG=4, 4 PEs -> model time 44."""

    assert sim_time("abstract", 8, 4, 4, 4, 4) == 44
    assert model_time(WaveParams(size=8, NP=4, GMT=4), 4, 4) == 44


def test_interleaving_invariance_full_schedule():
    """Model time is invariant under interleavings: exhaustive DFS over
    all schedules reaches FIN only with a single time value."""

    for kind in ("abstract", "minimum"):
        spec = PlatformSpec(size=4, NP=2, GMT=2, kind=kind,
                            fixed_WG=2, fixed_TS=2)
        m = build_model(spec)
        r = explore(m, NonTermination().violates, schedule="full",
                    stop_on_first=False, collect_terminals=True,
                    keep_trails=False, max_states=2_000_000)
        assert not r.truncated
        times = {t.globals["time"] for t in r.terminals if t.globals["FIN"]}
        assert len(times) == 1


def test_no_deadlocks_small_grid():
    """Every configuration terminates (all terminals have FIN)."""

    spec = PlatformSpec(size=8, NP=4, GMT=4, kind="minimum")
    m = build_model(spec)
    r = explore(m, lambda G: False, schedule="por", stop_on_first=False,
                collect_terminals=True, keep_trails=False)
    assert r.terminals, "no terminal states found"
    assert all(t.globals["FIN"] for t in r.terminals)


# ---------------------------------------------------------------------------
# properties + counterexamples
# ---------------------------------------------------------------------------

def test_overtime_semantics():
    p = OverTime(10)
    assert p.violates({"FIN": True, "time": 10})
    assert p.violates({"FIN": True, "time": 3})
    assert not p.violates({"FIN": True, "time": 11})
    assert not p.violates({"FIN": False, "time": 3})
    assert trace_satisfies(p, [{"FIN": False, "time": 0},
                               {"FIN": True, "time": 11}])
    assert not trace_satisfies(p, [{"FIN": False, "time": 0},
                                   {"FIN": True, "time": 9}])


def test_counterexample_replay_validates():
    """Step 4: the trail must replay through the model to the same FIN
    state (SPIN trail-simulation analogue)."""

    spec = PlatformSpec(size=8, NP=4, GMT=4, kind="abstract")
    m = build_model(spec)
    r = explore(m, OverTime(44).violates, schedule="por")
    assert r.counterexample is not None
    cex = Counterexample.from_terminal(r.counterexample)
    assert cex.time <= 44
    assert cex.validate(m)
    assert set(cex.config) == {"WG", "TS"}


def test_counterexample_respects_T():
    spec = PlatformSpec(size=8, NP=4, GMT=4, kind="abstract")
    m = build_model(spec)
    # T below the minimum -> property holds, no counterexample
    r = explore(m, OverTime(43).violates, schedule="por")
    assert r.property_holds
    assert r.counterexample is None


# ---------------------------------------------------------------------------
# bisection (Fig. 1)
# ---------------------------------------------------------------------------

def test_bisection_against_known_minimum():
    wp = WaveParams(size=64, NP=4, GMT=4, kind="minimum")
    oracle = cex_oracle(wp)
    res = find_minimal_time(oracle, t_ini=10_000)
    space = wg_ts_space(64)
    truth = min(model_time(wp, c["WG"], c["TS"]) for c in space)
    assert res.t_min == truth
    assert res.witness.time == truth
    # log records a refuted query at T_min - 1 (the termination condition)
    assert any(T == res.t_min - 1 and not found
               for T, found, _ in res.log.queries) or res.t_min == 0


def test_bisection_grows_infeasible_t_ini():
    wp = WaveParams(size=16, NP=4, GMT=4, kind="abstract")
    oracle = cex_oracle(wp)
    res = find_minimal_time(oracle, t_ini=1)  # infeasible start
    space = wg_ts_space(16)
    truth = min(model_time(wp, c["WG"], c["TS"]) for c in space)
    assert res.t_min == truth


@settings
@hypothesis.given(size_exp=st.integers(2, 8), gmt=st.sampled_from([2, 4, 16]),
                  kind=st.sampled_from(["abstract", "minimum"]))
def test_bisection_property(size_exp, gmt, kind):
    wp = WaveParams(size=1 << size_exp, NP=4, GMT=gmt, kind=kind)
    oracle = cex_oracle(wp)
    res = find_minimal_time(oracle, t_ini=model_time(wp, 1, 1))
    truth = min(model_time(wp, c["WG"], c["TS"])
                for c in wg_ts_space(1 << size_exp))
    assert res.t_min == truth


# ---------------------------------------------------------------------------
# engines agree
# ---------------------------------------------------------------------------

def test_sweep_matches_exhaustive_enumeration():
    wp = WaveParams(size=256, NP=8, GMT=4, L=3, kind="minimum", NU=4)
    res = sweep_times(wp)
    space = wg_ts_space(256)
    for cfg, t in zip(space, res.times):
        assert model_time(wp, cfg["WG"], cfg["TS"]) == int(t)


def test_engines_agree_on_optimum():
    spec = PlatformSpec(size=8, NP=4, GMT=4, kind="minimum")
    tunable = PlatformTunable(spec)
    r_sweep = tune(tunable, engine="sweep", cache=None)
    r_swarm = tune(tunable, engine="swarm", cache=None, n_walks=12, seed=1)
    assert r_sweep.t_min == r_swarm.t_min
    wp = WaveParams(size=8, NP=4, GMT=4, kind="minimum")
    assert model_time(wp, **{k: r_sweep.best_config[k] for k in ("WG", "TS")}
                      ) == r_sweep.t_min


@pytest.mark.slow
def test_explorer_engine_agrees():
    spec = PlatformSpec(size=8, NP=4, GMT=4, kind="abstract")
    tunable = PlatformTunable(spec)
    r_exp = tune(tunable, engine="explorer", cache=None)
    r_sweep = tune(tunable, engine="sweep", cache=None)
    assert r_exp.t_min == r_sweep.t_min == 44


def test_swarm_counterexample_carries_config():
    spec = PlatformSpec(size=16, NP=4, GMT=4, kind="minimum")
    m = build_model(spec)
    sr = swarm_search(m, n_walks=8, seed=2)
    assert sr.best.config["WG"] >= 1 and sr.best.config["TS"] >= 1
    wp = WaveParams(size=16, NP=4, GMT=4, kind="minimum")
    assert model_time(wp, sr.best.config["WG"], sr.best.config["TS"]) \
        == sr.t_min


# ---------------------------------------------------------------------------
# jnp twin
# ---------------------------------------------------------------------------

def test_model_time_jnp_matches_scalar():
    wp = WaveParams(size=1024, NP=8, GMT=16, L=2, kind="minimum", NU=2)
    space = wg_ts_space(1024)
    arrs = space.to_arrays()
    got = np.asarray(model_time_jnp(wp, arrs["WG"], arrs["TS"]))
    for i, cfg in enumerate(space):
        want = model_time(wp, cfg["WG"], cfg["TS"])
        if want < 2**31:  # within int32 range of the default jnp dtype
            assert got[i] == want


def test_replay_rejects_bogus_trail():
    spec = PlatformSpec(size=4, NP=2, GMT=2, kind="abstract",
                        fixed_WG=2, fixed_TS=2)
    m = build_model(spec)
    with pytest.raises(ValueError):
        replay(m, ("nonexistent-transition",))


# ---------------------------------------------------------------------------
# warp scheduling extension (paper §8 future work)
# ---------------------------------------------------------------------------

def test_warp_none_equals_full_warp():
    """warp == NP (one warp) must equal the warp-free model."""

    base = WaveParams(size=256, NP=16, GMT=8, kind="minimum")
    one_warp = WaveParams(size=256, NP=16, GMT=8, kind="minimum", warp=16)
    for WG in (4, 16, 64):
        for TS in (2, 8):
            assert model_time(base, WG, TS) == model_time(one_warp, WG, TS)


def test_warp_latency_hiding_helps():
    """Smaller warps (more resident warps) hide memory latency: time is
    non-increasing as the warp size shrinks — the §8 hypothesis."""

    times = []
    for warp in (16, 8, 4, 2):
        p = WaveParams(size=1024, NP=16, GMT=16, kind="minimum", warp=warp)
        times.append(model_time(p, 16, 8))
    assert all(b <= a for a, b in zip(times, times[1:]))
    assert times[-1] < times[0]


def test_warp_sweep_matches_scalar():
    from repro.core.sweep import sweep_times
    p = WaveParams(size=512, NP=16, GMT=16, kind="minimum", warp=4)
    res = sweep_times(p)
    for cfg, t in zip(wg_ts_space(512), res.times):
        assert model_time(p, cfg["WG"], cfg["TS"]) == int(t)


def test_branch_and_bound_engine():
    """Ruys-style B&B ([11], the paper's cited future work) finds the
    same optimum in one verification run, exploring fewer states than
    the collect-all engine."""

    for size, kind in [(8, "abstract"), (16, "minimum")]:
        spec = PlatformSpec(size=size, NP=4, GMT=4, kind=kind)
        tunable = PlatformTunable(spec)
        rb = tune(tunable, engine="bnb", cache=None)
        rs = tune(tunable, engine="sweep", cache=None)
        assert rb.t_min == rs.t_min
        assert rb.witness.validate(build_model(spec))
