"""Speculative-decoding subsystem tests: drafter units, greedy parity
across architectures (contiguous + paged, staggered admissions), paged
rollback invariants under partial acceptance, depth caps, telemetry,
and the ``serve.spec_depth`` tunable's plan/cache integration.

Parity tests run float32 params: the Server mirrors the params' dtype
into its KV cache, and float32 keeps real logit gaps between the
chunk-shaped verify/commit reductions and the one-token baseline (at
bfloat16 a random reduced model produces exact logit ties, which flip
on schedule-dependent ulp noise — see the serve module docstring)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.runtime.serve import Server, timed_server_drain
from repro.runtime.speculate import (DraftModelDrafter, Drafter,
                                     NGramDrafter, SpecDepthTunable,
                                     make_drafter, spec_depth_tunable)


def f32_model(arch="smollm-135m", **extra):
    cfg = get_config(arch).reduced().replace(logits_dtype="float32", **extra)
    api = build_model(cfg)
    params = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32),
                                    api.init(jax.random.PRNGKey(0)))
    return api, params


def cycled_prompts(vocab, n, length, period=4):
    return [[(r + i % period) % (vocab - 1) + 1 for i in range(length)]
            for r in range(n)]


# ---------------------------------------------------------------------------
# drafter units
# ---------------------------------------------------------------------------


def test_ngram_drafter_longest_most_recent_match():
    d = NGramDrafter()
    # suffix [7, 8] occurred earlier; propose its continuation
    assert d.propose([1, 7, 8, 9, 5, 7, 8], 3) == [9, 5, 7]
    # the MOST RECENT occurrence wins over an older one
    assert d.propose([7, 8, 1, 7, 8, 2, 7, 8], 1) == [2]
    # no match, nothing proposed
    assert d.propose([1, 2, 3, 4], 3) == []
    assert d.propose([1, 2], 0) == []
    assert d.propose([5], 4) == []


def test_ngram_drafter_caps_at_depth():
    d = NGramDrafter()
    out = d.propose([1, 2, 3, 4, 5, 1, 2, 3], 2)
    assert out == [4, 5]


def test_draft_model_drafter_matches_target_greedy():
    """Self-draft (draft model == target) proposes exactly the target's
    greedy continuation — the 100%-acceptance reference."""

    api, params = f32_model()
    d = DraftModelDrafter(api, params, bucket=8)
    prompt = cycled_prompts(api.cfg.vocab, 1, 6)[0]
    out = d.propose(prompt, 3)
    assert len(out) == 3
    # cross-check token 1 against a direct full forward
    buf = np.zeros((1, 8), np.int32)
    buf[0, :6] = prompt
    logits = api.forward(params, {"tokens": jnp.asarray(buf)})
    assert out[0] == int(jnp.argmax(logits[0, 5]))


def test_make_drafter_resolution_and_errors():
    assert isinstance(make_drafter("ngram"), NGramDrafter)
    d = NGramDrafter()
    assert make_drafter(d) is d
    assert isinstance(d, Drafter)
    with pytest.raises(ValueError, match="needs api=/params="):
        make_drafter("draft")
    with pytest.raises(ValueError, match="unknown drafter"):
        make_drafter("telepathy")
    with pytest.raises(TypeError, match="not a Drafter"):
        make_drafter(42)  # type: ignore[arg-type]


# ---------------------------------------------------------------------------
# greedy parity: speculation is a schedule change, never a text change
# ---------------------------------------------------------------------------


def _drain_outs(api, params, prompts, *, max_new, staggered=True, **kw):
    srv = Server(api, params, batch=2, context=32, prefill_chunk=4, **kw)
    reqs = [srv.submit(prompts[0], max_new=max_new)]
    if staggered:
        for _ in range(2):
            srv.tick()           # first request mid-prefill when rest land
    for p in prompts[1:]:
        reqs.append(srv.submit(p, max_new=max_new))
    srv.run_until_drained()
    assert all(r.done for r in reqs)
    return [r.out for r in reqs], srv


@pytest.mark.parametrize("arch,extra", [
    ("smollm-135m", {}),                       # dense GQA
    ("qwen1.5-4b", {}),                        # dense MHA + qkv bias
    ("smollm-135m", {"window": 8}),            # sliding-window ring
    ("hymba-1.5b", {}),                        # hybrid attn + SSM state
])
@pytest.mark.parametrize("paged", [False, True])
def test_speculative_parity_staggered(arch, extra, paged):
    """n-gram and self-draft speculation reproduce baseline greedy
    decode token-for-token under staggered admissions, contiguous and
    paged, across attention families (partial-acceptance commits must
    keep SSM recurrences and SWA rings exact too)."""

    api, params = f32_model(arch, **extra)
    prompts = cycled_prompts(api.cfg.vocab, 3, 8)
    pk = dict(paged=True, page_size=8) if paged else {}
    base, _ = _drain_outs(api, params, prompts, max_new=6, **pk)
    for speculate in ("ngram", "draft"):
        outs, srv = _drain_outs(api, params, prompts, max_new=6,
                                speculate=speculate, spec_depth=3, **pk)
        assert outs == base, f"{speculate} diverged from baseline"
        st = srv.stats()
        assert st["tokens_generated"] == sum(len(o) for o in base)
        if speculate == "draft":
            # self-draft acceptance is exact -> strictly fewer ticks
            assert st["accept_rate"] == 1.0
            assert st["ticks"] < 3 * 6


def test_snapshot_survives_host_mutation():
    """``_snapshot`` must hand jax a buffer the engine can never touch
    again.  The raw ``jnp.asarray`` of a small aligned numpy array
    zero-copy-aliases it on the CPU backend, so later in-place host
    writes leak into whatever async dispatch holds the alias."""
    from repro.runtime.serve import _snapshot

    a = np.arange(4, dtype=np.int32)
    snap = _snapshot(a)
    a[:] = -7
    assert np.asarray(snap).tolist() == [0, 1, 2, 3]


@pytest.mark.parametrize("paged", [False, True])
def test_dispatch_args_immune_to_host_buffer_mutation(paged):
    """Engine dispatches must see device SNAPSHOTS of the persistent
    host arrays (``slot_pos``, ``page_table``).  ``jnp.asarray``
    zero-copy-aliases small aligned numpy arrays on the CPU backend,
    and dispatches are asynchronous — before the ``_snapshot`` fix an
    in-flight speculation commit (whose logits nothing syncs on) could
    observe the ``slot_pos[s] += e`` made three lines below its
    dispatch and scatter the committed tokens one chunk too far,
    leaving the true rows holding the slot's PREVIOUS occupant's KV.
    The window only opens under CPU load, so simulate the host winning
    the race deterministically: corrupt the live host buffers while
    every jitted step executes, restore them after — parity with the
    baseline drain must survive."""
    api, params = f32_model()
    prompts = cycled_prompts(api.cfg.vocab, 4, 8)
    pk = dict(paged=True, page_size=8) if paged else {}
    base, _ = _drain_outs(api, params, prompts, max_new=6, staggered=False,
                          **pk)

    srv = Server(api, params, batch=2, context=32, prefill_chunk=4,
                 speculate="ngram", spec_depth=3, **pk)

    def racy(step):
        def run(*a):
            out = step(*a)
            # host gets ahead of the in-flight dispatch: corrupt the
            # live buffers, force the execution to finish inside the
            # corrupted window, then restore for the engine's own
            # bookkeeping
            srv.slot_pos += 1
            if paged:
                srv.alloc.page_table += 1
            try:
                jax.block_until_ready(out)
            finally:
                srv.slot_pos -= 1
                if paged:
                    srv.alloc.page_table -= 1
            return out
        return run

    srv._step = racy(srv._step)
    srv._verify_step = racy(srv._verify_step)
    srv._prefill_step = racy(srv._prefill_step)
    reqs = [srv.submit(p, max_new=6) for p in prompts]
    srv.run_until_drained()
    assert all(r.done for r in reqs)
    assert [r.out for r in reqs] == base


def test_self_draft_hits_depth_plus_one_tokens_per_tick():
    api, params = f32_model()
    prompts = cycled_prompts(api.cfg.vocab, 2, 8)
    base, bsrv = _drain_outs(api, params, prompts, max_new=8,
                             staggered=False)
    outs, srv = _drain_outs(api, params, prompts, max_new=8,
                            staggered=False, speculate="draft",
                            spec_depth=4)
    assert outs == base
    st, bst = srv.stats(), bsrv.stats()
    assert st["ticks"] < bst["ticks"]
    assert st["ticks_per_token"] < bst["ticks_per_token"]


# ---------------------------------------------------------------------------
# rollback invariants: rejected drafts leave no trace
# ---------------------------------------------------------------------------


class CorruptTailDrafter:
    """Self-draft with the tail corrupted from index ``split`` on:
    accepts exactly ``split`` tokens per verify, forcing the rejection/
    rollback path every single spec tick."""

    name = "corrupt-tail"

    def __init__(self, api, params, split=1):
        self.inner = DraftModelDrafter(api, params, bucket=8)
        self.split = split

    def propose(self, tokens, depth):
        out = self.inner.propose(tokens, depth)
        vocab = self.inner.api.cfg.vocab
        return [t if i < self.split else (t + 1) % vocab
                for i, t in enumerate(out)]


def test_partial_acceptance_parity_and_counters():
    api, params = f32_model()
    prompts = cycled_prompts(api.cfg.vocab, 2, 8)
    base, _ = _drain_outs(api, params, prompts, max_new=6, staggered=False)
    drafter = CorruptTailDrafter(api, params, split=1)
    outs, srv = _drain_outs(api, params, prompts, max_new=6,
                            staggered=False, speculate=drafter,
                            spec_depth=3)
    assert outs == base
    st = srv.stats()
    assert st["spec_proposed"] > 0
    assert 0 < st["spec_accepted"] < st["spec_proposed"]


def test_paged_rollback_page_table_matches_never_speculated_drain():
    """Pages grabbed for rejected draft positions are handed back the
    same tick: after the drain the allocator's free count and page
    tables are byte-identical to a drain that never speculated."""

    api, params = f32_model()
    prompts = cycled_prompts(api.cfg.vocab, 2, 8)
    pk = dict(paged=True, page_size=4)
    base, bsrv = _drain_outs(api, params, prompts, max_new=6,
                             staggered=False, **pk)
    drafter = CorruptTailDrafter(api, params, split=1)
    outs, srv = _drain_outs(api, params, prompts, max_new=6,
                            staggered=False, speculate=drafter,
                            spec_depth=3, **pk)
    assert outs == base
    assert srv.alloc.free_pages == bsrv.alloc.free_pages
    assert np.array_equal(srv.alloc.page_table, bsrv.alloc.page_table)
    assert srv.alloc.used_pages == 0        # everything retired + released


def test_spec_never_overshoots_max_new_or_context():
    """Depth caps: a deep draft near a request's max_new (or the context
    edge) is clipped so the request stops at exactly the baseline
    stopping point."""

    api, params = f32_model()
    prompts = cycled_prompts(api.cfg.vocab, 2, 8)
    base, _ = _drain_outs(api, params, prompts, max_new=3, staggered=False)
    outs, srv = _drain_outs(api, params, prompts, max_new=3,
                            staggered=False, speculate="draft",
                            spec_depth=8)
    assert outs == base
    assert all(len(o) == 3 for o in outs)


# ---------------------------------------------------------------------------
# serve.spec_depth tunable
# ---------------------------------------------------------------------------


def test_spec_depth_tunable_space_and_cost_shape():
    tb = SpecDepthTunable(param_bytes=1 << 22, layers=2, d_model=64,
                          kv_width=32, context=64, prompt_len=8,
                          requests=8, mean_new=16, batch=4, max_depth=8)
    cfgs = list(tb.space())
    assert sorted({c["depth"] for c in cfgs}) == [1, 2, 4, 8]
    assert {c["drafter"] for c in cfgs} == {"ngram", "draft"}
    # the geometric acceptance series: deeper drafts yield more tokens
    # per tick, saturating with the acceptance rate
    t1 = tb.tokens_per_tick({"depth": 1, "drafter": "draft"})
    t8 = tb.tokens_per_tick({"depth": 8, "drafter": "draft"})
    assert 1.0 < t1 < t8 < 1.0 + 0.8 / 0.2 + 1e-9
    # modeled drain cost is finite and positive everywhere
    assert all(tb.cost(c) > 0 for c in cfgs)
    fp = tb.fingerprint()
    assert fp["tunable"] == "serve.spec_depth" and fp["unit"] == "us"
    assert fp["drafters"] == ["ngram", "draft"]
    assert "api" not in fp and "params" not in fp


def test_spec_depth_tunable_rejects_unknown_drafter():
    with pytest.raises(ValueError, match="drafters must be drawn"):
        SpecDepthTunable(param_bytes=1 << 20, layers=2, d_model=64,
                         kv_width=32, context=32, prompt_len=4,
                         requests=2, mean_new=2, drafters=("oracle",))


def test_spec_depth_measure_fills_last_stats():
    api, params = f32_model()
    tb = spec_depth_tunable(api, context=32, prompt_len=6, requests=2,
                            max_new=3, batch=2, params=params)
    t = tb.measure({"depth": 2, "drafter": "draft"})
    assert t > 0
    st = tb.last_stats
    assert st["spec_proposed"] > 0 and st["accept_rate"] == 1.0


def test_spec_depth_plan_roundtrip_zero_engine_runs(tmp_path):
    """``serve.spec_depth`` resolves from a warmed cache through a
    pure-JSON plan spec with ZERO engine runs."""

    from repro.tune import TuningCache, TuningPlan, tune

    api, params = f32_model()
    cfg = api.cfg
    cache = TuningCache(tmp_path / "c.json")
    tb = spec_depth_tunable(api, context=32, prompt_len=6, requests=2,
                            max_new=3, batch=2, params=params)
    res = tune(tb, engine="grid", cache=cache)

    spec = {"name": "spec-warmup", "jobs": [
        {"tunable": "serve.spec_depth",
         "params": {"param_bytes": api.param_count() * 2,
                    "layers": cfg.n_layers, "d_model": cfg.d_model,
                    "kv_width": cfg.n_kv_heads * cfg.hd, "context": 32,
                    "prompt_len": 6, "requests": 2, "mean_new": 3,
                    "batch": 2},
         "engine": "grid"}]}
    report = TuningPlan.from_spec(spec).run(cache=cache)
    assert report.ok and report.results[0].status == "hit"
    assert report.results[0].best_config == dict(res.best_config)


def test_timed_server_drain_stats_out():
    api, params = f32_model()
    prompts = cycled_prompts(api.cfg.vocab, 2, 6)
    stats: dict = {}
    t = timed_server_drain(api, params, batch=2, context=32,
                           prompts=prompts, max_new=3, speculate="ngram",
                           spec_depth=2, warmup=0, iters=1,
                           stats_out=stats)
    assert t > 0
    assert stats["ticks"] > 0
    assert stats["tokens_generated"] == 2 * 3
    assert "accept_rate" in stats and "spec_ticks" in stats
