"""Platform calibration subsystem: fits, artifact round trip, resolver,
cache-fingerprint keying, cost-model repricing, trajectory, CLI, and the
TuningPlan calibration gate."""

import json

import pytest

from repro.calibrate import (DEFAULT_SPEC, CalibrationError, PlatformSpec,
                             calibration_hash, device_fingerprint,
                             ensure_calibrated, fit_bandwidth,
                             fit_dispatch_us, fit_link_bw, fit_peak_flops,
                             gap_from_stats, get_platform_spec, load_spec,
                             load_trajectory, run_trajectory,
                             set_platform_spec)
from repro.calibrate.cli import main as cli_main
from repro.calibrate.spec import SPEC_KIND, calibrated_replace
from repro.calibrate.trajectory import append_run


@pytest.fixture
def restore_spec():
    """Restore the session's pinned spec after a test that installs its
    own (set_platform_spec or ensure_calibrated(install=True))."""

    prev = get_platform_spec()
    yield
    set_platform_spec(prev)


def cpu_like() -> PlatformSpec:
    """A synthetic calibrated spec with CPU-magnitude constants."""

    return calibrated_replace(DEFAULT_SPEC, peak_flops=150e9, hbm_bw=20e9,
                              dispatch_us=80.0, backend="cpu",
                              device_kind="cpu")


# -- fits: pure + deterministic on synthetic sweeps -------------------------


def test_fit_peak_flops_takes_best_rung():
    sweep = [{"n": 128, "flops": 4e6, "us": 100.0},    # 4e10 FLOP/s
             {"n": 256, "flops": 32e6, "us": 200.0}]   # 1.6e11 FLOP/s
    assert fit_peak_flops(sweep) == pytest.approx(1.6e11)
    assert fit_peak_flops(list(reversed(sweep))) == pytest.approx(1.6e11)


def test_fit_bandwidth_reads_largest_footprint_not_cache():
    # the small (cache-resident) point is FASTER per byte; the fit must
    # report the main-memory point anyway
    sweep = [{"footprint": 1e6, "bytes": 3e6, "us": 10.0},     # 3e11 B/s
             {"footprint": 64e6, "bytes": 192e6, "us": 2000.0}]  # 9.6e10
    assert fit_bandwidth(sweep) == pytest.approx(9.6e10)


def test_fit_dispatch_is_median():
    assert fit_dispatch_us([9.0, 3.0, 5.0]) == 5.0


def test_fit_link_bw_single_device_is_none():
    assert fit_link_bw([]) is None


def test_empty_sweeps_raise():
    with pytest.raises(CalibrationError):
        fit_peak_flops([])
    with pytest.raises(CalibrationError):
        fit_bandwidth([])
    with pytest.raises(CalibrationError):
        fit_dispatch_us([])


# -- PlatformSpec artifact: round trip, staleness, hashes -------------------


def test_spec_json_round_trip(tmp_path):
    spec = cpu_like()
    path = spec.save(tmp_path / "spec.json")
    loaded = load_spec(path)
    assert loaded == spec
    assert loaded.calibration_hash() == spec.calibration_hash()


def test_stale_schema_rejected(tmp_path):
    doc = cpu_like().to_json()
    doc["schema"] = 0
    p = tmp_path / "stale.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(CalibrationError, match="stale"):
        load_spec(p)


def test_foreign_kind_rejected(tmp_path):
    p = tmp_path / "foreign.json"
    p.write_text(json.dumps({"schema": 1, "entries": {}}))
    with pytest.raises(CalibrationError, match="not a platform-spec"):
        load_spec(p)


def test_missing_artifact_is_oserror(tmp_path):
    with pytest.raises(OSError):
        load_spec(tmp_path / "nope.json")


def test_default_hash_is_literal_default():
    assert DEFAULT_SPEC.calibration_hash() == "default"
    assert cpu_like().calibration_hash() != "default"


def test_derived_properties():
    assert DEFAULT_SPEC.ici_bw == DEFAULT_SPEC.links * DEFAULT_SPEC.link_bw
    assert DEFAULT_SPEC.dispatch_s == pytest.approx(50e-6)


# -- resolver ----------------------------------------------------------------


def test_override_wins(restore_spec):
    spec = cpu_like()
    set_platform_spec(spec)
    assert get_platform_spec() is spec


def test_disk_artifact_resolves_when_device_matches(
        restore_spec, tmp_path, monkeypatch):
    dev = device_fingerprint()
    spec = calibrated_replace(DEFAULT_SPEC, peak_flops=1e11,
                              backend=dev["backend"],
                              device_kind=dev["device_kind"])
    spec.save(tmp_path / "spec.json")
    monkeypatch.setenv("REPRO_PLATFORM_SPEC", str(tmp_path / "spec.json"))
    set_platform_spec(None)            # re-enable disk resolution
    assert get_platform_spec().calibration_hash() == spec.calibration_hash()


def test_foreign_device_artifact_falls_back_to_default(
        restore_spec, tmp_path, monkeypatch):
    spec = calibrated_replace(DEFAULT_SPEC, peak_flops=1e11,
                              backend="not-a-backend",
                              device_kind="not-a-device")
    spec.save(tmp_path / "spec.json")
    monkeypatch.setenv("REPRO_PLATFORM_SPEC", str(tmp_path / "spec.json"))
    set_platform_spec(None)
    assert get_platform_spec() is DEFAULT_SPEC


def test_no_artifact_falls_back_to_default(
        restore_spec, tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_PLATFORM_SPEC", str(tmp_path / "none.json"))
    set_platform_spec(None)
    assert get_platform_spec() is DEFAULT_SPEC


# -- tuning-cache keying: calibrated never collides with default ------------


def test_cache_keys_differ_default_vs_calibrated(restore_spec):
    from repro.kernels.tuned_reduction.ops import ReductionTunable
    from repro.tune.cache import cache_key, platform_fingerprint

    tb = ReductionTunable(1024)
    set_platform_spec(DEFAULT_SPEC)
    k_default, doc_default = cache_key(tb, "grid")
    assert platform_fingerprint()["calibration"] == "default"

    set_platform_spec(cpu_like())
    k_cal, doc_cal = cache_key(tb, "grid")
    assert doc_cal["platform"]["calibration"] == \
        cpu_like().calibration_hash()
    assert k_default != k_cal


# -- cost-model repricing under a calibrated spec ---------------------------


def test_spec_depth_ranking_flips_under_cpu_constants(restore_spec):
    from repro.runtime.speculate import SpecDepthTunable
    tb = SpecDepthTunable(param_bytes=2_000_000_000, layers=24,
                          d_model=2048, kv_width=256, context=2048,
                          prompt_len=128, requests=32, mean_new=128,
                          batch=8, max_depth=8, drafters=("ngram",))
    set_platform_spec(DEFAULT_SPEC)
    pick_default = min(tb.space(), key=tb.cost)
    set_platform_spec(cpu_like())
    pick_cpu = min(tb.space(), key=tb.cost)
    # on v5e constants deep speculation pays; on CPU-magnitude
    # constants the extra verify FLOPs dominate and depth collapses
    assert pick_default["depth"] > pick_cpu["depth"]


def test_step_time_scales_with_spec():
    from repro.core.tpu_machine import TPUConfig, TPUWorkload, step_time
    w = TPUWorkload(params=10**9, active_params=10**9, layers=24,
                    d_model=2048, seq=1024, global_batch=64, vocab=32000)
    c = TPUConfig(dp=4, tp=2)
    fast = step_time(w, c, spec=DEFAULT_SPEC)
    slow = step_time(w, c, spec=cpu_like())
    assert slow["total"] > fast["total"]
    assert slow["compute"] == pytest.approx(
        fast["compute"] * DEFAULT_SPEC.peak_flops / cpu_like().peak_flops)


def test_gmt_from_spec_bridges_to_wave_model():
    from repro.core.wave_model import WaveParams, gmt_from_spec
    g_default = gmt_from_spec(DEFAULT_SPEC)
    assert g_default == round(DEFAULT_SPEC.peak_flops * 4
                              / DEFAULT_SPEC.hbm_bw)
    g_cpu = gmt_from_spec(cpu_like())
    assert g_cpu < g_default
    p = WaveParams.from_platform(64, spec=cpu_like())
    assert p.GMT == g_cpu


def test_roofline_analyze_with_spec():
    from repro.launch.roofline import analyze
    rec = {"arch": "smollm-135m", "shape": "train_4k", "mesh": "1x1",
           "status": "ok", "n_devices": 1,
           "cost": {"flops": 1e12, "bytes_accessed": 1e9},
           "collectives": {"total_bytes": 0}}
    fast = analyze(rec, spec=DEFAULT_SPEC)
    slow = analyze(rec, spec=cpu_like())
    assert slow.compute_s > fast.compute_s
    assert slow.memory_s > fast.memory_s


# -- ensure_calibrated: load-or-probe ---------------------------------------


TINY_PROBES = dict(matmul_sizes=(16,), footprints=(1 << 14,),
                   dispatch_reps=2)


def test_ensure_calibrated_probes_then_loads(restore_spec, tmp_path):
    path = tmp_path / "spec.json"
    spec1, probed1 = ensure_calibrated(path, **TINY_PROBES)
    assert probed1 and spec1.source == "calibrated"
    assert path.exists()
    # second call: pure artifact load, zero probes
    spec2, probed2 = ensure_calibrated(path, **TINY_PROBES)
    assert not probed2
    assert spec2.calibration_hash() == spec1.calibration_hash()
    # the loaded spec became the active one
    assert get_platform_spec().calibration_hash() == \
        spec1.calibration_hash()
    # fitted constants actually differ from the v5e datasheet
    assert spec1.peak_flops != DEFAULT_SPEC.peak_flops
    assert spec1.hbm_bw != DEFAULT_SPEC.hbm_bw


def test_ensure_calibrated_force_reprobes(restore_spec, tmp_path):
    path = tmp_path / "spec.json"
    ensure_calibrated(path, **TINY_PROBES)
    _, probed = ensure_calibrated(path, force=True, **TINY_PROBES)
    assert probed


# -- trajectory ---------------------------------------------------------------


def synthetic_stats(modeled_cfg, measured_cfg, model_us, best_us):
    return {"modeled_pick": {"config": modeled_cfg, "modeled": 1.0,
                             "measured": model_us},
            "measured_pick": {"config": measured_cfg, "modeled": 2.0,
                              "measured": best_us},
            "candidates": [{}, {}]}


def test_gap_from_stats():
    rec = gap_from_stats(synthetic_stats({"b": 1}, {"b": 2}, 150.0, 100.0))
    assert rec["gap"] == pytest.approx(1.5)
    assert not rec["agree"]
    agree = gap_from_stats(synthetic_stats({"b": 1}, {"b": 1}, 100.0, 100.0))
    assert agree["agree"] and agree["gap"] == 1.0


def test_gap_needs_measure_stats():
    with pytest.raises(CalibrationError):
        gap_from_stats({"evaluated": 3})


def test_append_run_accumulates(tmp_path):
    path = tmp_path / "BENCH_calibration.json"
    append_run([{"tunable": "a", "gap": 1.0}], path=path)
    append_run([{"tunable": "b", "gap": 1.2}], path=path)
    doc = load_trajectory(path)
    assert len(doc["runs"]) == 2
    assert doc["runs"][0]["tunables"][0]["tunable"] == "a"
    assert doc["runs"][1]["tunables"][0]["tunable"] == "b"
    assert doc["runs"][0]["calibration"] == "default"   # session pin


def test_trajectory_refuses_foreign_file(tmp_path):
    p = tmp_path / "BENCH_calibration.json"
    p.write_text(json.dumps({"kind": "something-else"}))
    with pytest.raises(CalibrationError):
        load_trajectory(p)


def test_run_trajectory_real_measure(tmp_path):
    from repro.kernels.tuned_reduction.ops import ReductionTunable
    path = tmp_path / "BENCH_calibration.json"
    run = run_trajectory([("reduce_4k", ReductionTunable(4096))],
                         path=path, top_k=1, repeats=1)
    assert run["tunables"][0]["tunable"] == "reduce_4k"
    assert run["tunables"][0]["gap"] >= 1.0
    assert len(load_trajectory(path)["runs"]) == 1


# -- CLI ----------------------------------------------------------------------


def test_cli_run_twice_is_pure_load(restore_spec, tmp_path, capsys,
                                    monkeypatch):
    path = tmp_path / "spec.json"
    monkeypatch.setattr(
        "repro.calibrate.probes.run_calibration",
        lambda quick=False, **kw: calibrated_replace(
            DEFAULT_SPEC, peak_flops=1e11, probes={"matmul": [1]},
            **device_fingerprint()))
    assert cli_main(["--spec", str(path), "run", "--json"]) == 0
    first = json.loads(capsys.readouterr().out)
    assert first["status"] == "calibrated"
    assert cli_main(["--spec", str(path), "run", "--json"]) == 0
    second = json.loads(capsys.readouterr().out)
    assert second["status"] == "loaded"
    assert second["probes_run"] == 0
    assert second["calibration"] == first["calibration"]


def test_cli_show_and_export(restore_spec, tmp_path, capsys):
    path = tmp_path / "spec.json"
    dev = device_fingerprint()
    calibrated_replace(DEFAULT_SPEC, peak_flops=1e11, **dev).save(path)
    assert cli_main(["--spec", str(path), "show", "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["source"] == "calibrated"
    out = tmp_path / "exported.json"
    assert cli_main(["--spec", str(path), "export", str(out)]) == 0
    assert load_spec(out).calibration_hash() == doc["calibration"]


def test_cli_errors_are_exit_code_1(tmp_path, capsys):
    assert cli_main(["--spec", str(tmp_path / "no.json"),
                     "export", str(tmp_path / "out.json")]) == 1
    assert "error" in capsys.readouterr().err


# -- TuningPlan calibration gate --------------------------------------------


def test_plan_calibrate_gate_uses_artifact(restore_spec, tmp_path,
                                           monkeypatch):
    from repro.tune import TuningCache, TuningPlan

    dev = device_fingerprint()
    spec = calibrated_replace(DEFAULT_SPEC, peak_flops=1e11, hbm_bw=1e10,
                              **dev)
    spec.save(tmp_path / "spec.json")
    monkeypatch.setenv("REPRO_PLATFORM_SPEC", str(tmp_path / "spec.json"))

    plan = TuningPlan.from_spec({
        "name": "cal-gate", "calibrate": True,
        "jobs": [{"tunable": "kernels.tuned_reduction",
                  "params": {"n": 4096}, "engine": "grid"}]})
    assert plan.require_calibration

    lines: list[str] = []
    cache = TuningCache(tmp_path / "cache.json")
    report = plan.run(cache=cache, progress=lines.append, save=False)
    assert report.ok
    # the gate loaded the artifact (no probes) and installed it before
    # any job: the cached entry is keyed under the calibrated hash
    assert any("loaded" in ln for ln in lines)
    assert get_platform_spec().calibration_hash() == spec.calibration_hash()
    entry = next(iter(cache.entries.values()))
    assert entry["fingerprint"]["platform"]["calibration"] == \
        spec.calibration_hash()


def test_plan_without_calibrate_key_stays_ungated():
    from repro.tune import TuningPlan
    plan = TuningPlan.from_spec({"name": "plain", "jobs": []})
    assert not plan.require_calibration
