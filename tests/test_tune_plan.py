"""``repro.tune`` v2 tests: declarative TuningPlan runner, cache
artifacts (export/merge/prune), the meta engine-kwarg tunable, the
``python -m repro.tune`` CLI, and the fleet-rollout end-to-end slice."""

import json
import time

import pytest

from repro.core.search_space import Param, SearchSpace
from repro.tune import (ArtifactError, MetaEngineTunable, TuningCache,
                        TuningPlan, build_tunable, cache_key,
                        set_default_cache, tune)
from repro.tune.artifact import ARTIFACT_KIND, ARTIFACT_SCHEMA
from repro.tune.cli import main as cli_main


class CountingTunable:
    name = "test.counting"

    def __init__(self, ident="a"):
        self.ident = ident
        self.cost_calls = 0

    def space(self):
        return SearchSpace(params=[Param("block", (1, 2, 4))])

    def cost(self, cfg):
        self.cost_calls += 1
        return 10 // cfg["block"]

    def fingerprint(self):
        return {"tunable": self.name, "ident": self.ident}


class MeasuredTunable(CountingTunable):
    """cost ranks block=4 best; wall-clock says block=2 (measured
    1 + |block - 2|, floored at 1 so the meta search-effort penalty
    stays discriminating)."""

    def __init__(self, ident="a"):
        super().__init__(ident)
        self.measure_calls = 0

    def measure(self, cfg):
        self.measure_calls += 1
        return 1.0 + abs(cfg["block"] - 2)


# ---------------------------------------------------------------------------
# TuningPlan runner
# ---------------------------------------------------------------------------


def test_plan_skip_on_hit_and_force(tmp_path):
    cache = TuningCache(tmp_path / "cache.json")
    t = CountingTunable()
    plan = TuningPlan(name="p")
    plan.add(t, engine="grid")

    r1 = plan.run(cache=cache)
    assert r1.counts == {"jobs": 1, "hits": 0, "tuned": 1, "forced": 0,
                         "failed": 0}
    n = t.cost_calls

    r2 = plan.run(cache=cache)                  # skip-on-hit
    assert r2.counts["hits"] == 1 and t.cost_calls == n
    assert r2.results[0].best_config == r1.results[0].best_config

    r3 = plan.run(cache=cache, force=True)      # force override re-tunes
    assert r3.counts["forced"] == 1 and t.cost_calls == 2 * n


def test_plan_per_job_failure_isolation(tmp_path):
    """One bad job (factory raises) must not sink the plan."""

    cache = TuningCache(tmp_path / "cache.json")

    def bad_factory():
        raise RuntimeError("boom at build time")

    plan = TuningPlan(name="p")
    plan.add(bad_factory, engine="grid", label="bad")
    plan.add(CountingTunable(), engine="grid")
    report = plan.run(cache=cache)
    assert report.counts["failed"] == 1 and report.counts["tuned"] == 1
    assert not report.ok
    bad, good = report.results
    assert bad.status == "failed" and "boom" in bad.error
    assert good.status == "tuned" and good.best_config == {"block": 4}


def test_plan_run_flushes_cache(tmp_path):
    path = tmp_path / "cache.json"
    cache = TuningCache(path)
    plan = TuningPlan(name="p")
    plan.add(CountingTunable(), engine="grid")
    plan.run(cache=cache)
    assert path.exists() and not cache.dirty    # warm-up persisted


def test_plan_parallel_run_matches_serial(tmp_path):
    """``workers=N`` must be a pure throughput knob: same jobs, same
    plan-order results, same statuses and picks as the serial run —
    including per-job failure isolation inside worker threads."""

    def bad_factory():
        raise RuntimeError("boom in a worker")

    def build_plan():
        plan = TuningPlan(name="par")
        for ident in ("a", "b", "c", "d"):
            plan.add(CountingTunable(ident), engine="grid")
        plan.add(bad_factory, engine="grid", label="bad")
        return plan

    serial = build_plan().run(cache=TuningCache(tmp_path / "s.json"))
    parallel = build_plan().run(cache=TuningCache(tmp_path / "p.json"),
                                workers=4)
    assert parallel.counts == serial.counts
    assert parallel.counts["failed"] == 1 and parallel.counts["tuned"] == 4
    for sr, pr in zip(serial.results, parallel.results):
        assert (sr.label, sr.status, sr.best_config) == \
            (pr.label, pr.status, pr.best_config)
    # parallel warm-up persisted like the serial one: a serial re-run
    # over the parallel-warmed cache is 100% hits
    rerun = build_plan().run(cache=TuningCache(tmp_path / "p.json"))
    assert rerun.counts["hits"] == 4


def test_plan_parallel_duplicate_keys_tune_once(tmp_path):
    """Regression: two jobs resolving to the SAME cache key used to race
    under ``workers=N`` — both missed, both tuned, last write won.
    Grouped dispatch runs same-key jobs serially inside one pool task:
    the first tunes, every duplicate is a cache hit."""

    class SlowCounting(CountingTunable):
        def cost(self, cfg):
            time.sleep(0.02)           # widen the old race window
            return super().cost(cfg)

    tunables = [SlowCounting("dup") for _ in range(4)]
    plan = TuningPlan(name="dups")
    for t in tunables:
        plan.add(t, engine="grid")
    plan.add(CountingTunable("solo"), engine="grid")
    report = plan.run(cache=TuningCache(tmp_path / "c.json"), workers=4)
    assert report.ok
    assert report.counts == {"jobs": 5, "hits": 3, "tuned": 2,
                             "forced": 0, "failed": 0}
    # exactly one of the duplicates did engine work
    assert sum(1 for t in tunables if t.cost_calls) == 1
    # and every duplicate reports the one tuned pick
    picks = {r.best_config["block"] for r in report.results[:4]}
    assert picks == {4}


def test_plan_from_spec_grid_expansion_and_labels(tmp_path):
    spec = {"name": "s", "jobs": [
        {"tunable": "kernels.tuned_reduction", "grid": {"n": [4096, 8192]},
         "engine": "grid"}]}
    plan = TuningPlan.from_spec(spec)
    assert len(plan) == 2
    report = plan.run(cache=TuningCache(tmp_path / "c.json"))
    assert report.ok
    assert {r.label for r in report.results} == \
        {"kernels.tuned_reduction[n=4096]", "kernels.tuned_reduction[n=8192]"}


def test_plan_from_spec_inline_json_and_missing_path(tmp_path):
    inline = TuningPlan.from_spec(
        '{"name": "x", "jobs": [{"tunable": "kernels.tuned_reduction", '
        '"params": {"n": 4096}, "engine": "grid"}]}')
    assert len(inline) == 1 and inline.name == "x"
    with pytest.raises(FileNotFoundError):
        TuningPlan.from_spec(tmp_path / "nope.json")
    with pytest.raises(FileNotFoundError):
        TuningPlan.from_spec(str(tmp_path / "nope.json"))


def test_prefill_chunk_plan_roundtrip_zero_engine_runs(tmp_path):
    """Acceptance slice: a measured PrefillChunkTunable entry (tuned
    with the model attached) is resolvable from a pure-JSON plan spec —
    the second warmup is a cache hit with ZERO engine runs, because
    api/params handles are excluded from the fingerprint."""

    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.runtime.serve import prefill_chunk_tunable

    cfg = get_config("smollm-135m").reduced().replace(
        logits_dtype="float32")
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    cache = TuningCache(tmp_path / "c.json")

    tb = prefill_chunk_tunable(api, context=24, prompt_len=8, requests=1,
                               max_new=2, batch=1, params=params)
    res = tune(tb, engine="measure", cache=cache, budget=1, repeats=1)
    assert res.stats["provenance"] == "measured"
    assert res.t_min > 0.0

    spec = {"name": "prefill-warmup", "jobs": [
        {"tunable": "serve.prefill_chunk",
         "params": {"param_bytes": api.param_count() * 2,
                    "layers": cfg.n_layers, "d_model": cfg.d_model,
                    "kv_width": cfg.n_kv_heads * cfg.hd,
                    "context": 24, "prompt_len": 8, "requests": 1,
                    "mean_new": 2, "batch": 1},
         "engine": "measure",
         "engine_kwargs": {"budget": 1, "repeats": 1}}]}
    report = TuningPlan.from_spec(spec).run(cache=cache)
    assert report.ok and report.counts["hits"] == 1
    job = report.results[0]
    assert job.status == "hit"                  # zero engine runs
    assert job.provenance == "measured"
    assert job.best_config == dict(res.best_config)


def test_build_tunable_unknown_name_lists_registry():
    with pytest.raises(ValueError, match="unknown tunable"):
        build_tunable("does.not.exist")
    with pytest.raises(ValueError, match="kernels.matmul_tuned"):
        build_tunable("does.not.exist")


# ---------------------------------------------------------------------------
# MetaEngineTunable — tuning the tuner through the same tune() path
# ---------------------------------------------------------------------------


def test_meta_engine_tunable_selects_top_k_and_repeats(tmp_path):
    """The meta lattice prices (top_k, repeats) by really running the
    measure engine: top_k=1 stops at the model's (worse) pick; top_k=2
    reaches the wall-clock winner; the effort penalty then prefers the
    smallest shortlist that achieves it."""

    cache = TuningCache(tmp_path / "cache.json")
    inner = MeasuredTunable()
    meta = MetaEngineTunable(inner, engine="measure",
                             space={"top_k": [1, 2, 4], "repeats": [1]})
    res = tune(meta, engine="grid", cache=cache)
    assert res.best_config == {"top_k": 2, "repeats": 1}
    # every meta point really searched (1 + 2 + 3 measure calls)
    assert inner.measure_calls == 6
    # trials keep the inner results inspectable
    t1 = meta.trials[(("repeats", 1), ("top_k", 1))]
    t2 = meta.trials[(("repeats", 1), ("top_k", 2))]
    assert t1.best_config == {"block": 4}       # model's pick, measured 3.0
    assert t2.best_config == {"block": 2}       # wall-clock winner, 1.0

    # cached like any tunable: the re-run is a pure hit
    r2 = tune(meta, engine="grid", cache=cache)
    assert r2.stats["cache"] == "hit" and inner.measure_calls == 6


def test_meta_engine_fingerprint_keys_space_and_inner():
    a = MetaEngineTunable(MeasuredTunable("a"), space={"top_k": [1, 2]})
    b = MetaEngineTunable(MeasuredTunable("b"), space={"top_k": [1, 2]})
    c = MetaEngineTunable(MeasuredTunable("a"), space={"top_k": [1, 4]})
    assert cache_key(a, "grid")[0] != cache_key(b, "grid")[0]
    assert cache_key(a, "grid")[0] != cache_key(c, "grid")[0]


# ---------------------------------------------------------------------------
# cache artifacts
# ---------------------------------------------------------------------------


def _warm_cache(tmp_path, name, tunables):
    cache = TuningCache(tmp_path / name)
    for t in tunables:
        tune(t, engine="grid", cache=cache)
    return cache


def test_artifact_export_merge_roundtrip_across_caches(tmp_path):
    src = _warm_cache(tmp_path, "src.json",
                      [CountingTunable("a"), CountingTunable("b")])
    art = tmp_path / "artifact.json"
    bundle = src.export_artifact(art)
    assert bundle["schema"] == ARTIFACT_SCHEMA
    assert bundle["entry_count"] == 2

    dst = _warm_cache(tmp_path, "dst.json", [CountingTunable("c")])
    report = dst.merge_artifact(art)
    assert report["added"] == 2 and report["replaced"] == 0
    assert len(dst) == 3

    # merged entries serve hits with zero engine runs
    probe = CountingTunable("a")
    res = tune(probe, engine="grid", cache=dst)
    assert res.stats["cache"] == "hit" and probe.cost_calls == 0


def test_artifact_prefer_measured_policy(tmp_path):
    """A modeled entry must never clobber a measured one under the
    default policy — and a measured one upgrades a modeled one."""

    modeled = _warm_cache(tmp_path, "modeled.json", [MeasuredTunable()])
    key_mod, _ = cache_key(MeasuredTunable(), "grid")
    assert key_mod in modeled.entries

    # hand-build an artifact whose entry collides with key_mod but is
    # measured + older — prefer_measured must still replace modeled
    entry = dict(modeled.entries[key_mod])
    entry["provenance"] = "measured"
    entry["created"] = entry["created"] - 1e6
    entry["best_config"] = {"block": 2}
    art = tmp_path / "a.json"
    bundle = {"kind": ARTIFACT_KIND, "schema": ARTIFACT_SCHEMA,
              "created": time.time(),
              "platforms": {"cpu/x": {"platform": {"backend": "cpu"},
                                      "entries": {key_mod: entry}}}}
    art.write_text(json.dumps(bundle))

    rep = modeled.merge_artifact(art)               # measured wins
    assert rep["replaced"] == 1
    assert modeled.entries[key_mod]["best_config"] == {"block": 2}

    # ... and the reverse direction: modeled-over-measured is kept out
    entry2 = dict(entry)
    entry2["provenance"] = "modeled"
    entry2["created"] = time.time() + 1e6           # even though newer
    bundle["platforms"]["cpu/x"]["entries"] = {key_mod: entry2}
    art.write_text(json.dumps(bundle))
    rep2 = modeled.merge_artifact(art)
    assert rep2["kept"] == 1 and rep2["replaced"] == 0
    assert modeled.entries[key_mod]["provenance"] == "measured"


def test_artifact_provenance_meta_travels_with_entries(tmp_path, capsys):
    """Export stamps host/tool/timestamp provenance on the bundle;
    merge surfaces it in the report and onto every entry it takes as
    ``origin``; ``ls --json`` shows it — "where did this config come
    from" survives the bundle file itself."""

    src = _warm_cache(tmp_path, "src.json", [CountingTunable("a")])
    art = tmp_path / "a.json"
    bundle = src.export_artifact(art)
    meta = bundle["meta"]
    assert meta["host"] and meta["python"] and meta["created_utc"]
    assert meta["tool"].startswith("repro ")

    dst = TuningCache(tmp_path / "dst.json")
    report = dst.merge_artifact(art)
    assert report["meta"] == meta
    (entry,) = dst.entries.values()
    assert entry["origin"] == meta
    dst.save()

    assert cli_main(["--cache", str(tmp_path / "dst.json"),
                     "ls", "--json"]) == 0
    rows = json.loads(capsys.readouterr().out)
    assert rows[0]["origin"] == meta

    # locally tuned entries carry no origin
    (local_entry,) = src.entries.values()
    assert "origin" not in local_entry

    # relayed bundles keep the ORIGINAL origin: re-export from the node
    # and merge into a third cache — the entry still answers with the
    # first exporter, not the relay host
    (key,) = dst.entries
    dst._entries[key]["origin"] = {"host": "the-original-tuner"}
    art2 = tmp_path / "relay.json"
    dst.export_artifact(art2)
    dst2 = TuningCache(tmp_path / "dst2.json")
    dst2.merge_artifact(art2)
    assert dst2.entries[key]["origin"] == {"host": "the-original-tuner"}


def test_plan_parallel_serializes_timed_jobs(tmp_path):
    """Wall-clock-sensitive jobs (engine="measure", meta jobs) must not
    share the machine with pooled jobs: they run serially AFTER the
    pool drains, so their timings never sample a neighbour's load —
    while the report keeps plan order."""

    import threading
    events: list[str] = []
    lock = threading.Lock()

    class Tracker(CountingTunable):
        def __init__(self, ident, tag):
            super().__init__(ident)
            self.tag = tag

        def cost(self, cfg):
            with lock:
                events.append(self.tag)
            return super().cost(cfg)

        def measure(self, cfg):
            with lock:
                events.append(self.tag)
            return 1.0

    plan = TuningPlan(name="timed")
    plan.add(Tracker("m", "timed"), engine="measure", budget=1, repeats=1)
    plan.add(Tracker("a", "pooled"), engine="grid")
    plan.add(Tracker("b", "pooled"), engine="grid")
    assert [j.timed for j in plan.jobs] == [True, False, False]

    report = plan.run(cache=TuningCache(tmp_path / "c.json"), workers=4)
    assert report.ok
    # report order is plan order; execution put the timed job LAST
    assert [r.label for r in report.results][0] == "test.counting"
    first_timed = events.index("timed")
    assert all(e == "timed" for e in events[first_timed:])

    # spec-built meta jobs classify as timed without materializing
    spec = {"jobs": [
        {"tunable": "meta.engine",
         "params": {"engine": "measure",
                    "inner": {"tunable": "kernels.tuned_reduction",
                              "params": {"n": 4096}},
                    "space": {"top_k": [1], "repeats": [1]}},
         "engine": "grid"},
        {"tunable": "kernels.tuned_reduction", "params": {"n": 4096},
         "engine": "grid"}]}
    from_spec = TuningPlan.from_spec(spec)
    assert [j.timed for j in from_spec.jobs] == [True, False]


def test_artifact_stale_schema_rejected(tmp_path):
    src = _warm_cache(tmp_path, "src.json", [CountingTunable()])
    art = tmp_path / "a.json"
    bundle = src.export_artifact(art)
    doc = json.loads(art.read_text())
    doc["schema"] = ARTIFACT_SCHEMA + 1
    art.write_text(json.dumps(doc))
    with pytest.raises(ArtifactError, match="schema"):
        src.merge_artifact(art)
    # and a random JSON file is not an artifact at all
    (tmp_path / "junk.json").write_text('{"hello": 1}')
    with pytest.raises(ArtifactError, match="not a"):
        src.merge_artifact(tmp_path / "junk.json")
    assert bundle["entry_count"] == 1               # export untouched


def test_artifact_platform_filter(tmp_path, monkeypatch):
    cache = TuningCache(tmp_path / "c.json")
    tune(CountingTunable("cpu-side"), engine="grid", cache=cache)
    monkeypatch.setattr("repro.tune.cache.platform_fingerprint",
                        lambda: {"backend": "tpu", "device_kind": "v5e"})
    tune(CountingTunable("tpu-side"), engine="grid", cache=cache)
    b_all = cache.export_artifact(tmp_path / "all.json")
    assert len(b_all["platforms"]) == 2
    b_tpu = cache.export_artifact(tmp_path / "tpu.json", platform="tpu")
    assert list(b_tpu["platforms"]) == ["tpu/v5e"]
    assert b_tpu["entry_count"] == 1 and b_tpu["skipped"] == 1


def test_dirty_cache_survives_gc_until_flushed(tmp_path):
    """Deferred puts must not be lost when a short-lived cache goes out
    of scope: the dirty registry holds a strong reference until save()
    (the atexit flush then covers normal shutdown)."""

    import gc
    import weakref

    from repro.tune.cache import _dirty_caches
    cache = TuningCache(tmp_path / "c.json")
    tune(CountingTunable(), engine="grid", cache=cache)
    assert cache.dirty
    ref = weakref.ref(cache)
    del cache
    gc.collect()
    alive = ref()
    assert alive is not None and alive in _dirty_caches  # pinned while dirty
    alive.save()
    assert alive not in _dirty_caches
    del alive
    gc.collect()
    assert ref() is None                                 # released once clean
    assert len(TuningCache(tmp_path / "c.json")) == 1


def test_cache_prune_by_backend_and_staleness(tmp_path):
    cache = _warm_cache(tmp_path, "c.json",
                        [CountingTunable("a"), CountingTunable("b")])
    key_a, _ = cache_key(CountingTunable("a"), "grid")
    cache._entries[key_a]["created"] -= 10 * 86400      # age one entry
    with pytest.raises(ValueError, match="prune needs"):
        cache.prune()
    assert cache.prune(backend="tpu") == 0              # no tpu entries
    assert cache.prune(stale_days=5) == 1               # the aged one
    assert cache.prune(backend="cpu") == 1              # the rest
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# CLI: python -m repro.tune warmup/export/merge/ls/prune
# ---------------------------------------------------------------------------


def _tiny_plan(tmp_path):
    spec = {"name": "ci", "jobs": [
        {"tunable": "kernels.matmul_tuned",
         "params": {"M": 128, "N": 128, "K": 128, "dtype_bytes": 4},
         "engine": "grid"},
        {"tunable": "kernels.tuned_reduction", "params": {"n": 4096},
         "engine": "grid"}]}
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(spec))
    return p


def test_cli_fleet_rollout_end_to_end(tmp_path, capsys):
    """warmup -> export -> merge into a fresh cache -> second warmup is
    100% hits -> @autotune resolves from pure cache hits (0 engine
    runs) — the rollout acceptance slice."""
    plan = _tiny_plan(tmp_path)
    warm = str(tmp_path / "warm.json")
    node = str(tmp_path / "node.json")
    art = str(tmp_path / "artifact.json")
    assert cli_main(["--cache", warm, "warmup", str(plan)]) == 0
    assert cli_main(["--cache", warm, "export", art]) == 0
    assert cli_main(["--cache", node, "merge", art]) == 0
    capsys.readouterr()

    assert cli_main(["--cache", node, "warmup", str(plan), "--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["counts"]["hits"] == rep["counts"]["jobs"] == 2
    assert rep["counts"]["failed"] == 0

    # a fleet node resolves @autotune block sizes from the merged cache
    import jax.numpy as jnp
    from repro.kernels.matmul_tuned.ops import matmul_tuned
    node_cache = TuningCache(node)
    prev = set_default_cache(node_cache)
    try:
        a = jnp.ones((128, 128), jnp.float32)
        decision = matmul_tuned.tune(a, a)
        assert decision.stats["cache"] == "hit"
        assert node_cache.misses == 0
    finally:
        set_default_cache(prev)


def test_cli_warmup_exit_code_on_failure(tmp_path, capsys):
    p = tmp_path / "plan.json"
    p.write_text(json.dumps({"jobs": [{"tunable": "nope"}]}))
    assert cli_main(["--cache", str(tmp_path / "c.json"),
                     "warmup", str(p)]) == 1
    assert "FAILED" in capsys.readouterr().out


def test_cli_ls_and_prune(tmp_path, capsys):
    plan = _tiny_plan(tmp_path)
    cache = str(tmp_path / "c.json")
    assert cli_main(["--cache", cache, "warmup", str(plan)]) == 0
    capsys.readouterr()
    assert cli_main(["--cache", cache, "ls", "--json"]) == 0
    rows = json.loads(capsys.readouterr().out)
    assert len(rows) == 2
    assert {r["tunable"] for r in rows} == \
        {"kernels.matmul_tuned", "kernels.tuned_reduction"}
    # machine-readable keys are the FULL sha256, correlatable with
    # warmup-report stats["key"] and artifact entry keys
    assert all(len(r["key"]) == 64 for r in rows)
    assert cli_main(["--cache", cache, "prune"]) == 2   # no filters: refuse
    assert cli_main(["--cache", cache, "prune", "--stale-days", "0"]) == 0
    capsys.readouterr()
    assert cli_main(["--cache", cache, "ls"]) == 0
    assert "empty" in capsys.readouterr().out


def test_cli_merge_rejects_non_artifact(tmp_path, capsys):
    junk = tmp_path / "junk.json"
    junk.write_text("{}")
    assert cli_main(["--cache", str(tmp_path / "c.json"),
                     "merge", str(junk)]) == 2
    assert "error" in capsys.readouterr().err
