"""Shared test setup: point the process-wide tuning cache at a temp dir
so ``@autotune``-decorated kernels never read/write the developer's
``~/.cache/repro`` store during the suite, and pin the platform spec to
the defaults so a developer's calibration artifact never reprices the
cost models mid-suite (tests that exercise calibration install their
own spec and restore)."""

import pytest


@pytest.fixture(autouse=True, scope="session")
def _isolated_tuning_cache(tmp_path_factory):
    from repro.tune import TuningCache, set_default_cache
    path = tmp_path_factory.mktemp("tune") / "cache.json"
    prev = set_default_cache(TuningCache(path))
    yield
    set_default_cache(prev)


@pytest.fixture(autouse=True, scope="session")
def _pinned_platform_spec():
    from repro.calibrate import DEFAULT_SPEC, set_platform_spec
    prev = set_platform_spec(DEFAULT_SPEC)
    yield
    set_platform_spec(prev)
