"""Shared test setup: point the process-wide tuning cache at a temp dir
so ``@autotune``-decorated kernels never read/write the developer's
``~/.cache/repro`` store during the suite."""

import pytest


@pytest.fixture(autouse=True, scope="session")
def _isolated_tuning_cache(tmp_path_factory):
    from repro.tune import TuningCache, set_default_cache
    path = tmp_path_factory.mktemp("tune") / "cache.json"
    prev = set_default_cache(TuningCache(path))
    yield
    set_default_cache(prev)
