"""Elastic rescale: train sharded on a (4,2) mesh, checkpoint, restore
onto a (2,4) mesh, continue — loss curve must continue seamlessly.
Runs in a subprocess (needs 8 host devices before jax init)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import SyntheticLM
from repro.models import build_model
from repro.runtime import TrainConfig, build_train_step, init_train_state
from repro.distribute.sharding import use_mesh, shard_like, default_rules
from repro.checkpoint import save_checkpoint, load_checkpoint
from repro.models.common import axes_tree
from repro.optim.adamw import OptState
from repro.runtime.train import TrainState

cfg = get_config("smollm-135m").reduced()
api = build_model(cfg)
tcfg = TrainConfig(lr=3e-3, warmup=2, total_steps=20)
shape = ShapeSpec("t", 32, 8, "train")
data = SyntheticLM(cfg, shape)
rules = default_rules()

def state_axes():
    ax = api.axes()
    return TrainState(params=ax, opt=OptState(step=(), m=ax, v=ax),
                      ef_residual=None)

def make_step(mesh):
    st_template = init_train_state(api, jax.random.PRNGKey(0), tcfg)
    st_sh = shard_like(st_template, state_axes(), mesh, rules)
    step = jax.jit(build_train_step(api, tcfg))
    return step, st_sh, st_template

def place(state_host, st_sh):
    return jax.tree.map(lambda x, s: jax.device_put(np.asarray(x), s),
                        state_host, st_sh)

losses = []
# phase 1: (4,2) mesh
mesh1 = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
with use_mesh(mesh1, rules):
    step, st_sh, state = make_step(mesh1)
    state = place(state, st_sh)
    for i in range(4):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
    save_checkpoint("/tmp/elastic_ckpt", 4, state)

# phase 2: elastic rescale onto (2,4)
mesh2 = Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
with use_mesh(mesh2, rules):
    step2, st_sh2, template = make_step(mesh2)
    restored, manifest = load_checkpoint("/tmp/elastic_ckpt", template)
    assert manifest["step"] == 4
    state2 = place(restored, st_sh2)
    for i in range(4, 8):
        state2, m = step2(state2, data.batch(i))
        losses.append(float(m["loss"]))

assert all(np.isfinite(losses)), losses
# loss continues from where it was (no re-warm spike > 25%)
assert losses[4] < losses[0] * 1.25, losses
print("ELASTIC_OK", " ".join(f"{l:.3f}" for l in losses))
"""


@pytest.mark.slow
def test_elastic_rescale_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "ELASTIC_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
