"""``python -m repro.verify`` plumbing: exit codes, JSON report shape,
trail files, replay round-trip."""

import json

import pytest

from repro.verify.cli import main


def test_lint_json_exits_zero_on_clean_tree(capsys):
    assert main(["lint", "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["ok"] is True
    assert out["findings"] == []
    assert len(out["waived"]) >= 2


def test_check_bounded_run_reports_and_passes(tmp_path, capsys):
    # a tiny state budget: every model check must report "bounded"
    # (bound exhausted is NOT "verified") but the gate still passes
    # because nothing was violated
    rc = main(["check", "--json", "--max-states", "300",
               "--trail-dir", str(tmp_path)])
    out = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert out["ok"] is True
    assert out["exhaustive"] is False
    by_name = {c["name"]: c for c in out["checks"]}
    assert by_name["alloc-invariants"]["status"] == "bounded"
    assert by_name["alloc-invariants"]["bound_reason"] == "max_states"
    assert by_name["alloc-invariants"]["frontier_peak"] > 0
    # the small server models fit inside 300 states and stay verified
    assert by_name["server-fcfs-pressure"]["status"] == "verified"
    assert by_name["spec-cycle"]["status"] == "verified"


def test_mutants_write_trails_that_replay_reproduces(tmp_path, capsys):
    rc = main(["mutants", "--json", "--trail-dir", str(tmp_path)])
    out = json.loads(capsys.readouterr().out)
    assert rc == 0 and out["ok"] is True
    assert {r["mutant"] for r in out["mutants"]} >= {
        "share-skips-refcount", "ensure-partial-on-oom"}
    for r in out["mutants"]:
        assert r["caught"] and r["reproduced"], r
        payload = json.loads(open(r["trail"]).read())
        assert payload["allocator"] == r["mutant"]
        assert payload["ops"]
    # replay one trail through the CLI: exit 1 = reproduced
    trail = out["mutants"][0]["trail"]
    assert main(["replay", "--trail", trail]) == 1
    rep = capsys.readouterr().out
    assert "REPRODUCED" in rep


def test_replay_clean_trail_exits_zero(tmp_path, capsys):
    trail = tmp_path / "clean.json"
    trail.write_text(json.dumps({
        "model": "allocator", "allocator": "real",
        "config": {"n_slots": 2, "page_size": 2, "pages_per_slot": 2,
                   "n_pages": 3},
        "ops": [["ensure", 0, 4], ["share", 0, 1, 2], ["release", 0]],
    }))
    assert main(["replay", "--trail", str(trail)]) == 0
    assert "clean" in capsys.readouterr().out


def test_unknown_trail_model_is_an_error(tmp_path):
    trail = tmp_path / "bogus.json"
    trail.write_text(json.dumps({"model": "nope", "ops": []}))
    assert main(["replay", "--trail", str(trail)]) == 2
