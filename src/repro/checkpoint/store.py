"""Checkpointing: mesh-agnostic pytree snapshots, async save, retention,
atomic commit, elastic reshard on load.

Design (mirrors Orbax semantics on a plain filesystem):

* A checkpoint is a directory ``step_<n>/`` holding one ``.npy`` per
  leaf (flattened key path) + a ``manifest.json`` (treedef, dtypes,
  step, mesh shape it was saved under).  Arrays are saved as full
  (unsharded) values — *mesh-agnostic by construction*, so a restart may
  load onto a different mesh/pod count (elastic rescale): the load path
  simply ``device_put``s each leaf with the *new* sharding.
* Atomicity: writes go to ``step_<n>.tmp/`` and are renamed after fsync
  — a crash mid-save never corrupts the latest checkpoint.
* Async: ``save(..., blocking=False)`` snapshots to host memory
  (jax.device_get) and writes on a daemon thread, overlapping I/O with
  the next training steps (checkpoint/compute overlap).
* Retention: ``keep`` most recent checkpoints are retained.

On a real multi-host fleet each host writes only its addressable shards;
here (single host) the full value is written — the manifest records the
intent and the restore path is identical.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve numpy or ml_dtypes (bfloat16, float8_*) dtype names."""

    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree, *, extra: dict | None
                    = None) -> str:
    """Blocking atomic save; returns the committed path."""

    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "keys": [], "extra": extra or {},
                "time": time.time()}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["keys"].append({"key": key, "file": fname,
                                 "dtype": str(arr.dtype),
                                 "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def load_checkpoint(directory: str, template, *, step: int | None = None,
                    sharding_fn: Callable[[str], Any] | None = None):
    """Restore onto ``template``'s structure.  ``sharding_fn(key)`` may
    return a Sharding for elastic placement onto a (possibly different)
    mesh; default = commit as numpy and let jit re-place."""

    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    by_key = {e["key"]: e for e in manifest["keys"]}
    leaves = _flatten_with_paths(template)
    out_leaves = []
    for key, tmpl in leaves:
        e = by_key[key]
        arr = np.load(os.path.join(path, e["file"]))
        target = _np_dtype(e["dtype"])
        if arr.dtype != target:       # np.save round-trips ml_dtypes as V<n>
            arr = arr.view(target)
        want = tuple(tmpl.shape) if hasattr(tmpl, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} "
                             f"vs template {want}")
        if sharding_fn is not None:
            out_leaves.append(jax.device_put(arr, sharding_fn(key)))
        else:
            out_leaves.append(arr)
    treedef = jax.tree.structure(template)
    return treedef.unflatten(out_leaves), manifest


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            out.append(int(name[len("step_"):]))
    return sorted(out)


@dataclass
class CheckpointManager:
    """Async save + retention policy."""

    directory: str
    keep: int = 3
    save_interval: int = 50

    def __post_init__(self):
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_interval == 0

    def save(self, step: int, tree, *, extra: dict | None = None,
             blocking: bool = False) -> None:
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra=extra)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self.raise_if_failed()

    def raise_if_failed(self) -> None:
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def latest_step(self) -> int | None:
        steps = available_steps(self.directory)
        return steps[-1] if steps else None

    def restore(self, template, *, sharding_fn=None, step=None):
        return load_checkpoint(self.directory, template, step=step,
                               sharding_fn=sharding_fn)

    def _gc(self) -> None:
        steps = available_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)


__all__ = ["save_checkpoint", "load_checkpoint", "available_steps",
           "CheckpointManager"]
