"""Architecture zoo: unified transformer / SSD / MoE / hybrid / enc-dec
stacks with PSpec parameter declarations and logical sharding axes."""

from .api import ModelAPI, build_model

__all__ = ["ModelAPI", "build_model"]
