"""Mixture-of-Experts layer: token-choice top-k routing with capacity,
scatter-based dispatch (MODEL_FLOPS-aligned, unlike one-hot GShard
dispatch whose (T,E,C) tensors are quadratic in tokens).

Sharding: expert weights carry ("experts", "embed", "expert_mlp") logical
axes.  Rules decide expert parallelism ("experts" -> "model", llama4's
128 experts) vs per-expert tensor parallelism ("expert_mlp" -> "model",
mixtral's 8 × 16384).  The auto-tuner flips these — the arch-dependent
tuning parameter of DESIGN.md §4.

A reference one-hot einsum dispatch (``moe_forward_einsum``) validates
the scatter path numerically on small shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distribute.sharding import logical_constraint as lc
from .common import PSpec


def moe_specs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    E = cfg.moe.num_experts
    specs = {
        "router": PSpec((d, E), ("embed", "experts"), dtype=jnp.float32),
        "wg": PSpec((E, d, f), ("experts", "embed", "expert_mlp")),
        "wu": PSpec((E, d, f), ("experts", "embed", "expert_mlp")),
        "wd": PSpec((E, f, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.moe.shared_experts:
        specs["shared_wg"] = PSpec((d, f), ("embed", "mlp"))
        specs["shared_wu"] = PSpec((d, f), ("embed", "mlp"))
        specs["shared_wd"] = PSpec((f, d), ("mlp", "embed"))
    return specs


def capacity(cfg: ArchConfig, tokens: int) -> int:
    m = cfg.moe
    c = int(m.top_k * tokens * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)  # pad to 8 for TPU-friendly shapes


def _route(cfg: ArchConfig, p: dict, x2: jax.Array):
    """x2: (T, d) -> gates (T, k) f32, idx (T, k) int32."""

    logits = (x2.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    k = cfg.moe.top_k
    top, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(top, axis=-1)  # renormalize over the top-k
    return gates, idx


def moe_forward(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).

    GShard-style *group-wise* dispatch with the batch row as the group:
    tokens compete for expert capacity only within their own sequence, so
    every dispatch tensor keeps the batch dim — shardable over the data
    axes (a globally-flattened dispatch would force E·C·d to be
    replicated per device; see EXPERIMENTS.md §Perf).  Scatter/gather
    dispatch keeps HLO FLOPs at the model level (one-hot einsum dispatch
    is quadratic in tokens)."""

    B, S, d = x.shape
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    C = capacity(cfg, S)                                  # per-row capacity
    gates, idx = _route(cfg, p, x.reshape(B * S, d))
    gates = gates.reshape(B, S, k)
    idx = idx.reshape(B, S, k)

    # rank of each (token, choice) within its expert, per row
    flat_e = idx.reshape(B, S * k)                        # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    my_pos = jnp.take_along_axis(
        pos, flat_e[..., None], axis=2)[..., 0]           # (B, S*k)
    keep = my_pos < C
    slot = jnp.where(keep, flat_e * C + my_pos, E * C)    # E*C = trash row

    x_rep = jnp.repeat(x, k, axis=1)                      # (B, S*k, d)
    rows = jnp.arange(B)[:, None]
    # scatter stays local per batch shard (slot indices are row-local);
    # the reshard to expert sharding below IS the EP all-to-all
    xd = jnp.zeros((B, E * C + 1, d), x.dtype).at[rows, slot].add(x_rep)
    xd = lc(xd, "batch", None, "embed")
    xd = xd[:, :E * C].reshape(B, E, C, d)
    xd = lc(xd, "batch", "experts", None, "embed")

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xd, p["wg"])) * \
        jnp.einsum("becd,edf->becf", xd, p["wu"])
    h = lc(h, "batch", "experts", None, "expert_mlp")
    yo = jnp.einsum("becf,efd->becd", h, p["wd"])
    yo = lc(yo, "batch", "experts", None, "embed")

    flat = yo.reshape(B, E * C, d)
    flat = lc(flat, "batch", None, "embed")   # combine all-to-all back
    picked = jnp.take_along_axis(
        flat, jnp.clip(slot, 0, E * C - 1)[..., None], axis=1)
    picked = picked * (keep[..., None] *
                       gates.reshape(B, S * k)[..., None]).astype(x.dtype)
    y = picked.reshape(B, S, k, d).sum(axis=2)

    if cfg.moe.shared_experts:
        x2 = x.reshape(B * S, d)
        y = y + ((jax.nn.silu(x2 @ p["shared_wg"]) * (x2 @ p["shared_wu"])
                  ) @ p["shared_wd"]).reshape(B, S, d)
    return lc(y, "batch", "seq", "embed")


def moe_forward_einsum(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """GShard-style one-hot dispatch oracle (small shapes only); same
    per-row capacity semantics as the scatter path."""

    B, S, d = x.shape
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    C = capacity(cfg, S)
    gates, idx = _route(cfg, p, x.reshape(B * S, d))
    gates = gates.reshape(B, S * k)
    flat_e = idx.reshape(B, S * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    my_pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = my_pos < C

    # dispatch/combine tensors (B, S*k, E, C)
    disp = (jax.nn.one_hot(flat_e, E)[..., None] *
            jax.nn.one_hot(jnp.clip(my_pos, 0, C - 1), C)[:, :, None, :])
    disp = disp * keep[..., None, None]

    x_rep = jnp.repeat(x, k, axis=1)                     # (B, S*k, d)
    xd = jnp.einsum("btec,btd->becd", disp.astype(x.dtype), x_rep)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xd, p["wg"])) * \
        jnp.einsum("becd,edf->becf", xd, p["wu"])
    yo = jnp.einsum("becf,efd->becd", h, p["wd"])
    comb = disp * gates[..., None, None]
    y = jnp.einsum("btec,becd->btd", comb.astype(x.dtype), yo)
    y = y.reshape(B, S, k, d).sum(axis=2)

    if cfg.moe.shared_experts:
        x2 = x.reshape(B * S, d)
        y = y + ((jax.nn.silu(x2 @ p["shared_wg"]) * (x2 @ p["shared_wu"])
                  ) @ p["shared_wd"]).reshape(B, S, d)
    return y


__all__ = ["moe_specs", "moe_forward", "moe_forward_einsum", "capacity"]
