"""Shared model substrate: parameter specs with logical sharding axes,
norms, rotary embeddings, MLPs.

Parameters are declared as :class:`PSpec` pytrees (shape + logical axis
names + init).  The same spec tree serves three consumers:

* ``init_params``     — materialize arrays (smoke tests / real training),
* ``abstract_params`` — ShapeDtypeStructs (dry-run lowering, no alloc),
* ``axes_tree``       — logical-axis tree, resolved to PartitionSpecs by
  :mod:`repro.distribute.sharding` rules (which the auto-tuner mutates).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_DTYPE = jnp.bfloat16


@dataclass(frozen=True)
class PSpec:
    """Declarative parameter: shape, logical axes (one name per dim, or
    None for unsharded), init kind, dtype."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones
    dtype: Any = DEFAULT_DTYPE
    scale: float | None = None    # stddev override for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def stack_specs(tree, n: int, axis_name: str | None = "layers"):
    """Add a leading stacked-layers dim of size n to every spec."""

    return jax.tree.map(
        lambda s: dataclasses.replace(
            s, shape=(n, *s.shape), axes=(axis_name, *s.axes)),
        tree, is_leaf=is_pspec)


def init_params(tree, rng: jax.Array):
    """Materialize a PSpec tree into arrays (deterministic per-leaf keys)."""

    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_pspec)
    keys = jax.random.split(rng, len(leaves))

    def make(spec: PSpec, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        fan_in = spec.shape[-1] if len(spec.shape) >= 1 else 1
        scale = spec.scale if spec.scale is not None else fan_in ** -0.5
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale
                ).astype(spec.dtype)

    return treedef.unflatten([make(s, k) for s, k in zip(leaves, keys)])


def abstract_params(tree):
    """ShapeDtypeStructs for dry-run lowering — no device allocation."""

    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        tree, is_leaf=is_pspec)


def axes_tree(tree):
    """The logical-axes pytree (leaf = tuple of axis names)."""

    return jax.tree.map(lambda s: s.axes, tree, is_leaf=is_pspec)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding; x: (..., S, D), positions: (..., S)."""

    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head dims: x is (B, H, S, D), ang is (B, S, half)
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rotated = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if d > 2 * half:  # odd head dims: pass through the tail
        rotated = jnp.concatenate([rotated, x[..., 2 * half:]], axis=-1)
    return rotated.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean CE; logits (..., V) any float dtype, computed in f32."""

    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


__all__ = [
    "PSpec", "is_pspec", "stack_specs", "init_params", "abstract_params",
    "axes_tree", "rms_norm", "rope", "swiglu", "softmax_cross_entropy",
    "DEFAULT_DTYPE",
]
