"""Unified model API: init / abstract params / loss / decode, per arch.

``build_model(cfg)`` returns a :class:`ModelAPI` whose functions are pure
(params-first) and jit/pjit-friendly:

* ``loss(params, batch)``          — train/prefill forward + CE loss
* ``forward(params, batch)``       — logits (prefill benchmark form)
* ``decode_state_specs(B, ctx)``   — per-arch decode state as PSpec tree
  (KV ring caches, SSM states, static encoder/image cross-K/V)
* ``decode_step(params, state, tokens)`` — one-token serve step

Decode state is a pytree with a stacked leading blocks dim, scanned in
lock-step with the stacked block params.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..distribute.sharding import logical_constraint as lc
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import (PSpec, abstract_params, axes_tree, init_params,
                     rms_norm, stack_specs)
from .transformer import (_block_plan, _logits, _sinusoid, forward_encdec,
                          forward_lm, lm_loss, mlp_forward,
                          stack_param_specs)


def _cache_len(cfg: ArchConfig, context: int) -> int:
    if cfg.window is not None:
        return min(cfg.window, context)
    return context


@dataclass
class ModelAPI:
    cfg: ArchConfig
    specs: dict = field(default_factory=dict)

    def __post_init__(self):
        if not self.specs:
            self.specs = stack_param_specs(self.cfg)

    # -- params ---------------------------------------------------------
    def init(self, rng: jax.Array):
        return init_params(self.specs, rng)

    def abstract(self):
        return abstract_params(self.specs)

    def axes(self):
        return axes_tree(self.specs)

    def param_count(self) -> int:
        import numpy as np
        return int(sum(np.prod(s.shape) for s in
                       jax.tree.leaves(self.specs,
                                       is_leaf=lambda x: isinstance(x, PSpec))))

    # -- train / prefill ---------------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        return lm_loss(params, self.cfg, batch)

    def forward(self, params, batch) -> jax.Array:
        if self.cfg.is_encdec:
            return forward_encdec(params, self.cfg, batch["tokens"],
                                  batch["frames"])
        return forward_lm(params, self.cfg, batch["tokens"],
                          batch.get("img_embeds"))

    # -- decode ---------------------------------------------------------
    def decode_block_specs(self, batch: int, context: int,
                           paged: Any = None, dtype: Any = None) -> dict:
        """Decode state of ONE block (unstacked) — also used by the
        dry-run's block-level cost lowering.

        ``paged`` (a :class:`~repro.runtime.kv.PagedKVSpec`) swaps the
        per-slot KV rings for a shared page pool — attention state
        becomes ``(n_pages, Hkv, page_size, hd)`` and slots address it
        through the page table fed to :meth:`decode_step` /
        :meth:`prefill_step`.  Recurrent (SSM) and cross/encoder state
        stay per-slot: they are O(1) in context, paging buys nothing.
        ``dtype`` overrides the KV storage dtype (default bfloat16) —
        pass the params' dtype to keep a float32 model float32 through
        the cache."""

        cfg = self.cfg
        kinds, _ = _block_plan(cfg)
        C = _cache_len(cfg, context)
        per_block: dict[str, Any] = {}
        for i, kind in enumerate(kinds):
            entry: dict[str, Any] = {}
            if kind in ("dense", "moe", "hybrid", "encoder"):
                entry["kv"] = (attn.kv_pool_specs(cfg, paged.n_pages,
                                                  paged.page_size,
                                                  dtype=dtype)
                               if paged is not None
                               else attn.kv_cache_specs(cfg, batch, C,
                                                        dtype=dtype))
            if kind in ("ssm", "hybrid"):
                entry["ssm"] = ssm_mod.ssm_state_specs(cfg, batch,
                                                       dtype=dtype)
            if kind == "cross":
                Hkv, hd = cfg.n_kv_heads, cfg.hd
                entry["enc_kv"] = {
                    "k": PSpec((batch, Hkv, cfg.n_img_tokens, hd),
                               ("cache_batch", "kv_heads", None, None),
                               init="zeros"),
                    "v": PSpec((batch, Hkv, cfg.n_img_tokens, hd),
                               ("cache_batch", "kv_heads", None, None),
                               init="zeros")}
            per_block[f"{i}_{kind}"] = entry
        return per_block

    def decode_state_specs(self, batch: int, context: int,
                           paged: Any = None, dtype: Any = None) -> dict:
        cfg = self.cfg
        _, n_blocks = _block_plan(cfg)
        per_block = self.decode_block_specs(batch, context, paged, dtype)
        state: dict[str, Any] = {"blocks": stack_specs(per_block, n_blocks)}
        if cfg.is_encdec:
            Hkv, hd = cfg.n_kv_heads, cfg.hd
            xkv = {"k": PSpec((batch, Hkv, cfg.enc_seq, hd),
                              ("cache_batch", "kv_heads", None, None),
                              init="zeros"),
                   "v": PSpec((batch, Hkv, cfg.enc_seq, hd),
                              ("cache_batch", "kv_heads", None, None),
                              init="zeros")}
            state["xattn"] = stack_specs(xkv, cfg.n_layers)
        return state

    def init_decode_state(self, batch: int, context: int, paged: Any = None,
                          dtype: Any = None):
        return init_params(self.decode_state_specs(batch, context, paged,
                                                   dtype),
                           jax.random.PRNGKey(0))

    def decode_step(self, params, state, tokens: jax.Array,
                    cur_len: jax.Array, page_table: jax.Array | None = None,
                    active: jax.Array | None = None):
        """tokens: (B, 1) -> (logits (B, V), new state).

        ``cur_len`` is a scalar token count, or a (B,) vector of
        per-slot counts — the continuous-batching server feeds each
        slot's own position so mixed-progress slots decode correctly
        in one batch.

        ``page_table`` ((B, M) int32, -1 = unallocated) switches the
        attention state to the paged pool layout; ``active`` ((B,)
        bool) then gates pool writes per slot INSIDE attention — the
        pool is shared, so the caller cannot slice a per-slot merge out
        of the returned state the way it can with per-slot rings."""

        cfg = self.cfg
        kinds, _ = _block_plan(cfg)
        x = jnp.take(params["embed"], tokens, axis=0)       # (B,1,d)
        cur_len = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32),
                                   (tokens.shape[0],))
        if cfg.is_encdec:
            x = x + _sinusoid_at(cur_len[:, None], cfg.d_model, x.dtype)

        body = make_decode_body(cfg, kinds, cur_len, page_table, active)

        if cfg.is_encdec:
            xs = (params["blocks"], state["blocks"],
                  params["xattn_blocks"], state["xattn"])
        else:
            xs = (params["blocks"], state["blocks"])
        x, new_blocks = jax.lax.scan(body, x, xs)
        logits = _logits(params, cfg, x)[:, 0]
        new_state = dict(state)
        new_state["blocks"] = new_blocks
        return logits, new_state

    def prefill_step(self, params, state, tokens: jax.Array,
                     positions: jax.Array, lengths: jax.Array | None = None,
                     page_table: jax.Array | None = None):
        """Chunked serving-side prefill: advance a CHUNK of prompt
        tokens per call against the decode caches.

        tokens: (B, T) — one chunk per slot; positions: (B,) per-slot
        count of tokens already in the cache; lengths: (B,) valid tokens
        of this chunk per slot (default: all T).  Slots with length 0
        (decoding or idle while others prefill) are untouched: padding
        tokens neither write the KV ring nor advance SSM state.
        ``page_table`` switches the KV writes/reads to the paged pool
        (``lengths`` already gates the scatter per slot, so no separate
        ``active`` mask is needed).

        Returns ``(logits (B, V), new state)`` where each slot's logits
        are read at its LAST valid chunk token — the next-token
        distribution a tokenwise prefill would reach after feeding the
        same tokens one tick at a time."""

        x, new_state, lengths = self._chunk_forward(
            params, state, tokens, positions, lengths, page_table)
        # logits only at each slot's last valid token: (B, T, V) never
        # materializes
        li = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
        h_last = jnp.take_along_axis(x, li[:, None, None], axis=1)
        logits = _logits(params, self.cfg, h_last)[:, 0]
        return logits, new_state

    def verify_step(self, params, state, tokens: jax.Array,
                    positions: jax.Array, lengths: jax.Array | None = None,
                    page_table: jax.Array | None = None):
        """Speculative-decode verifier: the chunked prefill forward with
        logits at EVERY chunk position instead of only the last.

        Same contract as :meth:`prefill_step` — tokens (B, T) at
        absolute positions ``positions + [0, T)``, per-slot ``lengths``
        gating writes — but returns ``(logits (B, T, V), new state)``:
        ``logits[b, t]`` is the next-token distribution after token
        ``t``, so comparing ``argmax(logits[b, t])`` against the drafted
        token at ``t+1`` scores a whole draft in one forward.  Callers
        DISCARD the returned state (it contains the rejected tokens'
        cache writes) and commit the accepted prefix with a second
        ``prefill_step(lengths=accepted)`` — the only uniform way to
        keep recurrent (SSM/hybrid) state exact under partial
        acceptance.  Positions past ``lengths`` hold garbage logits."""

        x, new_state, _ = self._chunk_forward(
            params, state, tokens, positions, lengths, page_table)
        return _logits(params, self.cfg, x), new_state

    def _chunk_forward(self, params, state, tokens, positions, lengths,
                       page_table):
        """Shared multi-token cached forward under ``prefill_step`` and
        ``verify_step``: embed + chunk-attention scan over the blocks.
        Returns ``(hidden (B, T, d), new state, lengths (B,))``."""

        cfg = self.cfg
        kinds, _ = _block_plan(cfg)
        B, T = tokens.shape
        positions = jnp.broadcast_to(jnp.asarray(positions, jnp.int32), (B,))
        if lengths is None:
            lengths = jnp.full((B,), T, jnp.int32)
        lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
        valid = jnp.arange(T, dtype=jnp.int32)[None, :] < lengths[:, None]
        x = jnp.take(params["embed"], tokens, axis=0)       # (B,T,d)
        if cfg.is_encdec:
            pos_grid = positions[:, None] + jnp.arange(T, dtype=jnp.int32)
            x = x + _sinusoid_at(pos_grid, cfg.d_model, x.dtype)

        body = make_prefill_body(cfg, kinds, positions, lengths, valid,
                                 page_table)

        if cfg.is_encdec:
            xs = (params["blocks"], state["blocks"],
                  params["xattn_blocks"], state["xattn"])
        else:
            xs = (params["blocks"], state["blocks"])
        x, new_blocks = jax.lax.scan(body, x, xs)
        new_state = dict(state)
        new_state["blocks"] = new_blocks
        return x, new_state, lengths

    def encode_cross_kv(self, params, frames: jax.Array) -> dict:
        """Enc-dec serving prefill: run the encoder and project per-layer
        cross-attention K/V.  Returns {"k","v"}: (L, B, Hkv, enc_seq, hd)."""

        cfg = self.cfg
        assert cfg.is_encdec
        from .transformer import _scan_blocks, _sinusoid
        B, Senc, d = frames.shape
        pos = jnp.broadcast_to(jnp.arange(Senc, dtype=jnp.int32), (B, Senc))
        enc = frames + _sinusoid(Senc, d, frames.dtype)
        enc = _scan_blocks(cfg, params["enc_blocks"], enc, pos, causal=False,
                           kinds=["encoder"])
        enc = rms_norm(enc, params["enc_ln_f"])

        def one(xp):
            k = jnp.einsum("bsd,dhk->bhsk", enc, xp["x"]["wk"])
            v = jnp.einsum("bsd,dhk->bhsk", enc, xp["x"]["wv"])
            if "k_norm" in xp["x"]:
                from .common import rms_norm as _rn
                k = _rn(k, xp["x"]["k_norm"])
            return {"k": k, "v": v}

        return jax.lax.map(one, params["xattn_blocks"])

    # -- assigned-shape input specs ----------------------------------------
    def input_specs(self, shape: ShapeSpec, *, reduced: bool = False,
                    prefill_chunk: int | None = None,
                    paged: Any = None) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a shape
        (the dry-run contract; no allocation).

        For decode shapes, ``cur_len`` is the (B,) per-slot position
        vector the continuous-batching server actually feeds — a scalar
        spec lowered a different ``decode_step`` than serving runs.
        ``prefill_chunk=T`` instead describes the chunked
        :meth:`prefill_step` inputs (tokens (B, T) + per-slot positions
        and lengths).  ``paged`` (a
        :class:`~repro.runtime.kv.PagedKVSpec`) switches the state tree
        to the page-pool layout and adds the ``page_table`` (and, for
        decode, the per-slot ``active`` write gate) the paged steps
        take."""

        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind in ("train", "prefill"):
            out = {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, S),
                                                                 jnp.int32)}
            if cfg.family == "vlm":
                out["img_embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
            if cfg.is_encdec:
                out["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            return out
        state = abstract_params(self.decode_state_specs(B, S, paged))
        paged_specs = {} if paged is None else {
            "page_table": jax.ShapeDtypeStruct((B, paged.pages_per_slot),
                                               jnp.int32)}
        if prefill_chunk is not None:
            # chunked serving-side prefill step
            return {"tokens": jax.ShapeDtypeStruct((B, prefill_chunk),
                                                   jnp.int32),
                    "state": state,
                    "positions": jax.ShapeDtypeStruct((B,), jnp.int32),
                    "lengths": jax.ShapeDtypeStruct((B,), jnp.int32),
                    **paged_specs}
        # decode: one new token per slot + state of length S
        if paged is not None:
            paged_specs["active"] = jax.ShapeDtypeStruct((B,), jnp.bool_)
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "state": state,
                "cur_len": jax.ShapeDtypeStruct((B,), jnp.int32),
                **paged_specs}


def make_decode_body(cfg: ArchConfig, kinds: list[str], cur_len: jax.Array,
                     page_table: jax.Array | None = None,
                     active: jax.Array | None = None):
    """One decode block: the scan body of ``decode_step`` and the unit
    lowered by the dry-run's block-cost analysis.  With ``page_table``
    the attention state is the shared paged pool (``active`` gates its
    writes per slot); recurrent/cross state is per-slot either way."""

    def one_attn(p, hn, c):
        if page_table is not None:
            return attn.decode_attention_paged(
                p, cfg, hn, c, page_table, cur_len, window=cfg.window,
                active=active)
        return attn.decode_attention(p, cfg, hn, c, cur_len,
                                     window=cfg.window)

    def body(carry, scanned):
        h = carry
        if cfg.is_encdec:
            bp, cache, xp, xkv = scanned
        else:
            bp, cache = scanned
        new_cache = {}
        for i, kind in enumerate(kinds):
            key = f"{i}_{kind}"
            p, c = bp[key], cache[key]
            nc: dict[str, Any] = {}
            hn = rms_norm(h, p["ln1"])
            if kind in ("dense", "moe", "encoder"):
                a, nc["kv"] = one_attn(p["attn"], hn, c["kv"])
                h = h + a
            elif kind == "hybrid":
                a, nc["kv"] = one_attn(p["attn"], hn, c["kv"])
                m, nc["ssm"] = ssm_mod.ssm_decode_step(
                    p["ssm"], cfg, hn, c["ssm"])
                h = h + p["mix"][0] * a + p["mix"][1] * m
            elif kind == "ssm":
                m, nc["ssm"] = ssm_mod.ssm_decode_step(
                    p["ssm"], cfg, hn, c["ssm"])
                h = h + m
            elif kind == "cross":
                a = attn.decode_cross_attention(p["xattn"], cfg, hn,
                                                c["enc_kv"])
                h = h + jnp.tanh(p["gate"]).astype(h.dtype) * a
                nc["enc_kv"] = c["enc_kv"]
            if "ffn" in p:
                h2 = rms_norm(h, p["ln2"])
                if kind == "moe":
                    h = h + moe_mod.moe_forward(p["ffn"], cfg, h2)
                else:
                    h = h + mlp_forward(p["ffn"], cfg, h2)
            new_cache[key] = nc
        if cfg.is_encdec:
            a = attn.decode_cross_attention(
                xp["x"], cfg, rms_norm(h, xp["ln_x"]), xkv)
            h = h + a
        return h, new_cache

    return body


def make_prefill_body(cfg: ArchConfig, kinds: list[str],
                      positions: jax.Array, lengths: jax.Array,
                      valid: jax.Array,
                      page_table: jax.Array | None = None):
    """One chunked-prefill block: the scan body of ``prefill_step`` —
    the multi-token sibling of :func:`make_decode_body`.  Attention
    advances the chunk through :func:`attn.decode_attention_chunked`
    (chunk-wide KV scatter, chunk-causal masking) — or its paged
    sibling when a ``page_table`` is given — SSM/hybrid state steps the
    chunk via scan, the enc-dec cross path is unchanged (already
    chunk-shape agnostic)."""

    def one_attn(p, hn, c):
        if page_table is not None:
            return attn.decode_attention_chunked_paged(
                p, cfg, hn, c, page_table, positions, lengths,
                window=cfg.window)
        return attn.decode_attention_chunked(
            p, cfg, hn, c, positions, lengths, window=cfg.window)

    def body(carry, scanned):
        h = carry
        if cfg.is_encdec:
            bp, cache, xp, xkv = scanned
        else:
            bp, cache = scanned
        new_cache = {}
        for i, kind in enumerate(kinds):
            key = f"{i}_{kind}"
            p, c = bp[key], cache[key]
            nc: dict[str, Any] = {}
            hn = rms_norm(h, p["ln1"])
            if kind in ("dense", "moe", "encoder"):
                a, nc["kv"] = one_attn(p["attn"], hn, c["kv"])
                h = h + a
            elif kind == "hybrid":
                a, nc["kv"] = one_attn(p["attn"], hn, c["kv"])
                m, nc["ssm"] = ssm_mod.ssm_prefill_step(
                    p["ssm"], cfg, hn, c["ssm"], valid)
                h = h + p["mix"][0] * a + p["mix"][1] * m
            elif kind == "ssm":
                m, nc["ssm"] = ssm_mod.ssm_prefill_step(
                    p["ssm"], cfg, hn, c["ssm"], valid)
                h = h + m
            elif kind == "cross":
                a = attn.decode_cross_attention(p["xattn"], cfg, hn,
                                                c["enc_kv"])
                h = h + jnp.tanh(p["gate"]).astype(h.dtype) * a
                nc["enc_kv"] = c["enc_kv"]
            if "ffn" in p:
                h2 = rms_norm(h, p["ln2"])
                if kind == "moe":
                    h = h + moe_mod.moe_forward(p["ffn"], cfg, h2)
                else:
                    h = h + mlp_forward(p["ffn"], cfg, h2)
            new_cache[key] = nc
        if cfg.is_encdec:
            a = attn.decode_cross_attention(
                xp["x"], cfg, rms_norm(h, xp["ln_x"]), xkv)
            h = h + a
        return h, new_cache

    return body


def _sinusoid_at(pos: jax.Array, d: int, dtype) -> jax.Array:
    """Sinusoidal embedding at absolute position(s): (...,) positions
    -> (..., d); callers shape the position grid ((B, 1) per-slot
    decode, (B, T) chunked prefill)."""

    pos = jnp.asarray(pos, jnp.float32)
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos[..., None] / jnp.power(10000.0, 2 * dim / d)   # (..., d/2)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1
                           ).astype(dtype)


def build_model(cfg: ArchConfig) -> ModelAPI:
    return ModelAPI(cfg)


__all__ = ["ModelAPI", "build_model"]
