"""GQA attention: qk-norm / qkv-bias / sliding-window / RoPE variants,
full-sequence (train / prefill), single-token cached decode, and
chunked cached prefill (multi-token serving steps) paths.

Pure-JAX math by default (XLA fuses this well on TPU); the Pallas flash
kernel (`repro.kernels.flash_attention`) is the opt-in runtime path via
``use_flash=True`` — block sizes resolve through ``@autotune`` and the
persistent tuning cache, interpret mode keeps it runnable on CPU, and
shapes the kernel cannot tile fall back to the pure-JAX math.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distribute.sharding import logical_constraint as lc
from .common import DEFAULT_DTYPE, PSpec, rms_norm, rope

NEG_INF = -1e30


def attn_specs(cfg: ArchConfig, cross: bool = False) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    specs: dict[str, Any] = {
        "wq": PSpec((d, H, hd), ("embed", "heads", None)),
        "wk": PSpec((d, Hkv, hd), ("embed", "kv_heads", None)),
        "wv": PSpec((d, Hkv, hd), ("embed", "kv_heads", None)),
        "wo": PSpec((H, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = PSpec((H, hd), ("heads", None), init="zeros")
        specs["bk"] = PSpec((Hkv, hd), ("kv_heads", None), init="zeros")
        specs["bv"] = PSpec((Hkv, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = PSpec((hd,), (None,), init="ones")
        specs["k_norm"] = PSpec((hd,), (None,), init="ones")
    return specs


def _project_qkv(p: dict, cfg: ArchConfig, xq: jax.Array, xkv: jax.Array):
    q = jnp.einsum("bsd,dhk->bhsk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    B, Hkv, S, hd = k.shape
    return jnp.repeat(k, n_rep, axis=1)


def _sdpa(q, k, v, mask, scale):
    s = jnp.einsum("bhqk,bhsk->bhqs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bhsk->bhqk", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


# Above this query length, attention processes queries in chunks so the
# f32 score tensor stays O(chunk·S) instead of O(S²) — the pure-JAX
# flash-attention-lite used by 32k prefill/train (the Pallas kernel is
# the TPU runtime path).  Chunk size is a tuning parameter.
Q_CHUNK_THRESHOLD = 8192
Q_CHUNK = 1024


def _sdpa_qchunked(q, k, v, positions, scale, *, causal, window,
                   chunk=Q_CHUNK):
    B, H, S, hd = q.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        # padded queries mask out every key (position -1 precedes all
        # keys under the causal mask); their rows are sliced off below
        positions = jnp.pad(positions, ((0, 0), (0, pad)),
                            constant_values=-1)
    qs = q.reshape(B, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    # the caller's per-query positions, chunked alongside q — the mask
    # must honor them (offset prefill), not assume 0-based contiguity
    ps = positions.reshape(B, nc, chunk).transpose(1, 0, 2)  # (nc,B,chunk)
    ki = positions[:, None, None, :S]                   # (B,1,1,S)

    def one(args):
        qc, pc = args
        qi = pc[:, None, :, None]                       # (B,1,chunk,1)
        if causal:
            m = ki <= qi
            if window is not None:
                m &= ki >= qi - window + 1
        else:
            m = jnp.ones((1, 1, 1, S), bool)
        return _sdpa(qc, k, v, m, scale)

    out = jax.lax.map(one, (qs, ps))                    # (nc,B,H,chunk,hd)
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, nc * chunk, hd)
    return out[:, :, :S]


def _flash_supported(S: int) -> bool:
    """Can the Pallas flash kernel serve this full-sequence call?  The
    kernel tiles S into >=128 blocks (S must divide) and lowers for TPU
    — interpret mode covers CPU; other backends fall back."""

    return S % 128 == 0 and jax.default_backend() in ("cpu", "tpu")


def _positions_standard(positions: jax.Array, S: int) -> bool:
    """The flash kernel masks by absolute 0-based indices, so it
    requires ``positions == arange(S)``.  Concrete arrays are checked
    (offset prefill falls back to the pure-JAX path, which honors the
    caller's positions); under a trace the contiguity precondition is
    the caller's documented responsibility."""

    if isinstance(positions, jax.core.Tracer):
        return True
    try:
        return bool(jnp.all(positions ==
                            jnp.arange(S, dtype=positions.dtype)))
    except jax.errors.ConcretizationTypeError:
        # a concrete array can still be swept into an enclosing trace
        # (e.g. jax.checkpoint lifts closed-over constants); same
        # contract as the Tracer case above
        return True


def attention(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
              *, causal: bool = True, window: int | None = None,
              x_kv: jax.Array | None = None,
              use_flash: bool = False) -> jax.Array:
    """Full-sequence attention.  ``x_kv`` switches to cross-attention
    (no causal mask, no rope on kv positions beyond their own index).

    ``use_flash=True`` routes self-attention through the ``@autotune``d
    Pallas flash kernel (block sizes from the tuning cache; interpret
    mode on CPU).  The kernel derives its mask from absolute 0-based
    query/key indices, so the flash path requires the standard
    contiguous ``positions == arange(S)`` of train/prefill; unsupported
    shapes/backends fall back to the pure-JAX math.
    """

    B, S, d = x.shape
    cross = x_kv is not None
    xkv = x_kv if cross else x
    q, k, v = _project_qkv(p, cfg, x, xkv)
    if cfg.use_rope and not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    q = lc(q, "batch", "heads", "seq", None)
    k = lc(k, "batch", "heads", "seq", None)

    Skv = xkv.shape[1]
    if use_flash and not cross and _flash_supported(S) \
            and _positions_standard(positions, S):
        from ..kernels.flash_attention.ops import flash_attention
        # window only applies under causality in the pure-JAX paths;
        # match that here so use_flash never changes semantics
        o = flash_attention(q, k, v, causal=causal,
                            window=window if causal else None)
    elif (not cross) and causal and S > Q_CHUNK_THRESHOLD:
        o = _sdpa_qchunked(q, k, v, positions, cfg.hd ** -0.5,
                           causal=True, window=window)
    else:
        if cross or not causal:
            mask = jnp.ones((1, 1, S, Skv), bool)
        else:
            qi = positions[:, None, :, None]           # (B,1,S,1)
            ki = positions[:, None, None, :]           # (B,1,1,S)
            mask = ki <= qi
            if window is not None:
                mask &= ki >= qi - window + 1
        o = _sdpa(q, k, v, mask, cfg.hd ** -0.5)
    o = lc(o, "batch", "heads", "seq", None)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return lc(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------


def kv_cache_specs(cfg: ArchConfig, batch: int, cache_len: int,
                   dtype: Any = None) -> dict:
    # cache_seq -> "model" keeps 32k caches shardable even when kv_heads
    # do not divide the model axis (GQA kv=8 on 16-way TP); the axis
    # dedup keeps whichever dim claims "model" first.  ``dtype`` lets
    # callers match the cache to the params' compute dtype (a float32
    # model wants float32 K/V — quantizing through bfloat16 costs exact
    # greedy-parity guarantees downstream consumers rely on).
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    dt = dtype if dtype is not None else DEFAULT_DTYPE
    return {
        "k": PSpec((batch, Hkv, cache_len, hd),
                   ("cache_batch", "kv_heads", "cache_seq", "head_dim"),
                   init="zeros", dtype=dt),
        "v": PSpec((batch, Hkv, cache_len, hd),
                   ("cache_batch", "kv_heads", "cache_seq", "head_dim"),
                   init="zeros", dtype=dt),
    }


def decode_attention(p: dict, cfg: ArchConfig, x: jax.Array, cache: dict,
                     cur_len: jax.Array, *, window: int | None = None,
                     x_kv_cache: dict | None = None) -> tuple[jax.Array, dict]:
    """One-token attention against a KV cache.

    x: (B, 1, d); cache["k"/"v"]: (B, Hkv, C, hd) where C is the cache
    length (= window size for SWA — a ring buffer — else max context);
    cur_len: count of tokens already in the cache — a scalar, or a (B,)
    vector of per-slot counts so mixed-progress serving slots each get
    their own RoPE rotation, ring slot, and validity mask.  Keys are
    stored post-RoPE.  Returns (output, updated cache)."""

    B, one, d = x.shape
    C = cache["k"].shape[2]
    cur_len = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    positions = cur_len[:, None]                  # (B, 1)
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k_new = rope(k_new, positions, cfg.rope_theta)

    slot = jnp.mod(cur_len, C)                    # (B,) ring for SWA
    # one-hot masked update instead of dynamic_update_slice: elementwise,
    # so it stays local under ANY cache sharding (dynamic updates on a
    # sharded dim made GSPMD replicate the whole cache — §Perf cell B)
    hot = (jnp.arange(C)[None, :] == slot[:, None])[:, None, :, None]
    k = jnp.where(hot, k_new.astype(cache["k"].dtype), cache["k"])
    v = jnp.where(hot, v_new.astype(cache["v"].dtype), cache["v"])
    new_cache = {"k": k, "v": v}

    # validity per slot: ring index i last held absolute position
    # cur_len[b] - ((slot[b] - i) mod C)
    idx = jnp.arange(C)[None, :]                  # (1, C)
    cl = cur_len[:, None]                         # (B, 1)
    if window is not None:
        abs_pos = cl - jnp.mod(slot[:, None] - idx, C)
        valid = (abs_pos >= jnp.maximum(0, cl - window + 1)) & \
                (abs_pos <= cl)
    else:
        valid = idx <= cl                         # (B, C)
    mask = valid[:, None, None, None, :]          # (B, 1, 1, 1, C)

    # grouped GQA attention: contract q head-groups against the kv-head
    # cache directly — jnp.repeat's broadcast made GSPMD all-gather the
    # whole cache per layer (§Perf cell B, 8 GiB/block)
    o = _grouped_sdpa(q, k, v, mask, cfg.hd ** -0.5).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return lc(out, "batch", "seq", "embed"), new_cache


def decode_attention_chunked(p: dict, cfg: ArchConfig, x: jax.Array,
                             cache: dict, cur_len: jax.Array,
                             lengths: jax.Array, *,
                             window: int | None = None
                             ) -> tuple[jax.Array, dict]:
    """Chunked cached prefill: advance T tokens against the decode cache
    in one call (the multi-token sibling of :func:`decode_attention`).

    x: (B, T, d); cache["k"/"v"]: (B, Hkv, C, hd); cur_len: (B,) tokens
    already in each slot's cache; lengths: (B,) valid tokens of this
    chunk per slot (rows past a slot's length are padding — they neither
    read into the cache nor write it, so mixed prefill/decode serving
    slots share one static-shape step).

    Queries attend to the *pre-chunk* cache snapshot concatenated with
    the in-chunk keys under a chunk-causal mask (the ``_sdpa_qchunked``
    offset-position discipline: masks come from absolute positions, not
    0-based contiguity).  Attending the snapshot rather than the updated
    ring is load-bearing for SWA: with a ring of C slots and a chunk
    longer than C, a late in-chunk token overwrites the ring slot an
    early query still needs.  The returned cache has the valid chunk K/V
    scattered in ring order, last writer per slot winning."""

    B, T, d = x.shape
    C = cache["k"].shape[2]
    cur_len = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    t_idx = jnp.arange(T, dtype=jnp.int32)
    pos = cur_len[:, None] + t_idx[None, :]            # (B, T) absolute
    valid = t_idx[None, :] < lengths[:, None]          # (B, T)

    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)

    # ring scatter: chunk token t lands in slot pos[t] mod C; for each
    # ring index take the LAST valid writer (one-hot + argmax keeps the
    # update elementwise — same sharding rationale as decode_attention)
    ring = jnp.mod(pos, C)                             # (B, T)
    match = (ring[:, :, None] == jnp.arange(C)[None, None, :]) \
        & valid[:, :, None]                            # (B, T, C)
    hit = match.any(axis=1)                            # (B, C)
    last_t = jnp.argmax(match * (t_idx[None, :, None] + 1), axis=1)

    def scatter(new, old):
        vals = jnp.take_along_axis(new, last_t[:, None, :, None], axis=2)
        return jnp.where(hit[:, None, :, None], vals.astype(old.dtype), old)

    new_cache = {"k": scatter(k_new, cache["k"]),
                 "v": scatter(v_new, cache["v"])}

    # pre-chunk snapshot key positions: ring index i last held absolute
    # position (cur_len-1) - ((slot_last - i) mod C); never-written
    # indices come out negative and mask off
    last = cur_len - 1
    slot_last = jnp.mod(last, C)
    idx = jnp.arange(C, dtype=jnp.int32)[None, :]      # (1, C)
    abs_old = last[:, None] - jnp.mod(slot_last[:, None] - idx, C)

    kp = jnp.concatenate([abs_old, pos], axis=1)       # (B, C+T)
    k_ok = jnp.concatenate([abs_old >= 0, valid], axis=1)
    qp = pos[:, :, None]                               # (B, T, 1)
    mask = k_ok[:, None, :] & (kp[:, None, :] <= qp)   # (B, T, C+T)
    if window is not None:
        mask &= kp[:, None, :] >= qp - window + 1
    mask = mask[:, None, None, :, :]                   # (B, 1, 1, T, C+T)

    # grouped GQA over snapshot-cache + in-chunk keys (no head repeat)
    k_all = jnp.concatenate([cache["k"].astype(jnp.float32),
                             k_new.astype(jnp.float32)], axis=2)
    v_all = jnp.concatenate([cache["v"].astype(jnp.float32),
                             v_new.astype(jnp.float32)], axis=2)
    o = _grouped_sdpa(q, k_all, v_all, mask, cfg.hd ** -0.5).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return lc(out, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# Paged cached decode (shared page pool instead of per-slot rings)
# ---------------------------------------------------------------------------


def kv_pool_specs(cfg: ArchConfig, n_pages: int, page_size: int,
                  dtype: Any = None) -> dict:
    """Paged KV storage for one block: a POOL of ``n_pages`` fixed-size
    pages shared by every serving slot, addressed through per-slot page
    tables (:mod:`repro.runtime.kv`) — the paged sibling of
    :func:`kv_cache_specs`.  Pages play the batch role of the
    contiguous layout, so they take its sharding axis.  ``dtype`` as in
    :func:`kv_cache_specs`."""

    Hkv, hd = cfg.n_kv_heads, cfg.hd
    dt = dtype if dtype is not None else DEFAULT_DTYPE
    return {
        "k": PSpec((n_pages, Hkv, page_size, hd),
                   ("cache_batch", "kv_heads", None, "head_dim"),
                   init="zeros", dtype=dt),
        "v": PSpec((n_pages, Hkv, page_size, hd),
                   ("cache_batch", "kv_heads", None, "head_dim"),
                   init="zeros", dtype=dt),
    }


def _gather_pool(pool_kv: jax.Array, page_table: jax.Array) -> jax.Array:
    """(P, Hkv, ps, hd) pool -> (B, Hkv, M*ps, hd) per-slot linear view
    through the page table (unallocated entries gather page 0 — callers
    mask them by ``page_table >= 0``)."""

    g = pool_kv[jnp.clip(page_table, 0)]          # (B, M, Hkv, ps, hd)
    B, M, Hkv, ps, hd = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, M * ps, hd)


def _pool_validity(page_table: jax.Array, page_size: int) -> jax.Array:
    """(B, M*ps) bool: which linear positions are backed by a live page."""

    return jnp.repeat(page_table >= 0, page_size, axis=1)


def _grouped_sdpa(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array, scale: float) -> jax.Array:
    """Grouped GQA attention: contract q head-groups against the
    kv-head cache directly (no head repeat — same sharding rationale as
    :func:`decode_attention`).  q: (B, H, T, hd); k/v: (B, Hkv, S, hd);
    mask broadcastable to (B, Hkv, g, T, S); returns (B, H, T, hd)."""

    B, H, T, hd = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, T, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, k.astype(jnp.float32))
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    og = jnp.einsum("bkgts,bksd->bkgtd", pr, v.astype(jnp.float32))
    return og.reshape(B, H, T, hd)


def decode_attention_paged(p: dict, cfg: ArchConfig, x: jax.Array,
                           pool: dict, page_table: jax.Array,
                           cur_len: jax.Array, *,
                           window: int | None = None,
                           active: jax.Array | None = None
                           ) -> tuple[jax.Array, dict]:
    """One-token attention against a PAGED KV pool — the paged sibling
    of :func:`decode_attention`, same numerics.

    x: (B, 1, d); pool["k"/"v"]: (P, Hkv, page_size, hd) shared by all
    slots; page_table: (B, M) physical page per logical page (-1 =
    unallocated); cur_len: (B,) tokens already cached per slot.  The
    new K/V lands at physical page ``page_table[b, pos // ps]``, offset
    ``pos % ps``; queries then attend the slot's pages through a
    page-table gather under the same absolute-position causal/window
    masks as the contiguous path.  ``active`` gates writes per slot —
    the pool is SHARED, so the server cannot gate the merged state
    per-slot afterwards the way it can with per-slot rings; an idle or
    prefilling neighbour must not scatter a garbage token here."""

    B, one, d = x.shape
    P, Hkv, ps, hd = pool["k"].shape
    M = page_table.shape[1]
    cur_len = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    if active is None:
        active = jnp.ones((B,), bool)
    positions = cur_len[:, None]                  # (B, 1)
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k_new = rope(k_new, positions, cfg.rope_theta)

    # scatter: slot b writes page page_table[b, pos//ps] offset pos%ps.
    # Pages are slot-exclusive (allocator invariant), so at most one
    # slot writes any (page, offset); one-hot + argmax keeps the update
    # elementwise under pool sharding, as in decode_attention.
    lp = jnp.clip(cur_len // ps, 0, M - 1)        # (B,) logical page
    off = cur_len % ps                            # (B,)
    phys = jnp.take_along_axis(page_table, lp[:, None], axis=1)[:, 0]
    phys = jnp.where(active, phys, -1)            # inactive: never match
    hot = (jnp.arange(P)[None, :] == phys[:, None])[:, :, None] \
        & (jnp.arange(ps)[None, :] == off[:, None])[:, None, :]  # (B,P,ps)
    written = hot.any(axis=0)                     # (P, ps)
    writer = jnp.argmax(hot, axis=0)              # (P, ps) -> slot index

    def scatter(new, old):                        # new: (B, Hkv, 1, hd)
        vals = new[writer, :, 0, :].transpose(0, 2, 1, 3)  # (P,Hkv,ps,hd)
        return jnp.where(written[:, None, :, None], vals.astype(old.dtype),
                         old)

    new_pool = {"k": scatter(k_new, pool["k"]),
                "v": scatter(v_new, pool["v"])}

    # gather the slot's linear view from the UPDATED pool; position t
    # lives at page t//ps — validity is the same absolute-position mask
    # as the contiguous path plus "is the page live"
    k = _gather_pool(new_pool["k"], page_table)   # (B, Hkv, M*ps, hd)
    v = _gather_pool(new_pool["v"], page_table)
    t = jnp.arange(M * ps, dtype=jnp.int32)[None, :]        # (1, M*ps)
    cl = cur_len[:, None]                                   # (B, 1)
    valid = (t <= cl) & _pool_validity(page_table, ps)
    if window is not None:
        valid &= t >= cl - window + 1
    mask = valid[:, None, None, None, :]          # (B, 1, 1, 1, M*ps)

    o = _grouped_sdpa(q, k, v, mask, cfg.hd ** -0.5).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return lc(out, "batch", "seq", "embed"), new_pool


def decode_attention_chunked_paged(p: dict, cfg: ArchConfig, x: jax.Array,
                                   pool: dict, page_table: jax.Array,
                                   cur_len: jax.Array, lengths: jax.Array,
                                   *, window: int | None = None
                                   ) -> tuple[jax.Array, dict]:
    """Chunked cached prefill against a paged pool — the paged sibling
    of :func:`decode_attention_chunked`.

    Unlike the ring layout, paged positions are unique (no wraparound
    inside a chunk), so the chunk K/V is scattered first and queries
    attend the *updated* pool directly: every key position ``<= qp`` is
    genuinely written, the chunk-causal mask does the rest.  ``lengths``
    gates both the scatter and nothing else is needed per slot —
    padding rows (idle/decoding neighbours) write no page."""

    B, T, d = x.shape
    P, Hkv, ps, hd = pool["k"].shape
    M = page_table.shape[1]
    cur_len = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    t_idx = jnp.arange(T, dtype=jnp.int32)
    pos = cur_len[:, None] + t_idx[None, :]            # (B, T) absolute
    valid = t_idx[None, :] < lengths[:, None]          # (B, T)

    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)

    # scatter: chunk token (b, t) -> page page_table[b, pos//ps], offset
    # pos%ps; valid tokens only.  Positions are unique and pages
    # slot-exclusive, so at most one (b, t) writes any (page, offset).
    lp = jnp.clip(pos // ps, 0, M - 1)                 # (B, T)
    off = pos % ps
    phys = jnp.take_along_axis(page_table, lp, axis=1)  # (B, T)
    phys = jnp.where(valid, phys, -1)
    hot = (phys[:, :, None] == jnp.arange(P)[None, None, :])[..., None] \
        & (off[:, :, None] == jnp.arange(ps)[None, None, :])[:, :, None, :]
    hot = hot.reshape(B * T, P, ps)                    # (B*T, P, ps)
    written = hot.any(axis=0)                          # (P, ps)
    writer = jnp.argmax(hot, axis=0)                   # (P, ps) -> b*T+t

    def scatter(new, old):                             # new: (B,Hkv,T,hd)
        flat = new.transpose(0, 2, 1, 3).reshape(B * T, Hkv, hd)
        vals = flat[writer].transpose(0, 2, 1, 3)      # (P, Hkv, ps, hd)
        return jnp.where(written[:, None, :, None], vals.astype(old.dtype),
                         old)

    new_pool = {"k": scatter(k_new, pool["k"]),
                "v": scatter(v_new, pool["v"])}

    # chunk-causal read over the updated pool: key position t is valid
    # for query position qp when t <= qp (all such positions are
    # written — this request's earlier ticks or this chunk) and its
    # page is live
    k = _gather_pool(new_pool["k"], page_table)        # (B, Hkv, M*ps, hd)
    v = _gather_pool(new_pool["v"], page_table)
    kp = jnp.arange(M * ps, dtype=jnp.int32)[None, None, :]   # (1,1,M*ps)
    qp = pos[:, :, None]                               # (B, T, 1)
    mask = (kp <= qp) & _pool_validity(page_table, ps)[:, None, :]
    if window is not None:
        mask &= kp >= qp - window + 1
    mask = mask[:, None, None, :, :]                   # (B,1,1,T,M*ps)

    o = _grouped_sdpa(q, k, v, mask, cfg.hd ** -0.5).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return lc(out, "batch", "seq", "embed"), new_pool


def decode_cross_attention(p: dict, cfg: ArchConfig, x: jax.Array,
                           enc_kv: dict) -> jax.Array:
    """Decode-time cross attention against precomputed encoder K/V."""

    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(enc_kv["k"], n_rep), _repeat_kv(enc_kv["v"], n_rep)
    mask = jnp.ones((1, 1, 1, k.shape[2]), bool)
    o = _sdpa(q, k, v, mask, cfg.hd ** -0.5)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"])


__all__ = ["attn_specs", "attention", "decode_attention",
           "decode_attention_chunked", "decode_attention_paged",
           "decode_attention_chunked_paged", "kv_cache_specs",
           "kv_pool_specs", "decode_cross_attention"]
