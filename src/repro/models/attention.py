"""GQA attention: qk-norm / qkv-bias / sliding-window / RoPE variants,
full-sequence (train / prefill), single-token cached decode, and
chunked cached prefill (multi-token serving steps) paths.

Pure-JAX math by default (XLA fuses this well on TPU); the Pallas flash
kernel (`repro.kernels.flash_attention`) is the opt-in runtime path via
``use_flash=True`` — block sizes resolve through ``@autotune`` and the
persistent tuning cache, interpret mode keeps it runnable on CPU, and
shapes the kernel cannot tile fall back to the pure-JAX math.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distribute.sharding import logical_constraint as lc
from .common import PSpec, rms_norm, rope

NEG_INF = -1e30


def attn_specs(cfg: ArchConfig, cross: bool = False) -> dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    specs: dict[str, Any] = {
        "wq": PSpec((d, H, hd), ("embed", "heads", None)),
        "wk": PSpec((d, Hkv, hd), ("embed", "kv_heads", None)),
        "wv": PSpec((d, Hkv, hd), ("embed", "kv_heads", None)),
        "wo": PSpec((H, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias and not cross:
        specs["bq"] = PSpec((H, hd), ("heads", None), init="zeros")
        specs["bk"] = PSpec((Hkv, hd), ("kv_heads", None), init="zeros")
        specs["bv"] = PSpec((Hkv, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        specs["q_norm"] = PSpec((hd,), (None,), init="ones")
        specs["k_norm"] = PSpec((hd,), (None,), init="ones")
    return specs


def _project_qkv(p: dict, cfg: ArchConfig, xq: jax.Array, xkv: jax.Array):
    q = jnp.einsum("bsd,dhk->bhsk", xq, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", xkv, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    B, Hkv, S, hd = k.shape
    return jnp.repeat(k, n_rep, axis=1)


def _sdpa(q, k, v, mask, scale):
    s = jnp.einsum("bhqk,bhsk->bhqs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqs,bhsk->bhqk", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


# Above this query length, attention processes queries in chunks so the
# f32 score tensor stays O(chunk·S) instead of O(S²) — the pure-JAX
# flash-attention-lite used by 32k prefill/train (the Pallas kernel is
# the TPU runtime path).  Chunk size is a tuning parameter.
Q_CHUNK_THRESHOLD = 8192
Q_CHUNK = 1024


def _sdpa_qchunked(q, k, v, positions, scale, *, causal, window,
                   chunk=Q_CHUNK):
    B, H, S, hd = q.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        # padded queries mask out every key (position -1 precedes all
        # keys under the causal mask); their rows are sliced off below
        positions = jnp.pad(positions, ((0, 0), (0, pad)),
                            constant_values=-1)
    qs = q.reshape(B, H, nc, chunk, hd).transpose(2, 0, 1, 3, 4)
    # the caller's per-query positions, chunked alongside q — the mask
    # must honor them (offset prefill), not assume 0-based contiguity
    ps = positions.reshape(B, nc, chunk).transpose(1, 0, 2)  # (nc,B,chunk)
    ki = positions[:, None, None, :S]                   # (B,1,1,S)

    def one(args):
        qc, pc = args
        qi = pc[:, None, :, None]                       # (B,1,chunk,1)
        if causal:
            m = ki <= qi
            if window is not None:
                m &= ki >= qi - window + 1
        else:
            m = jnp.ones((1, 1, 1, S), bool)
        return _sdpa(qc, k, v, m, scale)

    out = jax.lax.map(one, (qs, ps))                    # (nc,B,H,chunk,hd)
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, nc * chunk, hd)
    return out[:, :, :S]


def _flash_supported(S: int) -> bool:
    """Can the Pallas flash kernel serve this full-sequence call?  The
    kernel tiles S into >=128 blocks (S must divide) and lowers for TPU
    — interpret mode covers CPU; other backends fall back."""

    return S % 128 == 0 and jax.default_backend() in ("cpu", "tpu")


def _positions_standard(positions: jax.Array, S: int) -> bool:
    """The flash kernel masks by absolute 0-based indices, so it
    requires ``positions == arange(S)``.  Concrete arrays are checked
    (offset prefill falls back to the pure-JAX path, which honors the
    caller's positions); under a trace the contiguity precondition is
    the caller's documented responsibility."""

    if isinstance(positions, jax.core.Tracer):
        return True
    try:
        return bool(jnp.all(positions ==
                            jnp.arange(S, dtype=positions.dtype)))
    except jax.errors.ConcretizationTypeError:
        # a concrete array can still be swept into an enclosing trace
        # (e.g. jax.checkpoint lifts closed-over constants); same
        # contract as the Tracer case above
        return True


def attention(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
              *, causal: bool = True, window: int | None = None,
              x_kv: jax.Array | None = None,
              use_flash: bool = False) -> jax.Array:
    """Full-sequence attention.  ``x_kv`` switches to cross-attention
    (no causal mask, no rope on kv positions beyond their own index).

    ``use_flash=True`` routes self-attention through the ``@autotune``d
    Pallas flash kernel (block sizes from the tuning cache; interpret
    mode on CPU).  The kernel derives its mask from absolute 0-based
    query/key indices, so the flash path requires the standard
    contiguous ``positions == arange(S)`` of train/prefill; unsupported
    shapes/backends fall back to the pure-JAX math.
    """

    B, S, d = x.shape
    cross = x_kv is not None
    xkv = x_kv if cross else x
    q, k, v = _project_qkv(p, cfg, x, xkv)
    if cfg.use_rope and not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(k, n_rep), _repeat_kv(v, n_rep)
    q = lc(q, "batch", "heads", "seq", None)
    k = lc(k, "batch", "heads", "seq", None)

    Skv = xkv.shape[1]
    if use_flash and not cross and _flash_supported(S) \
            and _positions_standard(positions, S):
        from ..kernels.flash_attention.ops import flash_attention
        # window only applies under causality in the pure-JAX paths;
        # match that here so use_flash never changes semantics
        o = flash_attention(q, k, v, causal=causal,
                            window=window if causal else None)
    elif (not cross) and causal and S > Q_CHUNK_THRESHOLD:
        o = _sdpa_qchunked(q, k, v, positions, cfg.hd ** -0.5,
                           causal=True, window=window)
    else:
        if cross or not causal:
            mask = jnp.ones((1, 1, S, Skv), bool)
        else:
            qi = positions[:, None, :, None]           # (B,1,S,1)
            ki = positions[:, None, None, :]           # (B,1,1,S)
            mask = ki <= qi
            if window is not None:
                mask &= ki >= qi - window + 1
        o = _sdpa(q, k, v, mask, cfg.hd ** -0.5)
    o = lc(o, "batch", "heads", "seq", None)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return lc(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------


def kv_cache_specs(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    # cache_seq -> "model" keeps 32k caches shardable even when kv_heads
    # do not divide the model axis (GQA kv=8 on 16-way TP); the axis
    # dedup keeps whichever dim claims "model" first.
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": PSpec((batch, Hkv, cache_len, hd),
                   ("cache_batch", "kv_heads", "cache_seq", "head_dim"),
                   init="zeros"),
        "v": PSpec((batch, Hkv, cache_len, hd),
                   ("cache_batch", "kv_heads", "cache_seq", "head_dim"),
                   init="zeros"),
    }


def decode_attention(p: dict, cfg: ArchConfig, x: jax.Array, cache: dict,
                     cur_len: jax.Array, *, window: int | None = None,
                     x_kv_cache: dict | None = None) -> tuple[jax.Array, dict]:
    """One-token attention against a KV cache.

    x: (B, 1, d); cache["k"/"v"]: (B, Hkv, C, hd) where C is the cache
    length (= window size for SWA — a ring buffer — else max context);
    cur_len: count of tokens already in the cache — a scalar, or a (B,)
    vector of per-slot counts so mixed-progress serving slots each get
    their own RoPE rotation, ring slot, and validity mask.  Keys are
    stored post-RoPE.  Returns (output, updated cache)."""

    B, one, d = x.shape
    C = cache["k"].shape[2]
    cur_len = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    positions = cur_len[:, None]                  # (B, 1)
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k_new = rope(k_new, positions, cfg.rope_theta)

    slot = jnp.mod(cur_len, C)                    # (B,) ring for SWA
    # one-hot masked update instead of dynamic_update_slice: elementwise,
    # so it stays local under ANY cache sharding (dynamic updates on a
    # sharded dim made GSPMD replicate the whole cache — §Perf cell B)
    hot = (jnp.arange(C)[None, :] == slot[:, None])[:, None, :, None]
    k = jnp.where(hot, k_new.astype(cache["k"].dtype), cache["k"])
    v = jnp.where(hot, v_new.astype(cache["v"].dtype), cache["v"])
    new_cache = {"k": k, "v": v}

    # validity per slot: ring index i last held absolute position
    # cur_len[b] - ((slot[b] - i) mod C)
    idx = jnp.arange(C)[None, :]                  # (1, C)
    cl = cur_len[:, None]                         # (B, 1)
    if window is not None:
        abs_pos = cl - jnp.mod(slot[:, None] - idx, C)
        valid = (abs_pos >= jnp.maximum(0, cl - window + 1)) & \
                (abs_pos <= cl)
    else:
        valid = idx <= cl                         # (B, C)
    mask = valid[:, None, None, :]

    # grouped GQA attention: contract q head-groups against the kv-head
    # cache directly — jnp.repeat's broadcast made GSPMD all-gather the
    # whole cache per layer (§Perf cell B, 8 GiB/block)
    B2, H, one, hd = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    qg = q.reshape(B2, Hkv, g, hd).astype(jnp.float32) * cfg.hd ** -0.5
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k.astype(jnp.float32))
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    og = jnp.einsum("bkgs,bksd->bkgd", pr, v.astype(jnp.float32))
    o = og.reshape(B2, H, 1, hd).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return lc(out, "batch", "seq", "embed"), new_cache


def decode_attention_chunked(p: dict, cfg: ArchConfig, x: jax.Array,
                             cache: dict, cur_len: jax.Array,
                             lengths: jax.Array, *,
                             window: int | None = None
                             ) -> tuple[jax.Array, dict]:
    """Chunked cached prefill: advance T tokens against the decode cache
    in one call (the multi-token sibling of :func:`decode_attention`).

    x: (B, T, d); cache["k"/"v"]: (B, Hkv, C, hd); cur_len: (B,) tokens
    already in each slot's cache; lengths: (B,) valid tokens of this
    chunk per slot (rows past a slot's length are padding — they neither
    read into the cache nor write it, so mixed prefill/decode serving
    slots share one static-shape step).

    Queries attend to the *pre-chunk* cache snapshot concatenated with
    the in-chunk keys under a chunk-causal mask (the ``_sdpa_qchunked``
    offset-position discipline: masks come from absolute positions, not
    0-based contiguity).  Attending the snapshot rather than the updated
    ring is load-bearing for SWA: with a ring of C slots and a chunk
    longer than C, a late in-chunk token overwrites the ring slot an
    early query still needs.  The returned cache has the valid chunk K/V
    scattered in ring order, last writer per slot winning."""

    B, T, d = x.shape
    C = cache["k"].shape[2]
    cur_len = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (B,))
    t_idx = jnp.arange(T, dtype=jnp.int32)
    pos = cur_len[:, None] + t_idx[None, :]            # (B, T) absolute
    valid = t_idx[None, :] < lengths[:, None]          # (B, T)

    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if cfg.use_rope:
        q = rope(q, pos, cfg.rope_theta)
        k_new = rope(k_new, pos, cfg.rope_theta)

    # ring scatter: chunk token t lands in slot pos[t] mod C; for each
    # ring index take the LAST valid writer (one-hot + argmax keeps the
    # update elementwise — same sharding rationale as decode_attention)
    ring = jnp.mod(pos, C)                             # (B, T)
    match = (ring[:, :, None] == jnp.arange(C)[None, None, :]) \
        & valid[:, :, None]                            # (B, T, C)
    hit = match.any(axis=1)                            # (B, C)
    last_t = jnp.argmax(match * (t_idx[None, :, None] + 1), axis=1)

    def scatter(new, old):
        vals = jnp.take_along_axis(new, last_t[:, None, :, None], axis=2)
        return jnp.where(hit[:, None, :, None], vals.astype(old.dtype), old)

    new_cache = {"k": scatter(k_new, cache["k"]),
                 "v": scatter(v_new, cache["v"])}

    # pre-chunk snapshot key positions: ring index i last held absolute
    # position (cur_len-1) - ((slot_last - i) mod C); never-written
    # indices come out negative and mask off
    last = cur_len - 1
    slot_last = jnp.mod(last, C)
    idx = jnp.arange(C, dtype=jnp.int32)[None, :]      # (1, C)
    abs_old = last[:, None] - jnp.mod(slot_last[:, None] - idx, C)

    kp = jnp.concatenate([abs_old, pos], axis=1)       # (B, C+T)
    k_ok = jnp.concatenate([abs_old >= 0, valid], axis=1)
    qp = pos[:, :, None]                               # (B, T, 1)
    mask = k_ok[:, None, :] & (kp[:, None, :] <= qp)   # (B, T, C+T)
    if window is not None:
        mask &= kp[:, None, :] >= qp - window + 1
    mask = mask[:, None, None, :, :]                   # (B, 1, 1, T, C+T)

    # grouped GQA over snapshot-cache + in-chunk keys (no head repeat)
    k_all = jnp.concatenate([cache["k"].astype(jnp.float32),
                             k_new.astype(jnp.float32)], axis=2)
    v_all = jnp.concatenate([cache["v"].astype(jnp.float32),
                             v_new.astype(jnp.float32)], axis=2)
    B2, H, T2, hd = q.shape
    Hkv = k_all.shape[1]
    g = H // Hkv
    qg = q.reshape(B2, Hkv, g, T, hd).astype(jnp.float32) * cfg.hd ** -0.5
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, k_all)
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    og = jnp.einsum("bkgts,bksd->bkgtd", pr, v_all)
    o = og.reshape(B2, H, T, hd).astype(x.dtype)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    return lc(out, "batch", "seq", "embed"), new_cache


def decode_cross_attention(p: dict, cfg: ArchConfig, x: jax.Array,
                           enc_kv: dict) -> jax.Array:
    """Decode-time cross attention against precomputed encoder K/V."""

    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
    n_rep = cfg.n_heads // cfg.n_kv_heads
    k, v = _repeat_kv(enc_kv["k"], n_rep), _repeat_kv(enc_kv["v"], n_rep)
    mask = jnp.ones((1, 1, 1, k.shape[2]), bool)
    o = _sdpa(q, k, v, mask, cfg.hd ** -0.5)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"])


__all__ = ["attn_specs", "attention", "decode_attention",
           "decode_attention_chunked", "kv_cache_specs",
           "decode_cross_attention"]
