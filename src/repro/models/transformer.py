"""Unified decoder stack + enc-dec variant covering all 10 assigned
architectures, with scan-over-layers (O(1) HLO in depth) and
configurable remat.

Layer kinds (picked per arch family):

* dense   — pre-norm attention + MLP (minitron, qwen3, qwen1.5, smollm)
* moe     — attention + MoE FFN (mixtral every layer; llama4 every 2nd)
* ssm     — Mamba2 SSD block + (optional) MLP; d_ff == 0 -> pure SSD stack
* hybrid  — parallel attention (SWA) and SSD heads on the same input,
            learned per-dim mix (hymba)
* cross   — gated cross-attention to stub image embeddings every N
            layers (llama-3.2-vision)
* enc-dec — whisper: bidirectional encoder over stub frame embeddings,
            causal decoder with per-layer cross-attention (sinusoidal
            positions; the learned-positions detail of real Whisper is
            immaterial to systems behaviour and noted in DESIGN.md)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeSpec
from ..distribute.sharding import logical_constraint as lc
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import (PSpec, abstract_params, axes_tree, init_params,
                     rms_norm, softmax_cross_entropy, stack_specs)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_act == "gelu":
        return {"w1": PSpec((d, f), ("embed", "mlp")),
                "b1": PSpec((f,), ("mlp",), init="zeros"),
                "w2": PSpec((f, d), ("mlp", "embed")),
                "b2": PSpec((d,), ("embed",), init="zeros")}
    return {"wg": PSpec((d, f), ("embed", "mlp")),
            "wu": PSpec((d, f), ("embed", "mlp")),
            "wd": PSpec((f, d), ("mlp", "embed"))}


def mlp_forward(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_act == "gelu":
        h = jax.nn.gelu(x @ p["w1"] + p["b1"])
        h = lc(h, "batch", "seq", "mlp")
        return lc(h @ p["w2"] + p["b2"], "batch", "seq", "embed")
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    h = lc(h, "batch", "seq", "mlp")
    return lc(h @ p["wd"], "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def _norm_spec(cfg):
    return PSpec((cfg.d_model,), ("embed",), init="ones")


def layer_specs(cfg: ArchConfig, kind: str) -> dict:
    d = cfg.d_model
    s: dict[str, Any] = {"ln1": _norm_spec(cfg)}
    if kind == "dense" or kind == "moe":
        s["attn"] = attn.attn_specs(cfg)
        s["ln2"] = _norm_spec(cfg)
        s["ffn"] = moe_mod.moe_specs(cfg) if kind == "moe" else mlp_specs(cfg)
    elif kind == "ssm":
        s["ssm"] = ssm_mod.ssm_specs(cfg)
        if cfg.d_ff:
            s["ln2"] = _norm_spec(cfg)
            s["ffn"] = mlp_specs(cfg)
    elif kind == "hybrid":
        s["attn"] = attn.attn_specs(cfg)
        s["ssm"] = ssm_mod.ssm_specs(cfg)
        s["mix"] = PSpec((2, d), (None, "embed"), init="ones", scale=0.5)
        s["ln2"] = _norm_spec(cfg)
        s["ffn"] = mlp_specs(cfg)
    elif kind == "cross":
        s["xattn"] = attn.attn_specs(cfg, cross=True)
        s["gate"] = PSpec((1,), (None,), init="zeros", dtype=jnp.float32)
        s["ln2"] = _norm_spec(cfg)
        s["ffn"] = mlp_specs(cfg)
    else:  # encoder layer (bidirectional dense)
        s["attn"] = attn.attn_specs(cfg)
        s["ln2"] = _norm_spec(cfg)
        s["ffn"] = mlp_specs(cfg)
    return s


def layer_forward(p: dict, cfg: ArchConfig, kind: str, x: jax.Array,
                  positions: jax.Array, *, enc_out: jax.Array | None = None,
                  causal: bool = True) -> jax.Array:
    h = rms_norm(x, p["ln1"])
    if kind in ("dense", "moe", "encoder"):
        a = attn.attention(p["attn"], cfg, h, positions, causal=causal,
                           window=cfg.window, use_flash=cfg.use_flash)
        x = x + a
    elif kind == "ssm":
        x = x + ssm_mod.ssm_forward(p["ssm"], cfg, h)
    elif kind == "hybrid":
        a = attn.attention(p["attn"], cfg, h, positions, causal=True,
                           window=cfg.window, use_flash=cfg.use_flash)
        m = ssm_mod.ssm_forward(p["ssm"], cfg, h)
        x = x + p["mix"][0] * a + p["mix"][1] * m
    elif kind == "cross":
        a = attn.attention(p["xattn"], cfg, h, positions, x_kv=enc_out)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * a
    if "ffn" in p:
        h2 = rms_norm(x, p["ln2"])
        if kind == "moe":
            x = x + moe_mod.moe_forward(p["ffn"], cfg, h2)
        else:
            x = x + mlp_forward(p["ffn"], cfg, h2)
    return lc(x, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# Stacks (scan over layers; blocks for interleaved patterns)
# ---------------------------------------------------------------------------


def _block_plan(cfg: ArchConfig) -> tuple[list[str], int]:
    """Returns (kinds within one block, number of blocks).  The stack is
    ``n_blocks`` repetitions of the block, scanned."""

    if cfg.family == "dense":
        return ["dense"], cfg.n_layers
    if cfg.family == "ssm":
        return ["ssm"], cfg.n_layers
    if cfg.family == "hybrid":
        return ["hybrid"], cfg.n_layers
    if cfg.family == "moe":
        every = cfg.moe.every
        if every == 1:
            return ["moe"], cfg.n_layers
        assert cfg.n_layers % every == 0
        return ["dense"] * (every - 1) + ["moe"], cfg.n_layers // every
    if cfg.family == "vlm":
        every = cfg.cross_attn_every
        assert cfg.n_layers % every == 0
        return ["dense"] * (every - 1) + ["cross"], cfg.n_layers // every
    if cfg.family == "audio":
        return ["dense"], cfg.n_layers      # decoder; encoder built apart
    raise ValueError(cfg.family)


def stack_param_specs(cfg: ArchConfig) -> dict:
    kinds, n_blocks = _block_plan(cfg)
    block = {f"{i}_{kind}": layer_specs(cfg, kind)
             for i, kind in enumerate(kinds)}
    specs: dict[str, Any] = {
        "embed": PSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "ln_f": _norm_spec(cfg),
        "blocks": stack_specs(block, n_blocks),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = PSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    if cfg.family == "audio":
        enc_block = {"0_encoder": layer_specs(cfg, "encoder")}
        specs["enc_blocks"] = stack_specs(enc_block, cfg.encoder_layers)
        specs["enc_ln_f"] = _norm_spec(cfg)
        # decoder cross-attention lives in each decoder block
        xblock = {"x": attn.attn_specs(cfg, cross=True),
                  "ln_x": _norm_spec(cfg)}
        specs["xattn_blocks"] = stack_specs(xblock, cfg.n_layers)
    return specs


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # full


def _scan_blocks(cfg: ArchConfig, blocks, x, positions, *, enc_out=None,
                 causal=True, kinds=None):
    kinds = kinds or _block_plan(cfg)[0]

    def body(carry, bp):
        h = carry
        for i, kind in enumerate(kinds):
            h = layer_forward(bp[f"{i}_{kind}"], cfg, kind, h, positions,
                              enc_out=enc_out, causal=causal)
        return h, None

    body = _remat(cfg, body)
    x, _ = jax.lax.scan(body, x, blocks)
    return x


def _logits(params: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["ln_f"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["unembed"]
    logits = lc(logits, "batch", "seq", "vocab")
    return logits.astype(jnp.dtype(cfg.logits_dtype))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def forward_lm(params: dict, cfg: ArchConfig, tokens: jax.Array,
               img_embeds: jax.Array | None = None) -> jax.Array:
    """Decoder-only forward -> logits (B, S, V)."""

    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = lc(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _scan_blocks(cfg, params["blocks"], x, positions, enc_out=img_embeds)
    return _logits(params, cfg, x)


def forward_encdec(params: dict, cfg: ArchConfig, tokens: jax.Array,
                   frames: jax.Array) -> jax.Array:
    """Whisper-style: encode stub frame embeddings, decode tokens."""

    B, Senc, d = frames.shape
    pos_e = jnp.broadcast_to(jnp.arange(Senc, dtype=jnp.int32), (B, Senc))
    enc = frames + _sinusoid(Senc, d, frames.dtype)
    enc = _scan_blocks(cfg, params["enc_blocks"], enc, pos_e, causal=False,
                       kinds=["encoder"])
    enc = rms_norm(enc, params["enc_ln_f"])

    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + _sinusoid(S, d, x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, bp):
        h = carry
        dp, xp = bp
        h = layer_forward(dp["0_dense"], cfg, "dense", h, positions)
        a = attn.attention(xp["x"], cfg, rms_norm(h, xp["ln_x"]), positions,
                           x_kv=enc)
        return h + a, None

    body = _remat(cfg, body)
    x, _ = jax.lax.scan(body, x, (params["blocks"], params["xattn_blocks"]))
    return _logits(params, cfg, x)


def _sinusoid(S: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1
                           ).astype(dtype)[None]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def hidden_lm(params: dict, cfg: ArchConfig, tokens: jax.Array,
              img_embeds: jax.Array | None = None) -> jax.Array:
    """Decoder trunk up to (and including) the final norm — no logits."""

    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = lc(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = _scan_blocks(cfg, params["blocks"], x, positions, enc_out=img_embeds)
    return rms_norm(x, params["ln_f"])


def _chunked_ce(params: dict, cfg: ArchConfig, h: jax.Array,
                labels: jax.Array, chunk: int) -> jax.Array:
    """CE without materializing (B, S, V): lax.map over sequence chunks
    (memory-term optimization; numerically identical to the fused CE)."""

    B, S, d = h.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    hc = h.reshape(B, nc, chunk, d).swapaxes(0, 1)       # (nc, B, chunk, d)
    lc_ = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    valid = (jnp.arange(nc * chunk) < S).reshape(nc, 1, chunk)

    w = params["embed"] if cfg.tie_embeddings else params["unembed"]

    def one(args):
        hx, lx, vx = args
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", hx, w)
        else:
            logits = hx @ w
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * vx)

    sums = jax.lax.map(one, (hc, lc_, valid))
    return jnp.sum(sums) / (B * S)


def lm_loss(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    if cfg.is_encdec:
        logits = forward_encdec(params, cfg, batch["tokens"], batch["frames"])
        return softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    if cfg.loss_seq_chunk:
        h = hidden_lm(params, cfg, batch["tokens"], batch.get("img_embeds"))
        return _chunked_ce(params, cfg, h[:, :-1],
                           batch["labels"][:, 1:], cfg.loss_seq_chunk)
    logits = forward_lm(params, cfg, batch["tokens"],
                        batch.get("img_embeds"))
    return softmax_cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


__all__ = [
    "mlp_specs", "mlp_forward", "layer_specs", "layer_forward",
    "stack_param_specs", "forward_lm", "forward_encdec", "lm_loss",
]
