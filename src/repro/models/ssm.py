"""Mamba2 SSD (state-space duality) block — chunked scan + O(1) decode.

Implements the minimal SSD form of arXiv:2405.21060: scalar decay per
head (A), shared B/C projections (ngroups=1), short causal depthwise
conv on (x, B, C), gated output.  The sequence dimension is processed in
chunks of ``cfg.ssm.chunk`` (a tuning parameter): quadratic attention-like
math within a chunk, a `lax.scan` carrying the (heads, headdim, state)
recurrent state across chunks — the sub-quadratic property that makes
the 500k-token decode shape feasible.

Decode keeps a per-layer recurrent state (B, H, P, N) plus a conv ring
buffer; one step is O(H·P·N).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distribute.sharding import logical_constraint as lc
from .common import DEFAULT_DTYPE, PSpec


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.headdim
    return di, nh, s.headdim, s.state, s.conv_width


def ssm_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    di, nh, P, N, W = _dims(cfg)
    conv_ch = di + 2 * N
    return {
        "w_in": PSpec((d, 2 * di + 2 * N + nh), ("embed", "mlp")),
        "conv_w": PSpec((W, conv_ch), (None, "mlp"), scale=0.5),
        "conv_b": PSpec((conv_ch,), ("mlp",), init="zeros"),
        "a_log": PSpec((nh,), ("heads",), init="zeros"),
        "dt_bias": PSpec((nh,), ("heads",), init="zeros"),
        "D": PSpec((nh,), ("heads",), init="ones"),
        "w_out": PSpec((di, d), ("mlp", "embed")),
    }


def _split_in(cfg: ArchConfig, proj: jax.Array):
    di, nh, P, N, _ = _dims(cfg)
    z, xin, B, C, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, xin, B, C, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (W, C).

    Lowered as ONE depthwise conv op: the shifted-slice formulation
    looked harmless but exploded into thousands of per-shard slice ops
    under GSPMD (§Perf mamba2 iteration 2 — 247 GiB of f32 traffic)."""

    W, C = w.shape
    out = jax.lax.conv_general_dilated(
        x, w[:, None, :], window_strides=(1,), padding=[(W - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int, compute_dtype=jnp.float32):
    """SSD over chunks.  x: (B,S,H,P), dt: (B,S,H), A: (H,) negative,
    Bm/Cm: (B,S,N).  Returns y: (B,S,H,P).

    ``compute_dtype`` is the dtype of the O(S·Q) intra-chunk tensors
    (decay matrices) — the memory hot-spot; decays/cumsums stay f32 for
    stability, then cast (tunable: cfg.ssd_dtype)."""

    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:                    # pad tail: dt=0 tokens are inert (decay 1,
        pad = Q - S % Q          # zero state contribution)
        padf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (t.ndim - 2))
        x, dt, Bm, Cm = padf(x), padf(dt), padf(Bm), padf(Cm)
        S = S + pad
    nc = S // Q

    r = lambda t: t.reshape(Bsz, nc, Q, *t.shape[2:])
    x, dt, Bm, Cm = r(x), r(dt), r(Bm), r(Cm)

    dA = dt * A[None, None, None, :]                   # (B,nc,Q,H) negative
    seg = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    total = seg[:, :, -1, :]                           # (B,nc,H)

    cd = compute_dtype
    xc = x.astype(cd)
    # NOTE: every contraction below is staged as an explicit 2-operand
    # einsum with the elementwise factors pre-multiplied — XLA's n-ary
    # einsum planning materialized rank-6 outer products for the fused
    # forms (§Perf mamba2 iteration 2: 250 GiB of traffic).

    # intra-chunk (quadratic in Q): y_ij = C_i . B_j * exp(seg_i - seg_j) * dt_j
    decay = jnp.exp(seg[:, :, :, None, :] - seg[:, :, None, :, :])  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    decay = jnp.where(mask, decay, 0.0).astype(cd)
    cb = jnp.einsum("bcqn,bckn->bcqk", Cm.astype(cd), Bm.astype(cd),
                    preferred_element_type=jnp.float32)   # (B,nc,Q,Q)
    # attention-like weights W(b,c,q,k,h), then ONE k-contraction
    w_qk = cb.astype(cd)[..., None] * decay * dt.astype(cd)[:, :, None]
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", w_qk, xc,
                        preferred_element_type=jnp.float32)

    # chunk states: S_c = sum_j exp(total - seg_j) * dt_j * B_j x_j
    sdecay = jnp.exp(total[:, :, None, :] - seg)        # (B,nc,Q,H)
    u = (sdecay * dt).astype(cd)[..., None] * xc        # (B,nc,Q,H,P)
    states = jnp.einsum("bcqn,bcqhp->bchnp", Bm.astype(cd), u,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence
    def step(h, inp):
        st, tot = inp                                   # (B,H,N,P), (B,H)
        h_new = h * jnp.exp(tot)[:, :, None, None] + st
        return h_new, h                                 # emit state BEFORE chunk

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)  # carried state stays f32
    _, h_prev = jax.lax.scan(step, h0,
                             (states.swapaxes(0, 1), total.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                      # (B,nc,H,N,P)

    # contribution of carried state: y += C_i . h_prev * exp(seg_i)
    ch = jnp.einsum("bcqn,bchnp->bcqhp", Cm.astype(cd), h_prev.astype(cd),
                    preferred_element_type=jnp.float32)   # contract n first
    y_off = ch * jnp.exp(seg)[..., None]
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y[:, :S_orig]


def ssm_forward(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Full-sequence SSD block. x: (B,S,d) -> (B,S,d)."""

    di, nh, P, N, W = _dims(cfg)
    proj = x @ p["w_in"]
    z, xin, Bm, Cm, dt = _split_in(cfg, proj)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xin, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xin.reshape(*xin.shape[:2], nh, P)
    # shard SSD heads over the model axis: the (B, nc, Q, Q, H) decay
    # tensor is the memory hot-spot and follows these constraints
    xh = lc(xh, "batch", "seq", "heads", None)
    dt = lc(dt, "batch", "seq", "heads")
    cd = jnp.dtype(cfg.ssd_dtype)
    y = ssd_chunked(xh.astype(cd), dt, A, Bm.astype(cd),
                    Cm.astype(cd), cfg.ssm.chunk, compute_dtype=cd)
    y = lc(y, "batch", "seq", "heads", None)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*xin.shape[:2], di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    return lc(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# O(1) decode
# ---------------------------------------------------------------------------


def ssm_state_specs(cfg: ArchConfig, batch: int, dtype=None) -> dict:
    di, nh, P, N, W = _dims(cfg)
    conv_ch = di + 2 * N
    # the conv ring carries activations, so it follows the params' dtype
    # (the prefill scan's carry must type-match the body's conv output);
    # the SSM recurrence h stays float32 regardless — accumulation error
    # compounds over the whole sequence
    dt = dtype if dtype is not None else DEFAULT_DTYPE
    return {
        "h": PSpec((batch, nh, N, P), ("cache_batch", "heads", None, None),
                   init="zeros", dtype=jnp.float32),
        "conv": PSpec((batch, W - 1, conv_ch), ("cache_batch", None, "mlp"),
                      init="zeros", dtype=dt),
    }


def ssm_decode_step(p: dict, cfg: ArchConfig, x: jax.Array, state: dict
                    ) -> tuple[jax.Array, dict]:
    """One-token SSD step. x: (B,1,d)."""

    di, nh, P, N, W = _dims(cfg)
    proj = x[:, 0] @ p["w_in"]                           # (B, ...)
    z, xin, Bm, Cm, dt = _split_in(cfg, proj)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)    # (B, C)
    hist = jnp.concatenate([state["conv"], conv_in[:, None, :]], axis=1)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"])
    new_conv = hist[:, 1:, :]
    xin, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xin.reshape(-1, nh, P).astype(jnp.float32)
    decay = jnp.exp(dt * A[None, :])                     # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhnp", Bm.astype(jnp.float32), dt, xh)
    h = state["h"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, di).astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["w_out"])[:, None, :]
    return out, {"h": h, "conv": new_conv}


def ssm_prefill_step(p: dict, cfg: ArchConfig, x: jax.Array, state: dict,
                     valid: jax.Array) -> tuple[jax.Array, dict]:
    """Advance a chunk of T tokens through the decode-state recurrence:
    a ``lax.scan`` of :func:`ssm_decode_step` over the chunk dimension.

    x: (B, T, d); valid: (B, T) — padding tokens (rows past a serving
    slot's remaining prompt) must be inert, so the per-token state
    update is gated: an invalid token leaves (h, conv) untouched.
    Returns (y (B, T, d), new state)."""

    B, T, d = x.shape

    def body(st, inp):
        xt, vt = inp                                    # (B, d), (B,)
        y, st_new = ssm_decode_step(p, cfg, xt[:, None, :], st)
        gated = jax.tree.map(
            lambda new, old: jnp.where(
                vt.reshape((B,) + (1,) * (new.ndim - 1)), new, old),
            st_new, st)
        return gated, y[:, 0]

    st, ys = jax.lax.scan(body, state,
                          (x.swapaxes(0, 1), valid.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), st


__all__ = ["ssm_specs", "ssm_forward", "ssm_state_specs", "ssm_decode_step",
           "ssm_prefill_step", "ssd_chunked"]
