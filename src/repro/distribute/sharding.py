"""Logical-axis sharding: rules tables + mesh context.

Model code names tensor dims with *logical* axes ("batch", "embed",
"heads", "mlp", "vocab", "experts", ...).  A :class:`Rules` table maps
logical axes to mesh axes; :func:`logical_constraint` applies
``with_sharding_constraint`` when a mesh context is active and is a
no-op otherwise (CPU smoke tests).

The rules table is *the auto-tuner's action space* for distributed
configs: changing ``embed -> "data"`` turns on FSDP-style parameter
sharding, ``experts -> "model"`` turns on expert parallelism,
``seq -> "model"`` turns on sequence parallelism for long-context
decode, etc.  `launch/dryrun.py` re-lowers under mutated rules and the
roofline terms quantify the effect — the paper's "tune against the
machine model, not the hardware" loop.
"""

from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Any, Mapping

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Rules:
    """logical axis -> mesh axis (str), tuple of mesh axes, or None."""

    table: tuple[tuple[str, Any], ...]

    @staticmethod
    def make(**kw) -> "Rules":
        return Rules(tuple(sorted(kw.items())))

    def get(self, name: str | None):
        if name is None:
            return None
        d = dict(self.table)
        return d.get(name)

    def replace(self, **kw) -> "Rules":
        d = dict(self.table)
        d.update(kw)
        return Rules(tuple(sorted(d.items())))

    def spec(self, axes: tuple[str | None, ...]) -> P:
        """Resolve logical axes to a PartitionSpec, dropping duplicate
        mesh-axis uses (first dim wins, like flax partitioning)."""

        used: set[str] = set()
        out = []
        for a in axes:
            m = self.get(a)
            if m is None:
                out.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(x for x in ms if x not in used)
            used.update(ms)
            out.append(ms[0] if len(ms) == 1 else (ms if ms else None))
        return P(*out)


def default_rules(multi_pod: bool = False) -> Rules:
    batch = ("pod", "data") if multi_pod else ("data",)
    return Rules.make(
        batch=batch,        # data parallel over pod+data axes
        seq=None,           # sequence parallelism off by default
        embed=batch,        # FSDP: weights' embed dim sharded over dp axes
                            # (v5e 16 GB/chip demands it; weights are
                            # all-gathered per layer — ZeRO-3 semantics.
                            # Activations never pick this up: their batch
                            # dim claims the data axes first.)
        heads="model",      # tensor parallel attention
        kv_heads="model",
        mlp="model",        # tensor parallel MLP
        vocab="model",      # sharded embedding/logits
        experts="model",    # expert parallelism (MoE archs w/ many experts)
        expert_mlp=None,    # per-expert d_ff sharding (mixtral-style TP)
        state=None,         # SSM state dim
        cache_batch=batch,  # decode KV cache: shard over batch
        cache_seq="model",  # ... and over cache length when kv_heads can't
        head_dim=None,      # alternative cache TP dim (ring update stays
                            # local; tuner may prefer it over cache_seq)
        layers=None,
    )


@dataclass
class MeshCtx:
    mesh: Mesh
    rules: Rules


_CTX: contextvars.ContextVar[MeshCtx | None] = contextvars.ContextVar(
    "repro_mesh_ctx", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Rules):
    token = _CTX.set(MeshCtx(mesh, rules))
    try:
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
                else contextlib.nullcontext():
            yield
    finally:
        _CTX.reset(token)


def current_ctx() -> MeshCtx | None:
    return _CTX.get()


def logical_constraint(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain an intermediate to its logical sharding (no-op without a
    mesh context)."""

    ctx = _CTX.get()
    if ctx is None:
        return x
    spec = ctx.rules.spec(tuple(axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def named_sharding(axes: tuple[str | None, ...]) -> NamedSharding | None:
    ctx = _CTX.get()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, ctx.rules.spec(axes))


def tree_shardings(axes_tree, mesh: Mesh, rules: Rules):
    """PartitionSpec tree for a logical-axes tree (for pjit in/out)."""

    return jax.tree.map(
        lambda axes: NamedSharding(mesh, rules.spec(tuple(axes))),
        axes_tree, is_leaf=lambda t: isinstance(t, tuple) and
        all(isinstance(a, (str, type(None))) for a in t))


# Logical names a weight may fall back to for "model"-axis sharding when
# its canonical dim is not divisible by the mesh axis (e.g. 20 heads on a
# 16-way model axis -> shard the embed dim instead: row-parallel).
FALLBACK_NAMES = ("embed", "heads", "kv_heads", "mlp", "expert_mlp",
                  "vocab", "experts")


def arg_sharding(shape: tuple[int, ...], axes: tuple[str | None, ...],
                 mesh: Mesh, rules: Rules) -> NamedSharding:
    """Shape-aware sharding for *jit arguments* (which, unlike internal
    constraints, must divide evenly).

    Pass 1 applies the rules table where divisible; pass 2 guarantees
    weights still get a "model"-axis shard by falling back to the first
    divisible FALLBACK dim when the canonical one is not divisible."""

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def ax_size(m) -> int:
        ms = (m,) if isinstance(m, str) else tuple(m)
        n = 1
        for a in ms:
            n *= sizes[a]
        return n

    used: set[str] = set()
    out: list = [None] * len(axes)
    for i, name in enumerate(axes):
        m = rules.get(name)
        if m is None:
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a not in used)
        if not ms:
            continue
        if shape[i] % ax_size(ms) != 0:
            continue
        used.update(ms)
        out[i] = ms[0] if len(ms) == 1 else ms

    model_used = any(
        (o == "model") or (isinstance(o, tuple) and "model" in o)
        for o in out)
    if not model_used and "model" in sizes:
        for i, name in enumerate(axes):
            if out[i] is None and name in FALLBACK_NAMES and \
                    shape[i] % sizes["model"] == 0 and shape[i] > 1:
                out[i] = "model"
                break
    return NamedSharding(mesh, P(*out))


def shard_like(abstract_tree, axes_tree, mesh: Mesh, rules: Rules):
    """Shape-aware sharding tree for an abstract (ShapeDtypeStruct) tree
    + matching logical-axes tree."""

    is_axes_leaf = lambda t: isinstance(t, tuple) and all(
        isinstance(a, (str, type(None))) for a in t)
    return jax.tree.map(
        lambda leaf, axes: arg_sharding(tuple(leaf.shape), tuple(axes),
                                        mesh, rules),
        abstract_tree, axes_tree,
        is_leaf=lambda t: hasattr(t, "shape") and not isinstance(t, tuple))


__all__ = ["Rules", "default_rules", "use_mesh", "current_ctx",
           "logical_constraint", "named_sharding", "tree_shardings", "MeshCtx"]
