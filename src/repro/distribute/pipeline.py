"""Pipeline parallelism: GPipe microbatch schedule over a "pp" mesh axis.

The stacked layer-blocks pytree is sharded over "pp" (each stage holds a
contiguous slice); microbatch activations flow stage-to-stage via
``lax.ppermute`` inside a ``shard_map``.  The schedule runs
``M + P − 1`` ticks; stage p processes microbatch ``t − p`` at tick t
(classic GPipe bubbles).  Because the schedule is pure JAX, reverse-mode
autodiff through the scan+ppermute yields the backward pipeline
automatically (cooldown order), so the same function serves training.

This complements the DP/TP/EP/FSDP axes of the main mesh: for depth-
dominated models a "pp" axis can replace part of "model"
(mesh (pp, data, model)); the dry-run exercises it via
tests/test_pipeline.py on an 8-device host mesh.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def gpipe(stage_fn: Callable, stage_params, x: jax.Array, *, mesh: Mesh,
          microbatches: int, axis: str = "pp") -> jax.Array:
    """Run ``x`` through P pipeline stages.

    stage_fn(params_stage, act) -> act applies ONE stage's layer slice;
    stage_params: pytree with leading dim = total stages' units stacked,
    shardable over ``axis`` (leading dim must equal the axis size);
    x: (B, ...) with B divisible by ``microbatches``."""

    n_stages = mesh.shape[axis]
    M = microbatches
    B = x.shape[0]
    assert B % M == 0, (B, M)
    xm = x.reshape(M, B // M, *x.shape[1:])

    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_stage(params_local, xm_rep):
        p = jax.lax.axis_index(axis)
        T = M + n_stages - 1
        zero = jnp.zeros_like(xm_rep[0])

        def tick(carry, t):
            prev_out, outs = carry
            recv = jax.lax.ppermute(prev_out, axis, perm)
            mb = t - p
            active = (mb >= 0) & (mb < M)
            mb_c = jnp.clip(mb, 0, M - 1)
            inp = jnp.where(p == 0, xm_rep[mb_c], recv)
            out = stage_fn(params_local, inp)
            out = jnp.where(active, out, zero)
            write = ((p == n_stages - 1) & active).astype(out.dtype)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, outs[mb_c] * (1 - write) + out * write, mb_c, 0)
            return (out, outs), None

        (last, outs), _ = jax.lax.scan(
            tick, (zero, jnp.zeros_like(xm_rep)), jnp.arange(T))
        # only the last stage holds real outputs; psum replicates them
        return jax.lax.psum(outs, axis)

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh, in_specs=(spec_params, P()),
                   out_specs=P(), check_rep=False)
    outs = fn(stage_params, xm)
    return outs.reshape(B, *x.shape[1:])


__all__ = ["gpipe"]
