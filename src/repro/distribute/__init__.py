"""Distribution: logical-axis sharding rules, mesh context, pjit helpers."""

from .sharding import (Rules, default_rules, logical_constraint,
                       tree_shardings, use_mesh)

__all__ = ["Rules", "default_rules", "logical_constraint", "tree_shardings",
           "use_mesh"]
