"""Online model-conformance monitor for a live paged allocator.

PR 9's direction-2 check (:func:`repro.verify.conformance.trace_accepted`)
validated recorded allocator traces offline, in tests.  This module
runs the SAME check continuously against a serving drain: the monitor
enables the allocator's ``trace`` hook, and every
:meth:`ConformanceMonitor.poll` (the engine calls it at the end of each
tick) feeds the ops recorded since the last poll through an incremental
:class:`~repro.verify.conformance.TraceChecker` — every real op must be
a legal model transition returning exactly what the model returns —
and then compares the real allocator's full state projection against
the tracked model state.  The projection compare is the teeth: a
mutation whose returns still agree (a leaked refcount, a stale page
table entry) is caught at the first poll after the bad op.

On violation the monitor freezes with a diagnosis and can dump a
*replayable trail*: the complete op history in exactly the JSON format
``python -m repro.verify replay --trail`` consumes, with the allocator
field naming the planted mutant when the live allocator is one (the
e2e test's loop: mutant trips monitor -> trail -> CLI reproduces a
real failure).  A bounded sliding ``window`` of recent records rides
along in reports for at-a-glance context; the full history is capped
at ``max_trail`` ops (past the cap the trail is marked
non-replayable rather than silently truncated into a bogus repro).
"""

from __future__ import annotations

import dataclasses
import json
import os
from collections import deque
from typing import Any

from ..runtime.kv import PagedKVAllocator
from ..verify.conformance import ConformanceError, TraceChecker
from ..verify.models import AllocConfig, AllocatorSemantics


def _jsonable_ret(ret: Any) -> Any:
    if isinstance(ret, (list, tuple)):
        return [list(p) if isinstance(p, (list, tuple)) else p
                for p in ret]
    if isinstance(ret, bool) or ret is None:
        return ret
    return int(ret)


def thaw_ret(ret: Any) -> Any:
    """JSON round-tripped return -> the form ``_norm`` produces
    (pair lists refreeze to tuples of tuples)."""

    if isinstance(ret, list):
        return tuple(tuple(p) for p in ret)
    return ret


class ConformanceMonitor:
    def __init__(self, alloc: PagedKVAllocator, *, window: int = 256,
                 max_trail: int = 200_000, strict: bool = False):
        spec = alloc.spec
        self.alloc = alloc
        self.cfg = AllocConfig(n_slots=alloc.n_slots,
                               page_size=spec.page_size,
                               pages_per_slot=spec.pages_per_slot,
                               n_pages=spec.n_pages)
        self.sem = AllocatorSemantics(self.cfg, canonical=False)
        self.checker = TraceChecker(self.sem)
        self.strict = strict
        if alloc.trace is None:
            alloc.trace = []
        self._consumed = len(alloc.trace)
        self.window: deque[tuple] = deque(maxlen=window)
        self.ops: list[tuple] = []      # full (method, args) history
        self.max_trail = max_trail
        self.truncated = False
        self.ops_checked = 0
        self.polls = 0
        self.violation: dict | None = None

    @property
    def allocator_name(self) -> str:
        """``MUTANTS`` key when the live allocator is a planted mutant,
        ``"real"`` otherwise — resolved at call time so a class swapped
        in after construction (the e2e test's planting move) is still
        named correctly in the dumped trail."""

        from ..verify.mutants import MUTANTS
        cls = type(self.alloc)
        return next((k for k, v in MUTANTS.items() if cls is v), "real")

    @property
    def accepted(self) -> bool:
        return self.violation is None

    def poll(self, tick: int | None = None) -> bool:
        """Consume and check ops recorded since the last poll, then
        compare state projections.  Returns True while conformant; once
        violated the monitor latches (``strict=True`` raises
        instead)."""

        if self.violation is not None:
            return False
        self.polls += 1
        trace = self.alloc.trace
        new = trace[self._consumed:]
        self._consumed = len(trace)
        for record in new:
            method, args, _ret = record
            self.window.append(record)
            if len(self.ops) < self.max_trail:
                self.ops.append((method, *args))
            else:
                self.truncated = True
            try:
                self.checker.feed(record)
            except ConformanceError as exc:
                return self._violate(str(exc), tick)
            self.ops_checked += 1
        divergence = self.checker.state_divergence(self.alloc)
        if divergence is not None:
            return self._violate(divergence, tick)
        return True

    def _violate(self, message: str, tick: int | None) -> bool:
        self.violation = {"message": message, "tick": tick,
                          "op_index": self.ops_checked,
                          "allocator": self.allocator_name}
        if self.strict:
            raise ConformanceError(
                f"online conformance violation (tick {tick}): {message}")
        return False

    def report(self) -> dict:
        """Status summary embedded in exported traces; ``window`` holds
        the most recent records (with returns) for context, ``records``
        the full history when it fits — enough for ``python -m
        repro.obs check`` to re-run the offline check."""

        rep = {
            "status": "accepted" if self.accepted else "violation",
            "allocator": self.allocator_name,
            "config": dataclasses.asdict(self.cfg),
            "ops_checked": self.ops_checked,
            "polls": self.polls,
            "truncated": self.truncated,
            "window": [[m, list(a), _jsonable_ret(r)]
                       for m, a, r in self.window],
            "violation": self.violation,
        }
        if not self.truncated:
            rep["records"] = [[m, list(a), _jsonable_ret(r)]
                              for m, a, r in self.alloc.trace]
        return rep

    def trail(self) -> dict:
        """The replayable counterexample payload, in exactly the format
        ``python -m repro.verify replay --trail`` consumes."""

        v = self.violation or {}
        return {
            "model": "allocator",
            "allocator": self.allocator_name,
            "config": dataclasses.asdict(self.cfg),
            "ops": [list(op) for op in self.ops],
            "message": v.get("message", "no violation"),
            "source": "repro.obs online conformance monitor",
            "replayable": not self.truncated,
        }

    def dump_trail(self, path: str) -> dict:
        payload = self.trail()
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1)
            f.write("\n")
        os.replace(tmp, path)
        return payload


__all__ = ["ConformanceMonitor", "thaw_ret"]
