"""Per-tick phase profiling on the ``time_fn`` discipline.

The serving tick interleaves jitted dispatches (decode step, verify
chunk, commit/prefill step, COW page copies) with host-side work
(admission, draft proposal, bookkeeping).  Because dispatches are
asynchronous, naive wall-clock around a dispatch measures launch
latency, not compute — so when profiling is on, the engine hands each
phase's output to :meth:`PhaseProfiler.phase_end` and the profiler
``jax.block_until_ready``-syncs it INSIDE the timed region, exactly
the discipline :func:`repro.kernels.common.time_fn` uses.  The
residual between a tick's wall time and its summed phase times is
attributed to ``host`` (scheduling, drafting, numpy bookkeeping).

Blocking per phase serializes the tick's dispatch overlap, so a
profiled drain is slower than a traced-only drain — profiling is a
diagnosis mode (``--profile``), never on by default.  ``warmup_ticks``
excludes the first ticks (step compiles) from the totals.
"""

from __future__ import annotations

import time

import jax


class PhaseProfiler:
    """Accumulates blocking per-phase durations across ticks."""

    def __init__(self, warmup_ticks: int = 1):
        self.warmup_ticks = warmup_ticks
        self.ticks = 0
        self.totals_us: dict[str, float] = {}
        self.counts: dict[str, int] = {}
        self._tick_t0: float | None = None
        self._tick_phase_us = 0.0

    # -- tick bracket ------------------------------------------------------

    def tick_begin(self) -> None:
        self._tick_t0 = time.perf_counter()
        self._tick_phase_us = 0.0

    def tick_end(self) -> None:
        if self._tick_t0 is None:
            return
        wall_us = (time.perf_counter() - self._tick_t0) * 1e6
        self._tick_t0 = None
        self.ticks += 1
        if self.ticks <= self.warmup_ticks:
            return
        host = max(0.0, wall_us - self._tick_phase_us)
        self.totals_us["host"] = self.totals_us.get("host", 0.0) + host
        self.counts["host"] = self.counts.get("host", 0) + 1

    # -- phases ------------------------------------------------------------

    def phase_begin(self) -> float:
        return time.perf_counter()

    def phase_end(self, name: str, t0: float, sync=None) -> float:
        """Close a phase opened by :meth:`phase_begin`; ``sync`` (any
        pytree of jax arrays) is blocked on before the clock is read,
        so the duration covers the device work the phase launched."""

        if sync is not None:
            jax.block_until_ready(sync)
        dur_us = (time.perf_counter() - t0) * 1e6
        self._tick_phase_us += dur_us
        if self.ticks >= self.warmup_ticks:
            self.totals_us[name] = self.totals_us.get(name, 0.0) + dur_us
            self.counts[name] = self.counts.get(name, 0) + 1
        return dur_us

    # -- results -----------------------------------------------------------

    def report(self) -> dict[str, dict[str, float]]:
        """Per-phase ``{total_us, count, mean_us, share}`` (share of the
        summed phase time, warmup excluded)."""

        grand = sum(self.totals_us.values())
        out: dict[str, dict[str, float]] = {}
        for name in sorted(self.totals_us,
                           key=lambda n: -self.totals_us[n]):
            tot, cnt = self.totals_us[name], self.counts.get(name, 0)
            out[name] = {"total_us": tot, "count": float(cnt),
                         "mean_us": tot / cnt if cnt else 0.0,
                         "share": tot / grand if grand else 0.0}
        return out

    def format(self) -> str:
        rows = self.report()
        if not rows:
            return "phase profile: no samples (all ticks in warmup?)"
        width = max(len(n) for n in rows)
        lines = [f"phase profile ({self.ticks} ticks, "
                 f"{self.warmup_ticks} warmup):"]
        for name, r in rows.items():
            lines.append(f"  {name:<{width}}  total {r['total_us']:>10.0f} us"
                         f"  mean {r['mean_us']:>8.1f} us"
                         f"  x{int(r['count']):<5d} {r['share']:6.1%}")
        return "\n".join(lines)


__all__ = ["PhaseProfiler"]
