"""``python -m repro.obs`` — summarize / check / export recorded traces.

* ``summarize TRACE.json`` — human-readable digest: envelope, event
  counts, span totals by name, workload latency summary (when the
  drain was driven by :func:`repro.runtime.workload.drive_trace`),
  phase breakdown, monitor status.
* ``check TRACE.json [--json]`` — machine gate: schema + clock
  validation via :func:`repro.obs.trace.validate_trace`, plus an
  OFFLINE re-run of the direction-2 conformance check over the
  allocator records embedded by the online monitor.  Exit 1 on any
  schema problem or conformance violation — the CI obs smoke step.
* ``export TRACE.json --out chrome.json`` — strip the envelope down to
  the pure Chrome trace-event document (some external viewers reject
  unknown top-level keys; Perfetto loads the full artifact as-is).
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import Counter as _Counter


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _span_totals(events: list[dict]) -> dict[str, tuple[int, float]]:
    from .trace import spans_from_events
    totals: dict[str, tuple[int, float]] = {}

    def walk(spans):
        for sp in spans:
            n, dur = totals.get(sp.name, (0, 0.0))
            totals[sp.name] = (n + 1, dur + sp.dur)
            walk(sp.children)

    walk(spans_from_events(events))
    return totals


def _recheck_monitor(mon: dict) -> tuple[str, str]:
    """Re-run trace_accepted over the embedded records.  Returns
    (status, detail): ``accepted`` / ``violation`` / ``skipped``."""

    records = mon.get("records")
    if records is None:
        return ("skipped", "no embedded records "
                "(trail truncated or monitor absent)")
    from ..verify.conformance import ConformanceError, trace_accepted
    from ..verify.models import AllocConfig, AllocatorSemantics
    from .monitor import thaw_ret
    sem = AllocatorSemantics(AllocConfig(**mon["config"]),
                             canonical=False)
    trace = [(m, tuple(args), thaw_ret(ret))
             for m, args, ret in records]
    try:
        trace_accepted(sem, trace)
    except ConformanceError as exc:
        return ("violation", str(exc))
    return ("accepted", f"{len(trace)} allocator ops re-checked")


def _cmd_summarize(args: argparse.Namespace) -> int:
    from .trace import parse_trace
    doc = _load(args.trace)
    events = parse_trace(doc)
    meta = doc.get("meta", {})
    print(f"{args.trace}: {doc.get('kind')} schema {doc.get('schema')}")
    print(f"  created {meta.get('created_utc', '?')} on "
          f"{meta.get('host', '?')} ({meta.get('machine', '?')})")
    phs = _Counter(ev["ph"] for ev in events)
    print(f"  events: {len(events)} "
          f"({', '.join(f'{k}={v}' for k, v in sorted(phs.items()))})")
    try:
        totals = _span_totals(events)
    except ValueError as exc:
        print(f"  spans: UNPAIRABLE ({exc})")
        totals = {}
    for name in sorted(totals, key=lambda n: -totals[n][1]):
        n, dur = totals[name]
        print(f"    {name:<16} x{n:<6d} total {dur / 1e3:9.2f} ms  "
              f"mean {dur / n:9.1f} us")
    from ..runtime.workload import records_from_events, summarize
    records = records_from_events(events)
    done = {k: r for k, r in records.items() if "finish" in r}
    if done:
        ticks = max(r["finish"] for r in done.values())
        s = summarize(done, ticks)
        print(f"  workload: {int(s['requests'])} requests over "
              f"{int(s['ticks'])} ticks; p50/p99 all "
              f"{s['p50_all']:.0f}/{s['p99_all']:.0f} ticks; "
              f"SLO attainment {s['slo_attainment']:.1%}; "
              f"goodput {s['goodput_per_tick']:.2f} tok/tick")
    phases = doc.get("phases")
    if phases:
        print("  phases (profiled, device-synced):")
        for name, r in phases.items():
            print(f"    {name:<12} total {r['total_us']:>10.0f} us  "
                  f"mean {r['mean_us']:>8.1f} us  {r['share']:6.1%}")
    mon = doc.get("monitor")
    if mon:
        print(f"  monitor: {mon['status']} ({mon['ops_checked']} ops, "
              f"{mon['polls']} polls, allocator={mon['allocator']})")
        if mon.get("violation"):
            print(f"    {mon['violation']['message']}")
    return 0


def _cmd_check(args: argparse.Namespace) -> int:
    from .trace import parse_trace, validate_trace
    doc = _load(args.trace)
    problems = validate_trace(doc)
    mon = doc.get("monitor")
    stored = mon["status"] if mon else "absent"
    recheck, detail = (_recheck_monitor(mon) if mon
                       else ("skipped", "no monitor section"))
    if mon and recheck != "skipped" and recheck != stored:
        problems.append(f"monitor section says {stored!r} but offline "
                        f"re-check says {recheck!r}")
    ok = not problems and stored != "violation" and \
        recheck != "violation"
    report = {
        "ok": ok,
        "trace": args.trace,
        "events": len(doc.get("traceEvents", [])) if isinstance(
            doc.get("traceEvents"), list) else 0,
        "problems": problems,
        "monitor": stored,
        "monitor_recheck": recheck,
        "monitor_detail": detail,
    }
    if not problems:
        report["spans"] = sum(
            1 for ev in parse_trace(doc) if ev["ph"] == "B")
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print(f"{args.trace}: "
              f"{'OK' if ok else 'FAILED'} — {len(problems)} schema "
              f"problem(s), monitor {stored} (re-check: {recheck}, "
              f"{detail})")
        for p in problems:
            print(f"  - {p}")
    return 0 if ok else 1


def _cmd_export(args: argparse.Namespace) -> int:
    doc = _load(args.trace)
    out = {"displayTimeUnit": doc.get("displayTimeUnit", "ms"),
           "traceEvents": doc["traceEvents"]}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {len(out['traceEvents'])} events -> {args.out}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="summarize / check / export repro.obs traces")
    sub = parser.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="human-readable trace digest")
    p.add_argument("trace")
    p.set_defaults(fn=_cmd_summarize)

    p = sub.add_parser("check", help="schema + conformance gate "
                                     "(exit 1 on failure)")
    p.add_argument("trace")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_check)

    p = sub.add_parser("export", help="strip to pure Chrome trace JSON")
    p.add_argument("trace")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=_cmd_export)

    args = parser.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
