"""Metrics registry: counters, gauges, and log-bucket histograms.

One :class:`MetricsRegistry` per measurement scope (a drain, a tuning
plan run, a calibration pass).  Producers grab an instrument by name —
``registry.counter("serve.retired").inc()`` — and every instrument is
created on first touch, so publishing code never pre-declares schemas.
Consumers read either :meth:`MetricsRegistry.snapshot` (a plain nested
dict, the programmatic API the drain harnesses rebuild their
``stats_out`` shims from) or :meth:`MetricsRegistry.to_prometheus`
(text exposition in the Prometheus format, the operator surface behind
``python -m repro.launch.serve --metrics``).

Histograms are log-bucketed (power-of-two upper edges): an observation
``v`` lands in the bucket whose upper edge is the smallest ``2**k >=
v``.  That keeps per-instrument state O(log range) — queue waits span
one tick to tens of thousands — while still answering p50/p99 queries
to within a factor of two, which is the right resolution for a tick
clock (exact percentiles for latency come from the trace spans, see
:mod:`repro.obs.trace`).

Nothing here touches jax or the runtime: the module is importable from
anywhere (tunables, benchmarks, calibrate) without cycles.
"""

from __future__ import annotations

import math
from typing import Iterator

LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


def _prom_name(name: str) -> str:
    """Dotted registry names -> Prometheus-legal metric names."""

    return "".join(c if (c.isalnum() or c == "_") else "_" for c in name)


class Counter:
    """Monotonic accumulator; ``inc`` with a negative amount raises."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increments must be >= 0, "
                             f"got {amount}")
        self.value += amount


class Gauge:
    """Last-write-wins scalar (plus inc/dec for level tracking)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Log-bucket (base-2) histogram.

    ``buckets`` maps bucket exponent ``k`` to a count of observations
    ``v`` with ``2**(k-1) < v <= 2**k`` (``k=0`` holds ``v <= 1``,
    non-positive observations included).  ``sum``/``count`` give exact
    totals; :meth:`quantile` answers from bucket upper edges, so it is
    exact-to-a-factor-of-two, never an underestimate by more."""

    __slots__ = ("buckets", "sum", "count")

    def __init__(self) -> None:
        self.buckets: dict[int, int] = {}
        self.sum = 0.0
        self.count = 0

    @staticmethod
    def bucket_of(value: float) -> int:
        if value <= 1.0:
            return 0
        return max(0, math.ceil(math.log2(value)))

    def observe(self, value: float) -> None:
        k = self.bucket_of(float(value))
        self.buckets[k] = self.buckets.get(k, 0) + 1
        self.sum += float(value)
        self.count += 1

    def quantile(self, q: float) -> float:
        """Upper edge of the bucket containing the ``q``-quantile
        observation (0 when the histogram is empty)."""

        if not self.count:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        rank = q * self.count
        seen = 0
        for k in sorted(self.buckets):
            seen += self.buckets[k]
            if seen >= rank:
                return float(2 ** k)
        return float(2 ** max(self.buckets))

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Name -> instrument store with create-on-first-touch semantics.

    A name is bound to one instrument kind for the registry's lifetime;
    asking for the same name as a different kind raises (catching the
    classic counter-vs-gauge publishing bug at the call site)."""

    def __init__(self) -> None:
        self._metrics: dict[tuple[str, LabelKey], object] = {}
        self._kinds: dict[str, str] = {}
        self._help: dict[str, str] = {}

    def _get(self, kind: str, cls, name: str, help: str,
             labels: dict[str, str]):
        bound = self._kinds.setdefault(name, kind)
        if bound != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{bound}, requested {kind}")
        if help and name not in self._help:
            self._help[name] = help
        key = (name, _label_key(labels))
        inst = self._metrics.get(key)
        if inst is None:
            inst = self._metrics[key] = cls()
        return inst

    def counter(self, name: str, help: str = "",
                **labels: str) -> Counter:
        return self._get("counter", Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels: str) -> Gauge:
        return self._get("gauge", Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  **labels: str) -> Histogram:
        return self._get("histogram", Histogram, name, help, labels)

    def __iter__(self) -> Iterator[tuple[str, LabelKey, object]]:
        for (name, key), inst in sorted(self._metrics.items()):
            yield name, key, inst

    def collect(self, prefix: str) -> dict[str, float]:
        """Unlabelled scalar values under ``prefix.`` keyed by the name
        remainder — the back-compat bridge that rebuilds the drain
        harnesses' ``stats_out`` dicts from registry state."""

        dot = prefix + "."
        out: dict[str, float] = {}
        for name, key, inst in self:
            if key or not name.startswith(dot):
                continue
            if isinstance(inst, (Counter, Gauge)):
                out[name[len(dot):]] = inst.value
        return out

    def snapshot(self) -> dict[str, dict]:
        """Plain-data view of every instrument: ``{"counters": {...},
        "gauges": {...}, "histograms": {name: {count,sum,p50,p99,
        buckets}}}`` with labelled series keyed
        ``name{label="value"}``."""

        snap: dict[str, dict] = {"counters": {}, "gauges": {},
                                 "histograms": {}}
        for name, key, inst in self:
            label = name + _label_str(key)
            if isinstance(inst, Counter):
                snap["counters"][label] = inst.value
            elif isinstance(inst, Gauge):
                snap["gauges"][label] = inst.value
            else:
                assert isinstance(inst, Histogram)
                snap["histograms"][label] = {
                    "count": inst.count, "sum": inst.sum,
                    "mean": inst.mean(),
                    "p50": inst.quantile(0.5),
                    "p99": inst.quantile(0.99),
                    "buckets": {str(k): v for k, v
                                in sorted(inst.buckets.items())},
                }
        return snap

    def to_prometheus(self) -> str:
        """Text exposition (Prometheus format): HELP/TYPE headers per
        family, cumulative ``_bucket{le=...}`` series plus ``_sum`` /
        ``_count`` for histograms."""

        lines: list[str] = []
        seen_header: set[str] = set()
        for name, key, inst in self:
            pname = _prom_name(name)
            if name not in seen_header:
                seen_header.add(name)
                if name in self._help:
                    lines.append(f"# HELP {pname} {self._help[name]}")
                lines.append(f"# TYPE {pname} {self._kinds[name]}")
            ls = _label_str(key)
            if isinstance(inst, (Counter, Gauge)):
                lines.append(f"{pname}{ls} {inst.value:g}")
            else:
                assert isinstance(inst, Histogram)
                cum = 0
                for k in sorted(inst.buckets):
                    cum += inst.buckets[k]
                    edge = _label_key({"le": f"{2 ** k:g}"})
                    lines.append(f"{pname}_bucket"
                                 f"{_label_str(key + edge)} {cum}")
                inf = _label_key({"le": "+Inf"})
                lines.append(f"{pname}_bucket{_label_str(key + inf)} "
                             f"{inst.count}")
                lines.append(f"{pname}_sum{ls} {inst.sum:g}")
                lines.append(f"{pname}_count{ls} {inst.count}")
        return "\n".join(lines) + ("\n" if lines else "")


__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]
