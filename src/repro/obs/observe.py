"""The observability bundle a :class:`~repro.runtime.serve.Server`
publishes into.

``Observability`` composes the four obs facilities behind one object
the engine can hold and null-check: a :class:`.trace.TraceRecorder`
(lifecycle + tick spans), a :class:`.metrics.MetricsRegistry`
(counters/gauges/histograms), an optional :class:`.profile.PhaseProfiler`
(per-tick phase attribution with device sync), and an optional
:class:`.monitor.ConformanceMonitor` (the online direction-2 model
check on the paged allocator's op stream).  Construct one, pass it as
``Server(..., obs=...)``, drain, then :meth:`export` the combined
document — which is simultaneously the schema'd trace artifact and a
Perfetto-loadable timeline.

The engine's contract is narrow: every hook is a no-op-cheap method
call guarded by ``if self.obs is not None`` at the call site, and NO
hook touches device values (everything recorded is host state the
engine already materialized), so attaching observability cannot change
a drain's outputs.  Only ``profile=True`` alters timing, by
``block_until_ready``-syncing each phase — a diagnosis mode.

Tick stamps on request/slot tracks are the SERVER tick clock
(``server.ticks``); workload-level events driven on the driver clock
(:func:`repro.runtime.workload.drive_trace`) carry their driver-clock
values in ``args`` instead, keeping every track's ``tick`` field
monotone (the property :func:`.trace.validate_trace` enforces).
"""

from __future__ import annotations

import time
from typing import Any

from .metrics import MetricsRegistry
from .monitor import ConformanceMonitor
from .profile import PhaseProfiler
from .trace import TraceRecorder, export_trace


class Observability:
    def __init__(self, *, trace: bool = True, metrics: bool = True,
                 profile: bool = False, monitor: bool = False,
                 monitor_window: int = 256, strict: bool = False,
                 profile_warmup_ticks: int = 1):
        self.recorder = TraceRecorder() if trace else None
        self.registry = MetricsRegistry() if metrics else None
        self.profiler = (PhaseProfiler(warmup_ticks=profile_warmup_ticks)
                         if profile else None)
        self._want_monitor = monitor
        self._strict = strict
        self._monitor_window = monitor_window
        self.monitor: ConformanceMonitor | None = None
        self._server = None
        self._req_ticks: dict[int, dict[str, int]] = {}

    # -- lifecycle ---------------------------------------------------------

    def attach(self, server) -> None:
        if self._server is not None and self._server is not server:
            raise ValueError("Observability is per-Server state; build "
                             "one bundle per Server")
        self._server = server
        if self._want_monitor:
            if server.alloc is None:
                raise ValueError("monitor=True needs Server(paged=True): "
                                 "the conformance monitor checks the "
                                 "paged allocator's op stream")
            self.monitor = ConformanceMonitor(
                server.alloc, window=self._monitor_window,
                strict=self._strict)

    # -- request lifecycle hooks ------------------------------------------

    def on_submit(self, server, req) -> None:
        t = server.ticks
        self._req_ticks[req.rid] = {"submitted": t}
        if self.recorder:
            track = ("request", req.rid)
            self.recorder.begin("request", track=track, tick=t,
                                slo=req.slo, prompt=len(req.prompt),
                                max_new=req.max_new,
                                deadline=req.deadline)
            self.recorder.begin("queued", track=track, tick=t)
        if self.registry:
            self.registry.counter(
                "serve.submitted",
                "requests entering the queue").inc()

    def on_admit(self, server, req, slot: int, shared: int) -> None:
        t = server.ticks
        rt = self._req_ticks.setdefault(req.rid, {"submitted": t})
        waited = t - rt.get("submitted", t)
        rt["admitted"] = t
        if self.recorder:
            track = ("request", req.rid)
            self.recorder.end("queued", track=track, tick=t,
                              waited_ticks=waited)
            self.recorder.begin("running", track=track, tick=t,
                                slot=slot, shared_prefix=shared)
            self.recorder.begin(f"req{req.rid}", track=("slot", slot),
                                tick=t, rid=req.rid, slo=req.slo)
        if self.registry:
            self.registry.counter("serve.admitted",
                                  "queue -> slot placements").inc()
            self.registry.histogram(
                "serve.queue_wait_ticks",
                "ticks between submit and placement",
                slo=req.slo).observe(waited)
            if shared:
                self.registry.counter(
                    "serve.shared_prefix_tokens",
                    "prompt tokens admitted via COW sharing").inc(shared)

    def on_preempt(self, server, req, slot: int, reason: str) -> None:
        t = server.ticks
        if self.recorder:
            track = ("request", req.rid)
            self.recorder.end("running", track=track, tick=t,
                              reason=reason, tokens=len(req.out))
            self.recorder.begin("queued", track=track, tick=t,
                                resumed=True)
            self.recorder.end(f"req{req.rid}", track=("slot", slot),
                              tick=t, reason=reason)
        if self.registry:
            self.registry.counter("serve.preemptions",
                                  "mid-flight evictions",
                                  reason=reason).inc()

    def on_retire(self, server, req, slot: int) -> None:
        t = server.ticks
        rt = self._req_ticks.pop(req.rid, {})
        latency = t - rt.get("submitted", t)
        if self.recorder:
            track = ("request", req.rid)
            self.recorder.end("running", track=track, tick=t,
                              tokens=len(req.out))
            self.recorder.end("request", track=track, tick=t,
                              tokens=len(req.out),
                              latency_ticks=latency,
                              preempted=req.preempted)
            self.recorder.end(f"req{req.rid}", track=("slot", slot),
                              tick=t)
        if self.registry:
            self.registry.counter("serve.retired",
                                  "completed requests").inc()
            self.registry.counter("serve.tokens_out",
                                  "generated tokens across retired "
                                  "requests").inc(len(req.out))
            self.registry.histogram(
                "serve.latency_ticks",
                "submit -> retire, in engine ticks",
                slo=req.slo).observe(latency)

    # -- tick + phase hooks ------------------------------------------------

    def on_tick_begin(self, server, tick: int) -> None:
        if self.profiler:
            self.profiler.tick_begin()
        if self.recorder:
            self.recorder.begin("tick", tick=tick)

    def on_tick_end(self, server, tick: int, *, n_decode: int = 0,
                    n_spec: int = 0, n_prefill: int = 0) -> None:
        if self.recorder:
            self.recorder.end("tick", tick=tick, decode=n_decode,
                              spec=n_spec, prefill=n_prefill)
            self.recorder.counter("active_slots",
                                  n_decode + n_spec + n_prefill,
                                  tick=tick)
            self.recorder.counter("queue_depth", len(server.queue),
                                  tick=tick)
            if server.alloc is not None:
                self.recorder.counter("free_pages",
                                      server.alloc.free_pages,
                                      tick=tick)
        if self.registry:
            self.registry.gauge("serve.queue_depth").set(
                len(server.queue))
            if server.alloc is not None:
                self.registry.gauge("serve.free_pages").set(
                    server.alloc.free_pages)
        if self.monitor is not None:
            ok = self.monitor.poll(tick)
            if not ok and self.recorder and self.monitor.violation and \
                    not self.monitor.violation.get("_traced"):
                self.monitor.violation["_traced"] = True
                self.recorder.instant(
                    "conformance.violation", tick=tick,
                    message=self.monitor.violation["message"][:200])
                if self.registry:
                    self.registry.counter(
                        "serve.conformance_violations",
                        "online monitor trips").inc()
        if self.profiler:
            self.profiler.tick_end()

    def phase_begin(self, name: str, tick: int) -> float:
        t0 = time.perf_counter()
        if self.recorder:
            self.recorder.begin(f"phase.{name}", tick=tick)
        return t0

    def phase_end(self, name: str, tick: int, t0: float, sync=None,
                  **args: Any) -> None:
        if self.profiler:
            self.profiler.phase_end(name, t0, sync=sync)
        if self.recorder:
            self.recorder.end(f"phase.{name}", tick=tick, **args)

    # -- export ------------------------------------------------------------

    def export(self, path: str | None = None) -> dict:
        """Final poll, close truncated spans, compose the document."""

        if self.monitor is not None:
            self.monitor.poll()
        if self._server is not None and self.registry is not None:
            for k, v in self._server.stats().items():
                self.registry.gauge(f"serve.drain.{k}").set(v)
        events: list[dict] = []
        if self.recorder:
            self.recorder.close_open_spans()
            events = self.recorder.events
        return export_trace(
            events, path,
            metrics=(self.registry.snapshot() if self.registry
                     else None),
            phases=(self.profiler.report() if self.profiler else None),
            monitor=(self.monitor.report() if self.monitor
                     else None))


__all__ = ["Observability"]
