"""Structured tracing: tick-clock + wall-clock spans with a
Chrome-trace-event exporter.

The recorder's event model is deliberately tiny — begin/end spans,
instants, and counter samples, each stamped with BOTH clocks: the
engine tick (the deterministic clock every latency summary and model
check runs on) and wall-clock microseconds (what Perfetto renders).
Events live on *tracks*:

* ``("engine",)`` — per-tick spans and their nested phase spans
  (decode / speculate / verify / prefill / COW copies);
* ``("slot", s)`` — slot occupancy: one span per residency of a
  request in slot ``s``;
* ``("request", rid)`` — the request lifecycle: an outer ``request``
  span containing alternating ``queued`` / ``running`` child spans, so
  a preempted-and-resumed request renders as
  queued→running→queued→running inside one parent.

:func:`export_trace` writes a single JSON document that is BOTH the
schema'd artifact (``kind``/``schema``/``meta`` envelope, optional
``metrics``/``phases``/``monitor`` sections) and directly loadable by
Perfetto / ``chrome://tracing`` — those readers use the standard
``traceEvents`` key and ignore the extra top-level keys.  Tracks map to
pid/tid: pid 1 is the engine process (tid 0 the tick timeline, tid
``1+s`` slot ``s``), pid 2 the requests process (tid ``1+rid``), with
``M``-phase metadata events naming them.  :func:`parse_trace` inverts
the mapping (via those same metadata events), so record → export →
parse is a round trip; :func:`spans_from_events` stack-pairs B/E into
concrete spans for tests and the CLI summary.
"""

from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

TRACE_KIND = "repro.obs/trace"
TRACE_SCHEMA = 1

ENGINE: tuple = ("engine",)

_PID_ENGINE = 1
_PID_REQUESTS = 2


def _provenance_meta() -> dict[str, str]:
    from ..tune.artifact import provenance_meta
    return provenance_meta()


class TraceRecorder:
    """Append-only event recorder on a monotonic wall clock.

    ``ts`` is microseconds since recorder construction
    (``time.perf_counter`` based, so monotone by construction); ``tick``
    is whatever engine clock the caller passes.  Open spans are tracked
    per track so :meth:`close_open_spans` can truncate cleanly at
    export time (a drain that raised mid-tick still yields a valid,
    balanced trace)."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self.events: list[dict] = []
        self._open: dict[tuple, list[str]] = {}
        self._last_tick: dict[tuple, int] = {}

    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def _emit(self, ph: str, name: str, track: tuple, tick: int,
              args: dict) -> None:
        self._last_tick[track] = int(tick)
        self.events.append({"ph": ph, "name": name, "track": track,
                            "tick": int(tick), "ts": self.now_us(),
                            "args": args})

    def begin(self, name: str, *, track: tuple = ENGINE, tick: int = 0,
              **args: Any) -> None:
        self._open.setdefault(track, []).append(name)
        self._emit("B", name, track, tick, args)

    def end(self, name: str, *, track: tuple = ENGINE, tick: int = 0,
            **args: Any) -> None:
        stack = self._open.get(track)
        if stack and stack[-1] == name:
            stack.pop()
        self._emit("E", name, track, tick, args)

    def instant(self, name: str, *, track: tuple = ENGINE,
                tick: int = 0, **args: Any) -> None:
        self._emit("i", name, track, tick, args)

    def counter(self, name: str, value: float, *, tick: int = 0) -> None:
        self._emit("C", name, ENGINE, tick, {"value": float(value)})

    def open_spans(self, track: tuple) -> list[str]:
        return list(self._open.get(track, ()))

    def close_open_spans(self) -> int:
        """End every still-open span (innermost first), marking each
        ``truncated`` — called at export so a trace is always
        balanced."""

        n = 0
        for track, stack in list(self._open.items()):
            while stack:
                name = stack[-1]
                self.end(name, track=track,
                         tick=self._last_tick.get(track, 0),
                         truncated=True)
                n += 1
        return n


# -- export / parse ---------------------------------------------------------

def _track_pid_tid(track: tuple) -> tuple[int, int]:
    if track == ENGINE:
        return _PID_ENGINE, 0
    kind = track[0]
    if kind == "slot":
        return _PID_ENGINE, 1 + int(track[1])
    if kind == "request":
        return _PID_REQUESTS, 1 + int(track[1])
    raise ValueError(f"unknown track {track!r}")


def _track_name(track: tuple) -> str:
    if track == ENGINE:
        return "ticks"
    return f"{track[0]} {track[1]}"


def chrome_events(events: Iterable[dict]) -> list[dict]:
    """Internal events -> Chrome trace-event dicts (metadata first)."""

    tracks: dict[tuple, tuple[int, int]] = {}
    out: list[dict] = []
    for ev in events:
        track = tuple(ev["track"])
        pid, tid = tracks.get(track) or tracks.setdefault(
            track, _track_pid_tid(track))
        args = dict(ev["args"])
        args["tick"] = ev["tick"]
        rec: dict[str, Any] = {"name": ev["name"], "cat": track[0],
                               "ph": ev["ph"], "ts": ev["ts"],
                               "pid": pid, "tid": tid, "args": args}
        if ev["ph"] == "i":
            rec["s"] = "t"          # thread-scoped instant marker
        out.append(rec)

    meta: list[dict] = []
    pids = {pid for pid, _ in tracks.values()}
    pid_names = {_PID_ENGINE: "engine", _PID_REQUESTS: "requests"}
    for pid in sorted(pids):
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "tid": 0, "args": {"name": pid_names[pid]}})
    for track, (pid, tid) in sorted(tracks.items(),
                                    key=lambda kv: kv[1]):
        meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                     "tid": tid, "args": {"name": _track_name(track)}})
    return meta + out


def export_trace(events: Iterable[dict], path: str | None = None, *,
                 metrics: dict | None = None,
                 phases: dict | None = None,
                 monitor: dict | None = None,
                 meta: dict | None = None) -> dict:
    """Build (and optionally atomically write) the trace document."""

    doc: dict[str, Any] = {
        "kind": TRACE_KIND,
        "schema": TRACE_SCHEMA,
        "meta": dict(meta) if meta is not None else _provenance_meta(),
        "displayTimeUnit": "ms",
        "traceEvents": chrome_events(events),
    }
    if metrics is not None:
        doc["metrics"] = metrics
    if phases is not None:
        doc["phases"] = phases
    if monitor is not None:
        doc["monitor"] = monitor
    if path is not None:
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        os.replace(tmp, path)
    return doc


def parse_trace(doc: dict) -> list[dict]:
    """Chrome events back to the recorder's internal form, skipping
    metadata.  The (pid, tid) -> track map is rebuilt from the
    ``thread_name`` metadata the exporter emits."""

    tracks: dict[tuple[int, int], tuple] = {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") == "M" and ev["name"] == "thread_name":
            name = ev["args"]["name"]
            if name == "ticks":
                track: tuple = ENGINE
            else:
                kind, _, idx = name.partition(" ")
                track = (kind, int(idx))
            tracks[(ev["pid"], ev["tid"])] = track
    out: list[dict] = []
    for ev in doc["traceEvents"]:
        if ev.get("ph") == "M":
            continue
        track = tracks[(ev["pid"], ev["tid"])]
        args = dict(ev["args"])
        tick = args.pop("tick", 0)
        if ev["ph"] == "C":
            track = ENGINE
        out.append({"ph": ev["ph"], "name": ev["name"], "track": track,
                    "tick": tick, "ts": ev["ts"], "args": args})
    return out


@dataclass
class Span:
    name: str
    track: tuple
    tick0: int
    tick1: int
    ts: float
    dur: float
    args: dict = field(default_factory=dict)
    children: list["Span"] = field(default_factory=list)


def spans_from_events(events: Iterable[dict]) -> list[Span]:
    """Stack-pair B/E per track into :class:`Span` trees (roots
    returned, children nested).  Raises ``ValueError`` on mismatched
    nesting — the property the round-trip test asserts."""

    stacks: dict[tuple, list[Span]] = {}
    roots: list[Span] = []
    for ev in events:
        track = tuple(ev["track"])
        if ev["ph"] == "B":
            span = Span(name=ev["name"], track=track, tick0=ev["tick"],
                        tick1=ev["tick"], ts=ev["ts"], dur=0.0,
                        args=dict(ev["args"]))
            stack = stacks.setdefault(track, [])
            (stack[-1].children if stack else roots).append(span)
            stack.append(span)
        elif ev["ph"] == "E":
            stack = stacks.get(track)
            if not stack:
                raise ValueError(f"E {ev['name']!r} on {track!r} with "
                                 f"no open span")
            span = stack.pop()
            if span.name != ev["name"]:
                raise ValueError(f"E {ev['name']!r} closes open span "
                                 f"{span.name!r} on {track!r}")
            span.tick1 = ev["tick"]
            span.dur = ev["ts"] - span.ts
            span.args.update(ev["args"])
    open_names = [(t, s.name) for t, st in stacks.items() for s in st]
    if open_names:
        raise ValueError(f"unclosed spans: {open_names}")
    return roots


def validate_trace(doc: dict) -> list[str]:
    """Schema + clock sanity problems (empty list = valid): envelope
    keys, per-event fields, wall-clock monotonicity in file order,
    per-track tick monotonicity, and balanced span nesting."""

    problems: list[str] = []
    if doc.get("kind") != TRACE_KIND:
        problems.append(f"kind is {doc.get('kind')!r}, "
                        f"want {TRACE_KIND!r}")
    if doc.get("schema") != TRACE_SCHEMA:
        problems.append(f"schema is {doc.get('schema')!r}, "
                        f"want {TRACE_SCHEMA}")
    meta = doc.get("meta")
    if not isinstance(meta, dict) or not meta.get("created_utc"):
        problems.append("meta.created_utc missing")
    raw = doc.get("traceEvents")
    if not isinstance(raw, list):
        return problems + ["traceEvents is not a list"]
    for i, ev in enumerate(raw):
        missing = [k for k in ("name", "ph") if k not in ev]
        if ev.get("ph") != "M":
            missing += [k for k in ("ts", "pid", "tid", "args")
                        if k not in ev]
        if missing:
            problems.append(f"event {i} missing {missing}")
            return problems
    try:
        events = parse_trace(doc)
    except (KeyError, ValueError) as exc:
        return problems + [f"unparseable events: {exc}"]
    last_ts = -1.0
    last_tick: dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if ev["ts"] < last_ts:
            problems.append(f"event {i} ts {ev['ts']} < predecessor "
                            f"{last_ts}: wall clock not monotone")
            break
        last_ts = ev["ts"]
        prev = last_tick.get(ev["track"])
        if prev is not None and ev["tick"] < prev:
            problems.append(f"event {i} tick {ev['tick']} < {prev} on "
                            f"track {ev['track']}: tick clock not "
                            f"monotone")
            break
        last_tick[ev["track"]] = ev["tick"]
    try:
        spans_from_events(events)
    except ValueError as exc:
        problems.append(str(exc))
    return problems


__all__ = ["TRACE_KIND", "TRACE_SCHEMA", "ENGINE", "TraceRecorder",
           "Span", "chrome_events", "export_trace", "parse_trace",
           "spans_from_events", "validate_trace"]
