"""repro.obs — unified observability for the serving runtime.

Four facilities behind one :class:`~repro.obs.observe.Observability`
bundle a :class:`~repro.runtime.serve.Server` accepts via ``obs=``:

* :mod:`~repro.obs.trace` — per-request lifecycle spans and per-tick
  engine events on the tick clock + wall clock, exported as a single
  JSON document that is both a schema'd artifact and a
  Perfetto/``chrome://tracing``-loadable timeline;
* :mod:`~repro.obs.metrics` — counters / gauges / log-bucket
  histograms with a snapshot API and Prometheus text exposition;
* :mod:`~repro.obs.profile` — per-tick phase attribution (decode vs
  speculate vs prefill vs COW copies vs host) with proper device sync;
* :mod:`~repro.obs.monitor` — the online direction-2 model-conformance
  check: the live paged allocator's op stream continuously validated
  against the verified abstract model (:mod:`repro.verify`), dumping a
  replayable counterexample trail on violation.

``python -m repro.obs`` summarizes, schema-checks, and re-exports
recorded traces.
"""

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .monitor import ConformanceMonitor
from .observe import Observability
from .profile import PhaseProfiler
from .trace import (TRACE_KIND, TRACE_SCHEMA, Span, TraceRecorder,
                    export_trace, parse_trace, spans_from_events,
                    validate_trace)

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "ConformanceMonitor", "Observability", "PhaseProfiler",
           "TRACE_KIND", "TRACE_SCHEMA", "Span", "TraceRecorder",
           "export_trace", "parse_trace", "spans_from_events",
           "validate_trace"]
