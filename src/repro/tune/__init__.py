"""repro.tune — the unified auto-tuning API.

The paper's four-step loop (model → property → search → counterexample
extraction) packaged as one engine-pluggable front door:

* :class:`Tunable` — the protocol every tunable workload implements
  (``name``, ``space()``, ``cost(cfg)``, ``fingerprint()``, optional
  ``measure(cfg)``),
* :func:`tune` — the driver: ``tune(tunable, engine="sweep")``,
* :func:`register_engine` / :func:`get_engine` — the engine registry
  (``sweep``/``explorer``/``swarm``/``bnb``/``grid``/``bisect``/
  ``measure`` — the last refines cost-model picks on real hardware),
* :class:`TuningCache` — persistent tuned-config store keyed by tunable
  fingerprint + platform (backend, chip generation) + engine,
* :func:`autotune` — decorator resolving Pallas block sizes (and other
  call-site parameters) from the cache at call time,
* :class:`TuningPlan` — declarative batches of tuning jobs for fleet
  warm-up (skip-on-hit, per-job error isolation, summary report), with
  :class:`MetaEngineTunable` tuning the measure engine's own
  ``top_k``/``repeats`` through the same ``tune()`` path,
* :func:`export_artifact` / :func:`merge_artifact` (also methods on
  ``TuningCache``) — portable schema-versioned cache bundles keyed by
  platform fingerprint, with measured-beats-modeled conflict policy,
* ``python -m repro.tune`` — the warmup/export/merge/ls/prune CLI.

The legacy ``repro.core.AutoTuner`` / ``FunctionTuner`` shims have been
removed; this package is the only front door.
"""

from ..core.autotuner import TuneResult
from .api import tune
from .artifact import (ARTIFACT_SCHEMA, ArtifactError, export_artifact,
                       load_artifact, merge_artifact, provenance_meta)
from .cache import (TuningCache, cache_key, default_cache,
                    platform_fingerprint, set_default_cache,
                    tunable_fingerprint)
from .decorators import autotune
from .engines import (Engine, EngineError, available_engines, get_engine,
                      register_engine)
from .plan import (JobResult, MetaEngineTunable, PlanReport, TuningJob,
                   TuningPlan, available_tunables, build_tunable,
                   register_tunable)
from .tunable import FunctionTunable, PlatformTunable, Tunable

__all__ = [
    "tune", "TuneResult", "Tunable", "FunctionTunable", "PlatformTunable",
    "Engine", "EngineError", "register_engine", "get_engine",
    "available_engines", "TuningCache", "cache_key", "default_cache",
    "set_default_cache", "platform_fingerprint", "tunable_fingerprint",
    "autotune",
    # v2: plans, meta-tuning, artifacts
    "TuningPlan", "TuningJob", "JobResult", "PlanReport",
    "MetaEngineTunable", "register_tunable", "available_tunables",
    "build_tunable", "ARTIFACT_SCHEMA", "ArtifactError", "export_artifact",
    "load_artifact", "merge_artifact", "provenance_meta",
]
