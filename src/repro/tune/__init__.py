"""repro.tune — the unified auto-tuning API.

The paper's four-step loop (model → property → search → counterexample
extraction) packaged as one engine-pluggable front door:

* :class:`Tunable` — the protocol every tunable workload implements
  (``name``, ``space()``, ``cost(cfg)``, ``fingerprint()``, optional
  ``measure(cfg)``),
* :func:`tune` — the driver: ``tune(tunable, engine="sweep")``,
* :func:`register_engine` / :func:`get_engine` — the engine registry
  (``sweep``/``explorer``/``swarm``/``bnb``/``grid``/``bisect``/
  ``measure`` — the last refines cost-model picks on real hardware),
* :class:`TuningCache` — persistent tuned-config store keyed by tunable
  fingerprint + platform (backend, chip generation) + engine,
* :func:`autotune` — decorator resolving Pallas block sizes (and other
  call-site parameters) from the cache at call time.

Legacy entry points ``repro.core.AutoTuner`` / ``FunctionTuner`` remain
as thin deprecated shims over this package.
"""

from ..core.autotuner import TuneResult
from .api import tune
from .cache import (TuningCache, cache_key, default_cache,
                    platform_fingerprint, set_default_cache,
                    tunable_fingerprint)
from .decorators import autotune
from .engines import (Engine, EngineError, available_engines, get_engine,
                      register_engine)
from .tunable import FunctionTunable, PlatformTunable, Tunable

__all__ = [
    "tune", "TuneResult", "Tunable", "FunctionTunable", "PlatformTunable",
    "Engine", "EngineError", "register_engine", "get_engine",
    "available_engines", "TuningCache", "cache_key", "default_cache",
    "set_default_cache", "platform_fingerprint", "tunable_fingerprint",
    "autotune",
]
