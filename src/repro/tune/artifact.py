"""Portable tuning-cache artifacts — ship tuned configs to a fleet.

The paper's economic argument is amortization: a configuration found
off-hardware keeps paying for itself.  An *artifact* is the unit of that
amortization across machines: a schema-versioned JSON bundle of
:class:`~repro.tune.TuningCache` entries, grouped by the platform
fingerprint each entry was tuned for (backend + chip generation), so one
bundle can carry configs for a heterogeneous fleet and every node hits
only the keys that match its own platform.

Lifecycle: ``warmup`` a cache from a :class:`~repro.tune.plan.TuningPlan`
on one machine (or per platform), :func:`export_artifact` it, ship the
file, :func:`merge_artifact` it into each node's cache.  Merging is
conflict-aware: the default ``prefer_measured`` policy never lets a
cost-model-only entry overwrite a wall-clock-measured one, and between
equals the newer entry wins.
"""

from __future__ import annotations

import json
import os
import platform as _platform
import tempfile
import time
from pathlib import Path
from typing import Any, Mapping

ARTIFACT_SCHEMA = 1
ARTIFACT_KIND = "repro.tune/cache-artifact"

MERGE_POLICIES = ("prefer_measured", "prefer_newer", "keep_existing")

_PROVENANCE_RANK = {"modeled": 0, "measured": 1}


class ArtifactError(ValueError):
    """The file is not a usable cache artifact (wrong kind/schema)."""


def provenance_meta() -> dict[str, Any]:
    """Who/where/when/with-what built this bundle — recorded at export,
    surfaced by ``merge_artifact`` reports and ``ls --json``, and the
    groundwork for signing artifacts before cross-team rollouts (a
    signature needs a stable subject to sign)."""

    try:
        from .. import __version__ as tool_version
    except ImportError:                                # pragma: no cover
        tool_version = "unknown"
    return {
        "host": _platform.node() or "unknown",
        "machine": _platform.machine(),
        "python": _platform.python_version(),
        "tool": f"repro {tool_version}",
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }


def platform_key(platform: Mapping[str, Any] | None) -> str:
    """Stable string key for a platform fingerprint document."""

    pf = platform or {}
    return f"{pf.get('backend', 'unknown')}/{pf.get('device_kind', 'unknown')}"


def _entry_platform(entry: Mapping[str, Any]) -> dict[str, Any]:
    return dict((entry.get("fingerprint") or {}).get("platform") or {})


def export_artifact(cache, path: str | os.PathLike, *,
                    platform: str | None = None) -> dict[str, Any]:
    """Write ``cache``'s entries as a portable bundle; returns the bundle.

    ``platform`` filters to one platform — either a full key
    (``"cpu/TFRT_CPU_0"``) or just the backend (``"cpu"``, ``"tpu"``).
    ``None`` exports everything (a heterogeneous-fleet bundle).
    """

    platforms: dict[str, dict[str, Any]] = {}
    skipped = 0
    for key, entry in cache.entries.items():
        pf = _entry_platform(entry)
        pk = platform_key(pf)
        if platform is not None and platform not in (pk, pf.get("backend")):
            skipped += 1
            continue
        group = platforms.setdefault(pk, {"platform": pf, "entries": {}})
        group["entries"][key] = entry
    bundle = {
        "kind": ARTIFACT_KIND,
        "schema": ARTIFACT_SCHEMA,
        "created": time.time(),
        "meta": provenance_meta(),
        "source": str(getattr(cache, "path", "")),
        "entry_count": sum(len(g["entries"]) for g in platforms.values()),
        "skipped": skipped,
        "platforms": platforms,
    }
    out = Path(path).expanduser()
    out.parent.mkdir(parents=True, exist_ok=True)
    # atomic replace (same discipline as TuningCache.save): a crash
    # mid-export must not leave a truncated bundle to ship fleet-wide
    fd, tmp = tempfile.mkstemp(dir=str(out.parent), prefix=out.name,
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(bundle, f, indent=1, sort_keys=True, default=str)
        os.replace(tmp, out)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return bundle


def load_artifact(path: str | os.PathLike) -> dict[str, Any]:
    """Read + validate a bundle; raises :class:`ArtifactError` on a file
    that is not an artifact or carries a different schema version."""

    p = Path(path).expanduser()
    try:
        bundle = json.loads(p.read_text())
    except (OSError, ValueError) as e:
        raise ArtifactError(f"{p}: not readable as a cache artifact ({e})")
    if not isinstance(bundle, dict) or bundle.get("kind") != ARTIFACT_KIND:
        raise ArtifactError(f"{p}: not a {ARTIFACT_KIND} bundle")
    if bundle.get("schema") != ARTIFACT_SCHEMA:
        raise ArtifactError(
            f"{p}: artifact schema {bundle.get('schema')!r} != supported "
            f"{ARTIFACT_SCHEMA}; re-export from a matching repro version")
    return bundle


def _incoming_wins(mine: Mapping[str, Any], theirs: Mapping[str, Any],
                   policy: str) -> bool:
    if policy == "keep_existing":
        return False
    if policy == "prefer_measured":
        rank = lambda e: _PROVENANCE_RANK.get(e.get("provenance", "modeled"), 0)
        if rank(theirs) != rank(mine):
            return rank(theirs) > rank(mine)
    # prefer_newer, or same provenance under prefer_measured
    return float(theirs.get("created", 0)) > float(mine.get("created", 0))


def merge_artifact(cache, path: str | os.PathLike, *,
                   policy: str = "prefer_measured") -> dict[str, Any]:
    """Merge a bundle into ``cache`` (in memory — call ``cache.save()``
    to persist); returns a report dict.

    Policies: ``prefer_measured`` (default — measured provenance beats
    modeled, ties broken newer-wins), ``prefer_newer`` (timestamp only),
    ``keep_existing`` (only fill holes).

    The bundle's provenance ``meta`` (exporting host, timestamp, tool
    version) comes back in the report and is stamped onto every entry
    the merge takes as ``origin``, so ``ls --json`` can answer "where
    did this config come from" long after the bundle file is gone.
    """

    if policy not in MERGE_POLICIES:
        raise ValueError(f"unknown merge policy {policy!r}; "
                         f"one of {', '.join(MERGE_POLICIES)}")
    bundle = load_artifact(path)
    meta = bundle.get("meta")
    report = {"added": 0, "replaced": 0, "kept": 0,
              "platforms": sorted(bundle.get("platforms", {})),
              "policy": policy, "meta": meta}
    for group in bundle.get("platforms", {}).values():
        for key, entry in group.get("entries", {}).items():
            mine = cache.entries.get(key)
            incoming = dict(entry)
            # relayed bundles (warm -> node A -> re-export -> node B)
            # keep the ORIGINAL tuning host: only stamp entries that
            # don't already carry their provenance
            if meta is not None and "origin" not in incoming:
                incoming["origin"] = meta
            if mine is None:
                cache.put_entry(key, incoming)
                report["added"] += 1
            elif _incoming_wins(mine, entry, policy):
                cache.put_entry(key, incoming)
                report["replaced"] += 1
            else:
                report["kept"] += 1
    return report


__all__ = ["ARTIFACT_SCHEMA", "ARTIFACT_KIND", "MERGE_POLICIES",
           "ArtifactError", "platform_key", "provenance_meta",
           "export_artifact", "load_artifact", "merge_artifact"]
