"""``@autotune`` — resolve tuning parameters at call time from the cache.

Wrap a kernel entry point whose tuning parameters default to ``None``;
on each call with one of them omitted, the decorator builds the kernel's
Tunable from the actual arguments (shapes, dtype, flags), tunes through
:func:`repro.tune.tune` (served from the persistent cache on a hit), and
injects the tuned values:

    @autotune(lambda a, b, **kw: MatmulTunable(M=a.shape[0], ...),
              params=("bm", "bn", "bk"))
    def matmul_tuned(a, b, *, bm=None, bn=None, bk=None): ...

Explicitly passed parameters always win: with *all* of them given no
tuning runs at all, and with a subset given the remainder is tuned with
the explicit values pinned into the lattice — the joint constraints of
the space (e.g. VMEM residency) still apply to the combined
configuration.  Resolved configs are additionally memoized in-process
(keyed by the Tunable, when hashable) so hot call sites skip the
fingerprint/hash/cache machinery after the first call.  The wrapped
function also exposes ``fn.tune(*args, **kw) -> TuneResult`` to inspect
the decision the decorator would make for those arguments.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Mapping, Sequence

from ..core.search_space import Param, SearchSpace
from .api import tune as _tune
from .cache import tunable_fingerprint


class _PinnedTunable:
    """Restrict a tunable's lattice to configurations matching the
    caller's explicitly passed parameters (constraints preserved)."""

    def __init__(self, inner, pinned: Mapping[str, Any]):
        self.inner = inner
        self.pinned = dict(pinned)
        self.name = getattr(inner, "name", type(inner).__name__)

    def space(self) -> SearchSpace:
        s = self.inner.space()
        return SearchSpace(
            params=[Param(p.name, (self.pinned[p.name],))
                    if p.name in self.pinned else p for p in s.params],
            constraints=list(s.constraints))

    def cost(self, cfg: Mapping[str, Any]) -> float:
        return self.inner.cost(cfg)

    def fingerprint(self) -> dict[str, Any]:
        return {**tunable_fingerprint(self.inner),
                "pinned": dict(sorted(self.pinned.items()))}


def autotune(make_tunable: Callable[..., Any], *, params: Sequence[str],
             engine: str = "grid", cache="default", **tune_kw: Any):
    """``make_tunable(*args, **kw)`` receives the call's arguments with
    the tuning ``params`` stripped and returns the Tunable to search."""

    params = tuple(params)

    def deco(fn):
        memo: dict[Any, dict[str, Any]] = {}

        def resolve(args, kw):
            call_kw = {k: v for k, v in kw.items() if k not in params}
            tunable = make_tunable(*args, **call_kw)
            pinned = {p: kw[p] for p in params if kw.get(p) is not None}
            memo_key = None
            try:
                memo_key = (tunable, tuple(sorted(pinned.items())))
                best = memo.get(memo_key)
                if best is not None:
                    return best
            except TypeError:
                memo_key = None           # unhashable tunable: no memo
            target = _PinnedTunable(tunable, pinned) if pinned else tunable
            res = _tune(target, engine=engine, cache=cache, **tune_kw)
            if memo_key is not None:
                memo[memo_key] = res.best_config
            return res.best_config

        @functools.wraps(fn)
        def wrapper(*args, **kw):
            missing = [p for p in params if kw.get(p) is None]
            if missing:
                best = resolve(args, kw)
                for p in missing:
                    kw[p] = best[p]
            return fn(*args, **kw)

        def tune_for(*args, **kw):
            call_kw = {k: v for k, v in kw.items() if k not in params}
            pinned = {p: kw[p] for p in params if kw.get(p) is not None}
            tunable = make_tunable(*args, **call_kw)
            target = _PinnedTunable(tunable, pinned) if pinned else tunable
            return _tune(target, engine=engine, cache=cache, **tune_kw)

        wrapper.tune = tune_for
        wrapper.tuned_params = params
        return wrapper
    return deco


__all__ = ["autotune"]
