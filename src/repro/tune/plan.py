"""Declarative batches of tuning work — the fleet front door.

A single ``tune()`` call amortizes one configuration; a
:class:`TuningPlan` amortizes a *rollout*: a declarative list of
:class:`TuningJob`\\ s (tunable factory, engine, engine kwargs), built
programmatically with :meth:`TuningPlan.add` or from a small dict/JSON
spec with :meth:`TuningPlan.from_spec`, and executed by
:meth:`TuningPlan.run` against a :class:`~repro.tune.TuningCache` —
skip-on-hit, ``force=`` override, per-job error isolation (one bad job
never sinks the plan), optional ``workers=N`` thread-pool execution of
the independent jobs, progress lines and a summary
:class:`PlanReport`.  The warmed cache then ships as an artifact
(:mod:`repro.tune.artifact`) and every fleet node resolves its
``@autotune`` call sites from pure cache hits.

Spec format (JSON or dict)::

    {"name": "fleet-warmup",
     "calibrate": true,          # resolve a platform calibration first
     "jobs": [
       {"tunable": "kernels.matmul_tuned",
        "params": {"M": 1024, "N": 1024, "K": 1024, "dtype_bytes": 2},
        "engine": "grid"},
       {"tunable": "kernels.tuned_reduction",
        "grid": {"n": [65536, 1048576]},            # expands to 2 jobs
        "engine": "measure", "engine_kwargs": {"repeats": 3}},
       {"tunable": "meta.engine",                   # tune the tuner
        "params": {"engine": "measure",
                   "inner": {"tunable": "kernels.tuned_reduction",
                             "params": {"n": 65536}},
                   "space": {"top_k": [1, 2, 4], "repeats": [1, 3]}}}]}

``tunable`` names resolve through a registry (:func:`register_tunable`;
the in-tree tunables are pre-registered), ``params`` feed the factory,
and ``grid`` expands list-valued entries into the cartesian product of
jobs — the batch analogue of a shape sweep.

:class:`MetaEngineTunable` is "tuning the tuner" (Willemsen & van
Nieuwpoort, 2025) through the standard path: it exposes another tuning
run's *engine kwargs* (``top_k``/``repeats``/``budget`` of the measure
engine) as its own lattice, prices a point by actually running the inner
``tune()`` with those kwargs, and scores result quality plus a
search-effort penalty — so ``tune(MetaEngineTunable(...), "grid")``
selects the search hyperparameters themselves, cacheable like any other
tunable.
"""

from __future__ import annotations

import itertools
import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence

from ..core.autotuner import TuneResult
from ..core.search_space import Param, SearchSpace
from .api import _resolve_engine_name, tune
from .cache import TuningCache, cache_key, default_cache, tunable_fingerprint

# ---------------------------------------------------------------------------
# tunable registry (name -> factory), for dict/JSON plan specs
# ---------------------------------------------------------------------------

_FACTORIES: dict[str, Callable[..., Any]] = {}


def register_tunable(name: str):
    """``@register_tunable("kernels.mykernel")`` — make a tunable factory
    addressable from plan specs.  The factory receives the spec's
    ``params`` as keyword arguments and returns a Tunable."""

    def deco(factory: Callable[..., Any]) -> Callable[..., Any]:
        _FACTORIES[name] = factory
        return factory
    return deco


def available_tunables() -> tuple[str, ...]:
    _ensure_builtin_factories()
    return tuple(sorted(_FACTORIES))


def build_tunable(name: str, params: Mapping[str, Any] | None = None):
    """Resolve ``name`` in the registry and build the tunable."""

    _ensure_builtin_factories()
    try:
        factory = _FACTORIES[name]
    except KeyError:
        raise ValueError(
            f"unknown tunable {name!r}; registered: "
            f"{', '.join(sorted(_FACTORIES))}") from None
    return factory(**dict(params or {}))


_builtins_loaded = False


def _ensure_builtin_factories() -> None:
    # deferred: the kernel modules import repro.tune for @autotune, so
    # registering them at plan-import time would be circular
    global _builtins_loaded
    if _builtins_loaded:
        return

    from ..kernels.flash_attention.ops import FlashAttentionTunable
    from ..kernels.matmul_tuned.ops import MatmulTunable
    from ..kernels.sweep_eval.ops import SweepEvalTunable
    from ..kernels.tuned_reduction.ops import ReductionTunable
    from ..runtime.speculate import SpecDepthTunable
    from ..runtime.tunables import (DecodeBatchTunable, KVPageTunable,
                                    PrefillChunkTunable, SchedulerTunable)
    _FACTORIES.setdefault("kernels.matmul_tuned", MatmulTunable)
    _FACTORIES.setdefault("kernels.flash_attention", FlashAttentionTunable)
    _FACTORIES.setdefault("kernels.tuned_reduction", ReductionTunable)
    _FACTORIES.setdefault("kernels.sweep_eval", SweepEvalTunable)
    _FACTORIES.setdefault("serve.decode_batch", DecodeBatchTunable)
    _FACTORIES.setdefault("serve.prefill_chunk", PrefillChunkTunable)
    _FACTORIES.setdefault("serve.kv_page", KVPageTunable)
    _FACTORIES.setdefault("serve.spec_depth", SpecDepthTunable)
    _FACTORIES.setdefault("serve.scheduler", SchedulerTunable)
    _FACTORIES.setdefault("platform", _platform_factory)
    _FACTORIES.setdefault("tpu.distributed", _tpu_distributed_factory)
    _FACTORIES.setdefault("meta.engine", _meta_engine_factory)
    # only after every import succeeded — a transient ImportError above
    # must not poison the registry for the rest of the process
    _builtins_loaded = True


def _platform_factory(**spec_kw):
    from ..core.platform import PlatformSpec
    from .tunable import PlatformTunable
    return PlatformTunable(PlatformSpec(**spec_kw))


def _tpu_distributed_factory(*, arch: str | None = None,
                             shape: str = "train_4k",
                             workload: Mapping[str, Any] | None = None,
                             **kw):
    from ..core.tpu_machine import (DistributedTunable, TPUWorkload,
                                    workload_from_arch)
    if workload is not None:
        w = TPUWorkload(**dict(workload))
    elif arch is not None:
        w = workload_from_arch(arch, shape)
    else:
        raise ValueError("tpu.distributed needs arch= (+shape=) or workload=")
    return DistributedTunable(w, **kw)


def _meta_engine_factory(*, inner: Mapping[str, Any], engine: str = "measure",
                         space: Mapping[str, Sequence[Any]] | None = None,
                         oracle_call_penalty: float = 1e-3):
    inner_tunable = build_tunable(inner["tunable"], inner.get("params"))
    return MetaEngineTunable(inner_tunable, engine=engine, space=space,
                             oracle_call_penalty=oracle_call_penalty)


# ---------------------------------------------------------------------------
# MetaEngineTunable — tuning the tuner
# ---------------------------------------------------------------------------


class MetaEngineTunable:
    """Another tuning run's engine kwargs as this tunable's lattice.

    ``cost(cfg)`` runs ``tune(inner, engine=..., cache=None, **cfg)`` for
    real (caching disabled — every meta point must actually search) and
    scores ``t_min * (1 + oracle_call_penalty * oracle_calls)``: result
    quality, multiplicatively penalized by search effort, so between
    equal-quality settings the cheaper search wins and a bigger
    shortlist only wins when it finds a genuinely faster configuration.
    The per-point inner results stay inspectable in :attr:`trials`.
    """

    DEFAULT_SPACE: dict[str, tuple[Any, ...]] = {"top_k": (1, 2, 4),
                                                 "repeats": (1, 3)}

    def __init__(self, inner, *, engine: str = "measure",
                 space: Mapping[str, Sequence[Any]] | None = None,
                 oracle_call_penalty: float = 1e-3):
        self.inner = inner
        self.engine = engine
        self._space = {k: tuple(v)
                       for k, v in (space or self.DEFAULT_SPACE).items()}
        self.oracle_call_penalty = oracle_call_penalty
        inner_name = getattr(inner, "name", type(inner).__name__)
        self.name = f"meta.engine[{inner_name}/{engine}]"
        self.trials: dict[tuple, TuneResult] = {}

    def space(self) -> SearchSpace:
        return SearchSpace(params=[Param(k, v)
                                   for k, v in self._space.items()])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        res = tune(self.inner, engine=self.engine, cache=None, **dict(cfg))
        self.trials[tuple(sorted(cfg.items()))] = res
        return res.t_min * (1.0 + self.oracle_call_penalty
                            * res.oracle_calls)

    def fingerprint(self) -> dict[str, Any]:
        return {"tunable": "meta.engine", "engine": self.engine,
                "inner": dict(tunable_fingerprint(self.inner)),
                "space": {k: list(v) for k, v in self._space.items()},
                "oracle_call_penalty": self.oracle_call_penalty}


# ---------------------------------------------------------------------------
# jobs / plan / report
# ---------------------------------------------------------------------------


@dataclass
class TuningJob:
    """One unit of a plan: a tunable (or zero-arg factory of one), the
    engine to run it with, and the engine kwargs.  ``factory`` is called
    inside :meth:`TuningPlan.run`'s per-job error boundary, so a job
    whose construction fails is an isolated failure, not a crash."""

    factory: Callable[[], Any] | Any
    engine: str = "auto"
    engine_kwargs: dict[str, Any] = field(default_factory=dict)
    label: str = ""
    force: bool = False
    # wall-clock-sensitive: this job TIMES things (measure engine, or a
    # meta job whose cost() runs inner measure tunes), so a parallel
    # run must not let other jobs' CPU load pollute its samples
    timed: bool = False

    def materialize(self):
        tunable = self.factory
        if callable(tunable) and not hasattr(tunable, "space"):
            tunable = tunable()
        if not self.label:
            self.label = getattr(tunable, "name", type(tunable).__name__)
        return tunable


@dataclass
class JobResult:
    label: str
    status: str                 # hit | tuned | forced | failed
    engine: str = ""
    t_min: float | None = None
    best_config: dict[str, Any] | None = None
    provenance: str | None = None
    key: str | None = None
    elapsed_s: float = 0.0
    error: str | None = None
    result: TuneResult | None = field(default=None, repr=False)

    def to_json(self) -> dict[str, Any]:
        return {"label": self.label, "status": self.status,
                "engine": self.engine, "t_min": self.t_min,
                "best_config": self.best_config,
                "provenance": self.provenance, "key": self.key,
                "elapsed_s": round(self.elapsed_s, 6), "error": self.error}


@dataclass
class PlanReport:
    plan: str
    results: list[JobResult] = field(default_factory=list)

    @property
    def counts(self) -> dict[str, int]:
        c = {"jobs": len(self.results), "hits": 0, "tuned": 0,
             "forced": 0, "failed": 0}
        bucket = {"hit": "hits", "tuned": "tuned", "forced": "forced",
                  "failed": "failed"}
        for r in self.results:
            c[bucket[r.status]] += 1
        return c

    @property
    def ok(self) -> bool:
        return self.counts["failed"] == 0

    def summary(self) -> str:
        c = self.counts
        return (f"plan {self.plan!r}: {c['jobs']} jobs — {c['hits']} hits, "
                f"{c['tuned']} tuned, {c['forced']} forced, "
                f"{c['failed']} failed")

    def to_json(self) -> dict[str, Any]:
        return {"plan": self.plan, "counts": self.counts,
                "jobs": [r.to_json() for r in self.results]}


class TuningPlan:
    """A declarative batch of tuning jobs; see the module docstring."""

    def __init__(self, jobs: Sequence[TuningJob] | None = None, *,
                 name: str = "plan", require_calibration: bool = False):
        self.name = name
        self.jobs: list[TuningJob] = list(jobs or [])
        # True: run() resolves a platform calibration (load-or-probe via
        # repro.calibrate.ensure_calibrated) BEFORE any job, so measured
        # jobs tune — and cache-fingerprint — against measured constants
        self.require_calibration = require_calibration

    def add(self, tunable_or_factory, engine: str = "auto", *,
            label: str = "", force: bool = False,
            **engine_kwargs: Any) -> TuningJob:
        """Append a job (a Tunable instance or a zero-arg factory);
        returns it for further tweaking."""

        timed = (engine == "measure"
                 or isinstance(tunable_or_factory, MetaEngineTunable))
        job = TuningJob(factory=tunable_or_factory, engine=engine,
                        engine_kwargs=dict(engine_kwargs), label=label,
                        force=force, timed=timed)
        self.jobs.append(job)
        return job

    def __len__(self) -> int:
        return len(self.jobs)

    # -- spec loading -------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: Mapping[str, Any] | str | Path) -> "TuningPlan":
        """Build a plan from a dict spec, a JSON string, or a path to a
        JSON file (module docstring documents the format)."""

        if isinstance(spec, (str, Path)):
            # a string starting with "{" is inline JSON; anything else
            # is a file path — a typo'd path must say "file not found",
            # not surface as a JSON parse error on the path itself
            if isinstance(spec, str) and spec.lstrip().startswith("{"):
                text = spec
            else:
                text = Path(spec).expanduser().read_text()
            spec = json.loads(text)
        if not isinstance(spec, Mapping):
            raise ValueError("plan spec must be a mapping with a 'jobs' list")
        plan = cls(name=str(spec.get("name", "plan")),
                   require_calibration=bool(spec.get("calibrate", False)))
        for i, jspec in enumerate(spec.get("jobs", [])):
            for params, suffix in _expand_grid(jspec):
                name = jspec.get("tunable")
                if not name:
                    raise ValueError(f"job #{i}: missing 'tunable' name")
                label = jspec.get("label", name) + suffix
                # bind via defaults: the factory resolves lazily inside
                # run()'s error boundary, so a bad spec fails one job
                job = plan.add(lambda name=name, params=params:
                               build_tunable(name, params),
                               engine=jspec.get("engine", "auto"),
                               label=label,
                               force=bool(jspec.get("force", False)),
                               **dict(jspec.get("engine_kwargs", {})))
                # the factory is lazy, so classify wall-clock
                # sensitivity from the spec name (meta jobs time their
                # inner tunes whatever their own engine is)
                job.timed = job.timed or name == "meta.engine"
        return plan

    # -- execution ----------------------------------------------------------

    def run(self, *, cache="default", force: bool = False,
            progress: Callable[[str], None] | None = None,
            save: bool = True, workers: int = 1) -> PlanReport:
        """Execute every job through :func:`repro.tune.tune`.

        Cache hits skip the engine (``force=True`` — plan-wide or
        per-job — re-tunes and overwrites); a failing job is recorded
        and the plan continues.  ``save=True`` flushes a dirty
        :class:`TuningCache` at the end so a warm-up actually persists.

        ``workers=N`` runs jobs through a thread pool.  Jobs that TIME
        things (``engine="measure"``, meta jobs) are held back and run
        serially after the pool drains — concurrent drains would sample
        each other's CPU load and could cache a wrong wall-clock winner
        with ``measured`` provenance, which ``prefer_measured`` would
        then defend fleet-wide.  Pooled jobs are grouped by resolved
        cache key before dispatch and same-key jobs run serially within
        one pool task (first tunes, the rest hit), so parallel plans
        get the same intra-plan skip-on-hit dedup as serial ones.
        Per-job error isolation is preserved (one bad job still only
        fails itself), progress lines arrive in completion order, and
        the report lists results in PLAN order either way, so serial
        and parallel runs are comparable job for job."""

        store = default_cache() if cache == "default" else cache
        report = PlanReport(plan=self.name)
        say = progress or (lambda line: None)

        if self.require_calibration:
            # before ANY job (including key resolution): cost models and
            # cache fingerprints must see the calibrated constants
            from ..calibrate import ensure_calibrated
            spec, probed = ensure_calibrated(quick=True)
            say(f"[calibrate] {'probed' if probed else 'loaded'} "
                f"{spec.backend}/{spec.device_kind} "
                f"hash={spec.calibration_hash()}")

        def run_one(i: int, job: TuningJob) -> JobResult:
            t0 = time.perf_counter()
            label = job.label or f"job#{i}"
            try:
                tunable = job.materialize()
                label = job.label
                res = tune(tunable, engine=job.engine, cache=store,
                           force=force or job.force, **job.engine_kwargs)
                status = {"hit": "hit", "force": "forced"}.get(
                    res.stats.get("cache"), "tuned")
                jr = JobResult(
                    label=label, status=status, engine=res.engine,
                    t_min=res.t_min, best_config=dict(res.best_config),
                    provenance=res.stats.get("provenance"),
                    key=res.stats.get("key"),
                    elapsed_s=time.perf_counter() - t0, result=res)
                say(f"[{i + 1}/{len(self.jobs)}] {label}: {status} "
                    f"({res.engine}) t_min={res.t_min:g} "
                    f"config={jr.best_config} [{jr.elapsed_s:.2f}s]")
            except Exception as e:          # per-job isolation
                jr = JobResult(label=label, status="failed",
                               engine=job.engine,
                               elapsed_s=time.perf_counter() - t0,
                               error=f"{type(e).__name__}: {e}")
                say(f"[{i + 1}/{len(self.jobs)}] {label}: FAILED — "
                    f"{jr.error}")
            return jr

        def resolve_key(i: int, job: TuningJob) -> str:
            # the key tune() will use for this job; a job whose tunable
            # cannot even be built gets a unique group of its own (the
            # failure is then recorded by run_one's error boundary)
            try:
                tunable = job.materialize()
                eng = _resolve_engine_name(tunable, job.engine)
                key, _ = cache_key(tunable, eng,
                                   params=dict(job.engine_kwargs) or None)
                return key
            except Exception:
                return f"@unresolvable-job-{i}"

        if workers > 1 and len(self.jobs) > 1:
            from concurrent.futures import ThreadPoolExecutor
            slots: list[JobResult | None] = [None] * len(self.jobs)
            pooled = [(i, j) for i, j in enumerate(self.jobs) if not j.timed]
            timed = [(i, j) for i, j in enumerate(self.jobs) if j.timed]
            # group same-cache-key jobs into ONE pool task executed
            # serially: the first member tunes, the rest skip-on-hit —
            # without this, duplicate modeled jobs race the cache and
            # both tune (last write wins)
            groups: dict[str, list[tuple[int, TuningJob]]] = {}
            for i, job in pooled:
                groups.setdefault(resolve_key(i, job), []).append((i, job))

            def run_group(members: list[tuple[int, TuningJob]]) -> None:
                for i, job in members:
                    slots[i] = run_one(i, job)

            with ThreadPoolExecutor(max_workers=workers) as pool:
                futures = [pool.submit(run_group, members)
                           for members in groups.values()]
                for f in futures:
                    f.result()
            for i, job in timed:         # quiet machine: pool is drained
                slots[i] = run_one(i, job)
            report.results.extend(slots)
        else:
            report.results.extend(run_one(i, job)
                                  for i, job in enumerate(self.jobs))
        if save and isinstance(store, TuningCache) and store.dirty:
            store.save()
        say(report.summary())
        return report


def _expand_grid(jspec: Mapping[str, Any]):
    """Yield (params, label_suffix) for each point of the job's ``grid``
    (cartesian product over list-valued entries), merged over ``params``."""

    base = dict(jspec.get("params", {}))
    grid = {k: list(v) for k, v in dict(jspec.get("grid", {})).items()}
    if not grid:
        yield base, ""
        return
    names = sorted(grid)
    for combo in itertools.product(*(grid[n] for n in names)):
        point = dict(zip(names, combo))
        suffix = "[" + ",".join(f"{k}={v}" for k, v in point.items()) + "]"
        yield {**base, **point}, suffix


__all__ = ["TuningPlan", "TuningJob", "JobResult", "PlanReport",
           "MetaEngineTunable", "register_tunable", "available_tunables",
           "build_tunable"]
