"""Persistent tuned-configuration cache.

Tuning is worth amortizing: the whole point of the paper's off-hardware
method is that a configuration, once found, keeps paying for itself.
:class:`TuningCache` stores ``TuneResult``s on disk keyed by

* the tunable's :meth:`fingerprint` (problem identity + shape),
* the platform (JAX backend + chip generation — a config tuned for a
  v5e is not a config tuned for CPU interpret mode),
* the engine name (engines may legitimately disagree, e.g. swarm's
  randomized bound vs the exact sweep).

The key is the SHA-256 of the canonical JSON of that document, so any
shape/platform/engine change invalidates the entry naturally.  The store
is one JSON file (atomic replace on write) with hit/miss counters.
Writes are deferred: ``put`` only marks the store dirty, and the file is
rewritten on explicit :meth:`save` or at interpreter exit — a sweep that
stores N entries costs one serialization, not N (O(n²) before).

Entries carry a ``provenance`` field — ``"modeled"`` for cost-model-only
engines, ``"measured"`` when the result was ranked by wall-clock (the
``measure`` engine) — so empirical picks stay distinguishable from
modeled ones across runs.
"""

from __future__ import annotations

import atexit
import hashlib
import json
import os
import tempfile
import time
from pathlib import Path
from types import MappingProxyType
from typing import Any, Mapping

from ..core.autotuner import TuneResult

_SCHEMA = 1
_ENV_VAR = "REPRO_TUNE_CACHE"
_DEFAULT_PATH = "~/.cache/repro/tune_cache.json"


def platform_fingerprint() -> dict[str, str]:
    """Backend + chip generation + calibration id the tuned config is
    valid for.  ``calibration`` is the active
    :func:`repro.calibrate.calibration_hash` — the literal ``"default"``
    under the datasheet constants, a short digest of the fitted ones
    under a calibration artifact — so configs tuned against calibrated
    cost models never collide with default-constant entries."""

    try:
        import jax
        dev = jax.devices()[0]
        fp = {"backend": jax.default_backend(),
              "device_kind": str(getattr(dev, "device_kind", "unknown"))}
    except Exception:                                  # pragma: no cover
        fp = {"backend": "unknown", "device_kind": "unknown"}
    try:
        from ..calibrate.spec import calibration_hash
        fp["calibration"] = calibration_hash()
    except Exception:                                  # pragma: no cover
        fp["calibration"] = "default"
    return fp


def tunable_fingerprint(tunable) -> dict[str, Any]:
    """The tunable's own identity; falls back to name + lattice values
    for objects that don't implement ``fingerprint()``."""

    fp = getattr(tunable, "fingerprint", None)
    if callable(fp):
        return dict(fp())
    space = tunable.space()
    return {"tunable": getattr(tunable, "name", type(tunable).__name__),
            "space": {p.name: list(p.values) for p in space.params}}


def cache_key(tunable, engine: str,
              params: Mapping[str, Any] | None = None
              ) -> tuple[str, dict[str, Any]]:
    """(sha256 hex key, the fingerprint document it hashes).

    ``params`` carries engine arguments that change the answer
    (``use_measure``, ``n_walks``, ``seed``, ``budget``, ...) so runs
    with different search settings get distinct entries."""

    doc = {"schema": _SCHEMA,
           "tunable": tunable_fingerprint(tunable),
           "platform": platform_fingerprint(),
           "engine": engine}
    if params:
        doc["params"] = dict(params)
    blob = json.dumps(doc, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest(), doc


class TuningCache:
    """On-disk map: cache key -> tuned config + t_min (+ provenance)."""

    def __init__(self, path: str | os.PathLike | None = None):
        if path is None:
            path = os.environ.get(_ENV_VAR, _DEFAULT_PATH)
        self.path = Path(path).expanduser()
        self.hits = 0
        self.misses = 0
        self._entries: dict[str, dict[str, Any]] = {}
        self._dirty = False
        self._load()

    # -- persistence --------------------------------------------------------

    def _load(self) -> None:
        try:
            doc = json.loads(self.path.read_text())
            if doc.get("schema") == _SCHEMA:
                self._entries = dict(doc.get("entries", {}))
        except (OSError, ValueError):
            self._entries = {}

    @property
    def dirty(self) -> bool:
        """True when in-memory entries have not been flushed to disk."""

        return self._dirty

    def _mark_dirty(self) -> None:
        # the strong registration keeps this cache alive until flushed,
        # so deferred puts survive the object going out of scope
        self._dirty = True
        _dirty_caches.add(self)

    def save(self) -> None:
        """Flush pending entries to disk (atomic replace).  ``put`` only
        marks the store dirty; this runs on explicit call and — for
        still-dirty caches — at interpreter exit."""

        self.path.parent.mkdir(parents=True, exist_ok=True)
        doc = {"schema": _SCHEMA, "entries": self._entries}
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1, sort_keys=True, default=str)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._dirty = False
        _dirty_caches.discard(self)

    # -- lookup/store --------------------------------------------------------

    def get(self, key: str) -> dict[str, Any] | None:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        return entry

    def put(self, key: str, result: TuneResult,
            fingerprint: Mapping[str, Any] | None = None) -> None:
        witness = None
        if result.witness is not None:
            w = result.witness
            witness = {"time": w.time, "config": dict(w.config),
                       "trail": list(w.trail), "depth": w.depth}
        # full result provenance minus the bulky grid trace
        stats = {k: v for k, v in result.stats.items() if k != "trace"}
        self._entries[key] = {
            "best_config": dict(result.best_config),
            "t_min": result.t_min,
            "engine": result.engine,
            "oracle_calls": result.oracle_calls,
            "elapsed_s": result.elapsed_s,
            "stats": stats,
            "witness": witness,
            "created": time.time(),
            "provenance": result.stats.get("provenance", "modeled"),
            "fingerprint": dict(fingerprint) if fingerprint else None,
        }
        self._mark_dirty()

    def put_entry(self, key: str, entry: Mapping[str, Any]) -> None:
        """Store an already-serialized entry (artifact merge path)."""

        self._entries[key] = dict(entry)
        self._mark_dirty()

    def clear(self) -> None:
        self._entries.clear()
        self._dirty = False
        _dirty_caches.discard(self)
        if self.path.exists():
            self.path.unlink()

    @property
    def entries(self) -> Mapping[str, dict[str, Any]]:
        """Read-only view of the stored entries (key -> entry doc)."""

        return MappingProxyType(self._entries)

    # -- fleet-rollout tooling (artifacts, pruning) -------------------------

    def export_artifact(self, path, *, platform: str | None = None
                        ) -> dict[str, Any]:
        """Write entries as a portable schema-versioned bundle — see
        :func:`repro.tune.artifact.export_artifact`."""

        from .artifact import export_artifact
        return export_artifact(self, path, platform=platform)

    def merge_artifact(self, path, *, policy: str = "prefer_measured"
                       ) -> dict[str, Any]:
        """Merge a bundle into this cache (``prefer_measured`` conflict
        policy by default) — see :func:`repro.tune.artifact.merge_artifact`.
        In-memory until :meth:`save`."""

        from .artifact import merge_artifact
        return merge_artifact(self, path, policy=policy)

    def prune(self, *, backend: str | None = None,
              stale_days: float | None = None,
              now: float | None = None) -> int:
        """Drop entries tuned for ``backend`` and/or older than
        ``stale_days``; returns the number removed.  Filters AND
        together; at least one is required (``clear()`` wipes)."""

        if backend is None and stale_days is None:
            raise ValueError("prune needs backend= and/or stale_days= "
                             "(use clear() to wipe the cache)")
        now = time.time() if now is None else now
        doomed = []
        for key, e in self._entries.items():
            if backend is not None:
                pf = (e.get("fingerprint") or {}).get("platform") or {}
                if pf.get("backend") != backend:
                    continue
            if stale_days is not None and \
                    now - float(e.get("created", 0)) < stale_days * 86400:
                continue
            doomed.append(key)
        for key in doomed:
            del self._entries[key]
        if doomed:
            self._mark_dirty()
        return len(doomed)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    @property
    def stats(self) -> dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._entries)}


# every dirty cache, flushed at interpreter exit so deferred puts are
# never lost on a normal shutdown.  The reference is STRONG on purpose:
# a short-lived cache that goes out of scope before exit must survive
# until its pending entries hit disk (save() releases it)
_dirty_caches: "set[TuningCache]" = set()


@atexit.register
def _flush_dirty_caches() -> None:                     # pragma: no cover
    for cache in list(_dirty_caches):
        if cache.dirty:
            try:
                cache.save()
            except OSError:
                pass


_default_cache: TuningCache | None = None


def default_cache() -> TuningCache:
    """Process-wide cache (path from $REPRO_TUNE_CACHE, else
    ``~/.cache/repro/tune_cache.json``), created on first use."""

    global _default_cache
    if _default_cache is None:
        _default_cache = TuningCache()
    return _default_cache


def set_default_cache(cache: TuningCache | None) -> TuningCache | None:
    """Swap the process-wide cache (tests point it at a temp dir);
    returns the previous one."""

    global _default_cache
    prev = _default_cache
    _default_cache = cache
    return prev


__all__ = ["TuningCache", "cache_key", "tunable_fingerprint",
           "platform_fingerprint", "default_cache", "set_default_cache"]
