"""``repro.tune.tune`` — the one front door for all tuning.

    from repro.tune import tune, PlatformTunable
    res = tune(PlatformTunable(spec), engine="sweep")
    res.best_config, res.t_min

The driver is engine-agnostic (Step 3 of the paper's method as a
component): resolve the engine from the registry, consult the persistent
:class:`~repro.tune.cache.TuningCache` (fingerprint + platform + engine),
run the engine on a miss, store the result.
"""

from __future__ import annotations

import time as _time
from typing import Any

from ..core.autotuner import TuneResult
from ..core.counterexample import Counterexample
from .cache import TuningCache, cache_key, default_cache
from .engines import get_engine


def _resolve_engine_name(tunable, engine: str) -> str:
    if engine != "auto":
        return engine
    # platform tunables get the exact vectorized sweep; everything else
    # walks its lattice through the cost model
    return "sweep" if getattr(tunable, "spec", None) is not None else "grid"


def _resolve_cache(cache) -> TuningCache | None:
    if cache == "default":
        return default_cache()
    return cache            # a TuningCache instance, or None = disabled


def tune(tunable, engine: str = "auto", *, cache="default",
         budget: int | None = None, force: bool = False,
         **engine_kw: Any) -> TuneResult:
    """Tune ``tunable`` with the named engine, through the cache.

    Parameters
    ----------
    tunable: an object implementing the :class:`~repro.tune.Tunable`
        protocol (``name``/``space``/``cost``/``fingerprint``).
    engine: registry name (``sweep``/``explorer``/``swarm``/``bnb``/
        ``grid``/``bisect``/``measure``/...); ``auto`` picks ``sweep``
        for platform tunables and ``grid`` otherwise.
    cache: ``"default"`` (process-wide persistent cache), a
        :class:`TuningCache`, or ``None`` to disable caching.
    budget: engine-specific work bound (configs / states / walks).
    force: re-run the engine even on a cache hit (the result overwrites
        the cached entry; such a re-tune reports ``stats["cache"] ==
        "force"``, a cold forced run plain ``"miss"``).
    engine_kw: forwarded to ``Engine.run`` (e.g. ``schedule="por"``,
        ``use_bisection=True``, ``n_walks=8``).
    """

    eng = get_engine(_resolve_engine_name(tunable, engine))
    store = _resolve_cache(cache)

    key = doc = None
    overwrote = False
    if store is not None:
        extras = dict(engine_kw)
        if budget is not None:
            extras["budget"] = budget
        key, doc = cache_key(tunable, eng.name, params=extras or None)
        if force:
            # a forced re-run over an existing entry is a re-tune, not a
            # cold miss — rollout reports tag it "force" below
            overwrote = key in store
        else:
            hit = store.get(key)
            if hit is not None:
                witness = None
                if hit.get("witness") is not None:
                    w = hit["witness"]
                    witness = Counterexample(time=w["time"],
                                             config=dict(w["config"]),
                                             trail=tuple(w["trail"]),
                                             depth=w["depth"])
                stats = {**hit.get("stats", {}), "cache": "hit", "key": key}
                # measured-vs-modeled provenance survives the round-trip
                stats.setdefault("provenance",
                                 hit.get("provenance", "modeled"))
                return TuneResult(best_config=dict(hit["best_config"]),
                                  t_min=hit["t_min"],
                                  engine=hit.get("engine", eng.name),
                                  oracle_calls=hit.get("oracle_calls", 0),
                                  elapsed_s=0.0, witness=witness,
                                  stats=stats)

    t0 = _time.perf_counter()
    res = eng.run(tunable, budget=budget, **engine_kw)
    res.elapsed_s = _time.perf_counter() - t0
    res.stats.setdefault("provenance", "modeled")

    if store is not None:
        store.put(key, res, fingerprint=doc)
        res.stats.setdefault("cache", "force" if overwrote else "miss")
        res.stats.setdefault("key", key)
    return res


__all__ = ["tune"]
