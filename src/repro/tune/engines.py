"""Engine registry: pluggable search backends behind one interface.

Every engine answers the same question — *the minimal reachable
termination time and a configuration witnessing it* — through
``Engine.run(tunable, budget=...) -> TuneResult``.  This replaces the
seed's ``AutoTuner.tune`` if/elif chain: engines register under a name with
:func:`register_engine` and :func:`get_engine` resolves them, so new
search strategies plug in without touching the driver.

The paper-faithful Fig. 1 protocol (bisection on T against a
counterexample oracle ``C_ex``) lives in
:func:`repro.core.bisect_search.find_minimal_time`; any engine that can
answer "is there an execution with time ≤ T?" plugs into it — the
explicit-state explorer, the vectorized sweep, or a plain cost table
(:class:`BisectEngine`).

Engines shipped here:

========== ==================================================================
``grid``    exhaustive cost-model scan (any tunable; alias ``function``)
``bisect``  Fig. 1 bisection with a cost-table C_ex oracle (any tunable)
``measure`` cost-model shortlist, wall-clock verdict (tunables with measure)
``sweep``   vectorized lattice sweep over the wave model (platform tunables)
``explorer`` explicit-state DFS, SPIN-faithful (platform tunables)
``swarm``   Fig. 5 randomized bounded search (platform tunables)
``bnb``     Ruys-style branch-and-bound, one verification run (platform)
========== ==================================================================
"""

from __future__ import annotations

import inspect
import math
from typing import Any, Mapping, Type

from ..core import bisect_search, explorer, platform, properties, swarm, sweep
from ..core.autotuner import TuneResult
from ..core.counterexample import Counterexample
from ..core.wave_model import model_time
from ..kernels.common import median


class EngineError(ValueError):
    """An engine cannot run on the given tunable."""


class Engine:
    """Common interface: ``run(tunable, budget=None, **kw) -> TuneResult``.

    ``budget`` bounds the engine's work in engine-specific units
    (configurations evaluated, states explored, walks); ``None`` means
    the engine's own default.
    """

    name: str = ""

    def run(self, tunable, *, budget: int | None = None, **kw) -> TuneResult:
        raise NotImplementedError


_REGISTRY: dict[str, Type[Engine]] = {}


def register_engine(name: str):
    """Class decorator: ``@register_engine("sweep")`` adds an
    :class:`Engine` subclass to the registry under ``name`` (a class may
    register under several aliases)."""

    def deco(cls: Type[Engine]) -> Type[Engine]:
        _REGISTRY[name] = cls
        if not cls.name:
            cls.name = name
        return cls
    return deco


def get_engine(name: str) -> Engine:
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered engines: "
            f"{', '.join(sorted(_REGISTRY))}") from None
    inst = cls()
    inst.name = name
    return inst


def available_engines() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# helpers shared by the platform engines
# ---------------------------------------------------------------------------


def _require_platform(tunable, engine: str):
    spec = getattr(tunable, "spec", None)
    if spec is None:
        raise EngineError(
            f"engine {engine!r} needs a platform tunable (an object with a "
            f"PlatformSpec `spec` attribute, e.g. repro.tune.PlatformTunable)"
            f"; got {type(tunable).__name__}")
    return spec


def _config_vars(tunable) -> tuple[str, ...]:
    return tuple(getattr(tunable, "config_vars", ("WG", "TS")))


def _explorer_oracle(model, config_vars, *, schedule="por",
                     max_states=2_000_000):
    def oracle(T: int) -> Counterexample | None:
        prop = properties.OverTime(T)
        r = explorer.explore(model, prop.violates, schedule=schedule,
                             max_states=max_states)
        if r.counterexample is None:
            return None
        return Counterexample.from_terminal(r.counterexample, config_vars)
    return oracle


def _simulate_t_ini(model) -> int:
    """The paper obtains T_ini from a SPIN simulation run: one random
    walk to FIN reads off a feasible termination time."""

    for seed in range(16):
        r = explorer.explore(model, properties.NonTermination().violates,
                             schedule="random", seed=seed,
                             depth_limit=2_000_000)
        if r.counterexample is not None:
            return int(r.counterexample.globals["time"])
    raise RuntimeError("simulation never reached FIN")


def _eval_fn(tunable, use_measure: bool):
    if use_measure:
        measure = getattr(tunable, "measure", None)
        if not callable(measure):
            raise EngineError(
                f"use_measure=True but {type(tunable).__name__} has no "
                f"measure(cfg) method")
        return measure
    return tunable.cost


# ---------------------------------------------------------------------------
# generic engines (any Tunable)
# ---------------------------------------------------------------------------


@register_engine("grid")
@register_engine("function")
class GridEngine(Engine):
    """Exhaustive scan of the lattice through the cost model
    (first-wins tie-break, matching the seed's FunctionTuner)."""

    def run(self, tunable, *, budget: int | None = None,
            keep_trace: bool = False, use_measure: bool = False
            ) -> TuneResult:
        evaluate = _eval_fn(tunable, use_measure)
        best_cfg, best_t = None, None
        trace: list[tuple[float, dict]] = []
        n = 0
        for cfg in tunable.space():
            if budget is not None and n >= budget:
                break
            t = evaluate(cfg)
            n += 1
            if keep_trace:
                trace.append((t, dict(cfg)))
            if best_t is None or t < best_t:
                best_cfg, best_t = dict(cfg), t
        if best_cfg is None:
            raise RuntimeError("empty search space")
        stats: dict[str, Any] = {"evaluated": n}
        if keep_trace:
            stats["trace"] = trace
        return TuneResult(best_config=best_cfg, t_min=best_t,
                          engine=self.name, oracle_calls=n, stats=stats)


@register_engine("bisect")
class BisectEngine(Engine):
    """The paper's Fig. 1 protocol over an arbitrary cost tunable: the
    cost table answers C_ex(T) and :func:`find_minimal_time` bisects.
    Times are rounded to integers (the paper's setting); use ``grid``
    for fractional cost models."""

    def run(self, tunable, *, budget: int | None = None,
            use_measure: bool = False) -> TuneResult:
        evaluate = _eval_fn(tunable, use_measure)
        table: list[tuple[int, dict]] = []
        for i, cfg in enumerate(tunable.space()):
            if budget is not None and i >= budget:
                break
            t = evaluate(cfg)
            if math.isfinite(t):
                table.append((int(round(t)), dict(cfg)))
        if not table:
            raise RuntimeError("empty search space")

        def oracle(T: int) -> Counterexample | None:
            ok = [e for e in table if e[0] <= T]
            if not ok:
                return None
            t, cfg = min(ok, key=lambda e: e[0])
            return Counterexample(time=t, config=cfg, trail=(), depth=0)

        t_ini = max(t for t, _ in table)
        br = bisect_search.find_minimal_time(oracle, t_ini=t_ini)
        return TuneResult(best_config=br.witness.config, t_min=br.t_min,
                          engine=self.name, oracle_calls=br.oracle_calls,
                          witness=br.witness, log=br.log,
                          stats={"evaluated": len(table)})


@register_engine("measure")
class MeasureEngine(Engine):
    """Model-guided empirical tuning — the §8 concession closed.

    The cost model is an abstraction of the platform; real machines
    disagree with it in the tail.  This engine uses the model for what
    it is good at (pruning the lattice off-hardware) and the hardware
    for what only it can answer (the final ranking): score every
    configuration through ``cost``, shortlist the ``top_k`` best
    modeled points, then time each candidate for real through the
    tunable's ``measure(cfg)`` — median of ``repeats`` calls, with the
    warmup/`block_until_ready` discipline inside ``measure`` itself —
    and return the wall-clock winner.

    The shortlist always contains the pure cost-model pick, so the
    measured winner's measured time is ≤ the measured time of the
    modeled pick by construction.  ``budget`` bounds the shortlist
    size (overriding ``top_k``); ``stats`` records both the modeled
    and the measured ranking (``provenance="measured"``), which the
    :class:`~repro.tune.TuningCache` persists so empirical picks stay
    distinguishable from modeled ones.
    """

    def run(self, tunable, *, budget: int | None = None, top_k: int = 4,
            repeats: int = 3) -> TuneResult:
        measure = getattr(tunable, "measure", None)
        if not callable(measure):
            raise EngineError(
                f"engine 'measure' needs a tunable with a measure(cfg) "
                f"method (hardware-in-the-loop oracle); "
                f"got {type(tunable).__name__}")

        scored: list[tuple[float, dict]] = []
        for cfg in tunable.space():
            t = tunable.cost(cfg)
            if math.isfinite(t):
                scored.append((t, dict(cfg)))
        if not scored:
            raise RuntimeError("empty search space (all configs infeasible)")
        scored.sort(key=lambda e: e[0])

        k = top_k if budget is None else budget
        k = max(1, min(len(scored), k))
        # warm up once per candidate, not once per repeat: after the
        # first call the jit/compile caches are hot, so later repeats
        # ask measure to skip its internal warmup when it supports it
        try:
            warmup_aware = "warmup" in inspect.signature(measure).parameters
        except (TypeError, ValueError):                # pragma: no cover
            warmup_aware = False
        candidates: list[dict[str, Any]] = []
        for modeled, cfg in scored[:k]:
            times = []
            for rep in range(max(1, repeats)):
                kw = {"warmup": 0} if (rep and warmup_aware) else {}
                times.append(float(measure(cfg, **kw)))
            # true median (even repeats average the middle pair —
            # sorted[n//2] returned the WORSE of two samples)
            times.sort()
            candidates.append({"config": cfg, "modeled": modeled,
                               "measured": median(times),
                               "samples": times})
        best = min(candidates, key=lambda c: c["measured"])
        modeled_pick = candidates[0]            # scored[0] = model's argmin
        return TuneResult(
            best_config=dict(best["config"]), t_min=best["measured"],
            engine=self.name,
            oracle_calls=len(candidates) * max(1, repeats),
            stats={"provenance": "measured",
                   "evaluated": len(scored), "shortlist": k,
                   "repeats": repeats,
                   "modeled_pick": {"config": dict(modeled_pick["config"]),
                                    "modeled": modeled_pick["modeled"],
                                    "measured": modeled_pick["measured"]},
                   "measured_pick": {"config": dict(best["config"]),
                                     "modeled": best["modeled"],
                                     "measured": best["measured"]},
                   "candidates": [{"config": dict(c["config"]),
                                   "modeled": c["modeled"],
                                   "measured": c["measured"]}
                                  for c in candidates]})


# ---------------------------------------------------------------------------
# platform engines (the paper's search backends)
# ---------------------------------------------------------------------------


@register_engine("sweep")
class SweepEngine(Engine):
    """Vectorized lattice evaluation over the closed-form wave model
    (beyond-paper); with ``use_bisection=True`` the sweep plays the
    C_ex oracle inside the paper's Fig. 1 loop."""

    def run(self, tunable, *, budget: int | None = None,
            use_bisection: bool = False) -> TuneResult:
        _require_platform(tunable, self.name)
        wave = tunable.wave
        space = tunable.space()
        if use_bisection:
            oracle = sweep.cex_oracle(wave, space)
            t_ini = model_time(wave, WG=1, TS=1)  # trivially feasible config
            br = bisect_search.find_minimal_time(oracle, t_ini=t_ini)
            return TuneResult(best_config=br.witness.config, t_min=br.t_min,
                              engine="sweep+bisection",
                              oracle_calls=br.oracle_calls,
                              witness=br.witness, log=br.log)
        r = sweep.sweep_times(wave, space)
        return TuneResult(best_config=r.best_config, t_min=r.t_min,
                          engine=self.name, oracle_calls=1,
                          stats={"evaluated": r.evaluated})


@register_engine("explorer")
class ExplorerEngine(Engine):
    """Explicit-state search (SPIN-faithful).  ``mode="collect"`` is the
    paper's §6 optimization: one exploration with Φ_t collects *all*
    terminating executions, and the bisection answers from the table;
    ``mode="bisect"`` re-explores per bisection query."""

    def run(self, tunable, *, budget: int | None = None,
            schedule: str = "por", mode: str = "collect",
            max_states: int = 2_000_000) -> TuneResult:
        spec = _require_platform(tunable, self.name)
        config_vars = _config_vars(tunable)
        if budget is not None:
            max_states = budget
        model = platform.build_model(spec)
        if mode == "collect":
            r = explorer.explore(model, properties.NonTermination().violates,
                                 schedule=schedule, max_states=max_states,
                                 stop_on_first=False, collect_terminals=True)
            if not r.terminals:
                raise RuntimeError("no terminating executions found")
            table = [Counterexample.from_terminal(t, config_vars)
                     for t in r.terminals]

            def oracle(T: int) -> Counterexample | None:
                ok = [c for c in table if c.time <= T]
                return min(ok, key=lambda c: c.time) if ok else None

            t_ini = max(c.time for c in table)
            br = bisect_search.find_minimal_time(oracle, t_ini=t_ini)
            return TuneResult(best_config=br.witness.config, t_min=br.t_min,
                              engine=f"explorer/{schedule}+collect",
                              oracle_calls=br.oracle_calls,
                              witness=br.witness, log=br.log,
                              stats={"states": r.states,
                                     "terminals": len(table)})
        oracle = _explorer_oracle(model, config_vars, schedule=schedule,
                                  max_states=max_states)
        t_ini = _simulate_t_ini(model)
        br = bisect_search.find_minimal_time(oracle, t_ini=t_ini)
        return TuneResult(best_config=br.witness.config, t_min=br.t_min,
                          engine=f"explorer/{schedule}",
                          oracle_calls=br.oracle_calls, witness=br.witness,
                          log=br.log)


@register_engine("swarm")
class SwarmEngine(Engine):
    """Fig. 5 randomized bounded search (budget = number of walks)."""

    def run(self, tunable, *, budget: int | None = None, n_walks: int = 16,
            depth_limit: int = 500_000, seed: int = 0, n_workers: int = 1
            ) -> TuneResult:
        spec = _require_platform(tunable, self.name)
        if budget is not None:
            n_walks = budget
        model = platform.build_model(spec)
        sr = swarm.swarm_search(model, n_walks=n_walks,
                                depth_limit=depth_limit, seed=seed,
                                n_workers=n_workers,
                                config_vars=_config_vars(tunable))
        return TuneResult(best_config=sr.best.config, t_min=sr.t_min,
                          engine=self.name, oracle_calls=sr.stats.rounds,
                          witness=sr.best,
                          stats={"walks": sr.stats.walks,
                                 "counterexamples": sr.stats.counterexamples})


@register_engine("bnb")
class BranchAndBoundEngine(Engine):
    """Ruys-style branch-and-bound (paper §8 future work [11]): the
    minimal time from ONE verification run — no bisection."""

    def run(self, tunable, *, budget: int | None = None,
            schedule: str = "por", max_states: int = 5_000_000
            ) -> TuneResult:
        spec = _require_platform(tunable, self.name)
        if budget is not None:
            max_states = budget
        model = platform.build_model(spec)
        r = explorer.explore(model, lambda G: False, schedule=schedule,
                             branch_and_bound="time", stop_on_first=False,
                             max_states=max_states)
        if r.counterexample is None:
            raise RuntimeError("no terminating execution found")
        cex = Counterexample.from_terminal(r.counterexample,
                                           _config_vars(tunable))
        return TuneResult(best_config=cex.config, t_min=cex.time,
                          engine=f"bnb/{schedule}", oracle_calls=1,
                          witness=cex, stats={"states": r.states})


__all__ = ["Engine", "EngineError", "register_engine", "get_engine",
           "available_engines", "GridEngine", "BisectEngine", "MeasureEngine",
           "SweepEngine", "ExplorerEngine", "SwarmEngine",
           "BranchAndBoundEngine"]
