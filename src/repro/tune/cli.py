"""``python -m repro.tune`` — fleet-rollout tooling for the tuning cache.

Subcommands:

* ``warmup <plan.json>`` — run a :class:`~repro.tune.plan.TuningPlan`
  spec against the cache (skip-on-hit; ``--force`` re-tunes;
  ``--workers N`` thread-pools the independent jobs); prints per-job
  progress + a summary, ``--json`` emits the machine-readable report.
  Exit code 1 if any job failed.
* ``export <artifact.json>`` — write the cache as a portable
  schema-versioned bundle (``--platform`` filters, e.g. ``cpu``/``tpu``).
* ``merge <artifact.json>`` — merge a bundle into the cache
  (``--policy prefer_measured|prefer_newer|keep_existing``).
* ``ls`` — list cached entries (``--json`` for scripts).
* ``prune`` — drop entries by ``--backend`` and/or ``--stale-days``.

``--cache PATH`` (before the subcommand) overrides the store; default is
``$REPRO_TUNE_CACHE`` or ``~/.cache/repro/tune_cache.json``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Sequence

from .artifact import ArtifactError, MERGE_POLICIES, platform_key
from .cache import TuningCache
from .plan import TuningPlan


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="Tuning-cache warm-up / export / merge tooling "
                    "(fleet rollout).")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="cache file (default: $REPRO_TUNE_CACHE or "
                         "~/.cache/repro/tune_cache.json)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("warmup", help="run a TuningPlan spec into the cache")
    p.add_argument("plan", help="path to a plan JSON spec")
    p.add_argument("--force", action="store_true",
                   help="re-tune even on cache hits (overwrites entries)")
    p.add_argument("--workers", type=int, default=1, metavar="N",
                   help="run plan jobs through an N-thread pool (jobs are "
                        "independent; per-job failure isolation preserved)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="print the PlanReport as JSON")

    p = sub.add_parser("export", help="write the cache as an artifact")
    p.add_argument("artifact", help="output bundle path")
    p.add_argument("--platform", default=None,
                   help="only entries for this platform "
                        "(backend or backend/device_kind)")

    p = sub.add_parser("merge", help="merge an artifact into the cache")
    p.add_argument("artifact", help="bundle to merge")
    p.add_argument("--policy", default="prefer_measured",
                   choices=MERGE_POLICIES)

    p = sub.add_parser("ls", help="list cached entries")
    p.add_argument("--json", action="store_true", dest="as_json")

    p = sub.add_parser("prune", help="drop entries by backend/staleness")
    p.add_argument("--backend", default=None,
                   help="drop entries tuned for this JAX backend")
    p.add_argument("--stale-days", type=float, default=None,
                   help="drop entries older than this many days")
    return ap


def _cmd_warmup(cache: TuningCache, args) -> int:
    plan = TuningPlan.from_spec(args.plan)
    report = plan.run(cache=cache, force=args.force,
                      workers=args.workers,
                      progress=None if args.as_json else print)
    if args.as_json:
        print(json.dumps(report.to_json(), indent=1, sort_keys=True))
    return 0 if report.ok else 1


def _cmd_export(cache: TuningCache, args) -> int:
    bundle = cache.export_artifact(args.artifact, platform=args.platform)
    print(f"exported {bundle['entry_count']} entries "
          f"({len(bundle['platforms'])} platform(s)"
          f"{', %d filtered out' % bundle['skipped'] if bundle['skipped'] else ''}) "
          f"-> {args.artifact}")
    return 0


def _cmd_merge(cache: TuningCache, args) -> int:
    report = cache.merge_artifact(args.artifact, policy=args.policy)
    cache.save()
    print(f"merged {args.artifact} (policy={args.policy}): "
          f"{report['added']} added, {report['replaced']} replaced, "
          f"{report['kept']} kept -> {cache.path} "
          f"({len(cache)} entries)")
    meta = report.get("meta")
    if meta:
        print(f"  artifact provenance: {meta.get('tool', '?')} on "
              f"{meta.get('host', '?')} ({meta.get('machine', '?')}) "
              f"at {meta.get('created_utc', '?')}")
    return 0


def _cmd_ls(cache: TuningCache, args) -> int:
    rows = []
    for key, e in sorted(cache.entries.items()):
        fp = e.get("fingerprint") or {}
        rows.append({
            "key": key,
            "tunable": (fp.get("tunable") or {}).get("tunable", "?"),
            "engine": e.get("engine", "?"),
            "provenance": e.get("provenance", "modeled"),
            "platform": platform_key(fp.get("platform")),
            "t_min": e.get("t_min"),
            "age_days": round((time.time()
                               - float(e.get("created", 0))) / 86400, 2),
            # artifact provenance: where a merged entry was exported
            # from (None for entries tuned locally)
            "origin": e.get("origin"),
        })
    if args.as_json:
        print(json.dumps(rows, indent=1, sort_keys=True))
        return 0
    if not rows:
        print(f"{cache.path}: empty")
        return 0
    hdr = f"{'key':<12} {'tunable':<28} {'engine':<10} {'prov':<9} " \
          f"{'platform':<22} {'t_min':>12} {'age_d':>7}"
    print(f"{cache.path}: {len(rows)} entries")
    print(hdr)
    for r in rows:
        t = "?" if r["t_min"] is None else f"{r['t_min']:.4g}"
        print(f"{r['key'][:12]:<12} {r['tunable']:<28} {r['engine']:<10} "
              f"{r['provenance']:<9} {r['platform']:<22} "
              f"{t:>12} {r['age_days']:>7}")
    return 0


def _cmd_prune(cache: TuningCache, args) -> int:
    if args.backend is None and args.stale_days is None:
        print("prune: need --backend and/or --stale-days", file=sys.stderr)
        return 2
    n = cache.prune(backend=args.backend, stale_days=args.stale_days)
    cache.save()
    print(f"pruned {n} entries -> {len(cache)} remain in {cache.path}")
    return 0


def main(argv: Sequence[str] | None = None) -> int:
    args = _build_parser().parse_args(argv)
    cache = TuningCache(args.cache)
    try:
        handler = {"warmup": _cmd_warmup, "export": _cmd_export,
                   "merge": _cmd_merge, "ls": _cmd_ls,
                   "prune": _cmd_prune}[args.cmd]
        return handler(cache, args)
    except (ArtifactError, ValueError, OSError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


__all__ = ["main"]
