"""The ``Tunable`` protocol — the one contract every tunable workload
implements (the paper's Step 1 "model" generalized).

A tunable names itself, exposes its configuration lattice
(:class:`~repro.core.search_space.SearchSpace`), prices a configuration
through an analytic cost model (the abstract machine's ``time``), and
fingerprints itself so tuned configs can be cached across runs.  An
optional ``measure(cfg)`` method prices a configuration by actually
executing it (hardware-in-the-loop); engines fall back to ``cost`` when
it is absent.

Implementations live next to their workloads:

* :class:`PlatformTunable` (here) — the paper's abstract OpenCL platform,
* :class:`repro.core.tpu_machine.DistributedTunable` / ``TPUWorkload`` —
  the 512-chip distributed-training configuration,
* ``MatmulTunable`` / ``FlashAttentionTunable`` / ``ReductionTunable`` /
  ``SweepEvalTunable`` in ``repro.kernels.*.ops`` — Pallas block sizes,
* :class:`repro.runtime.serve.DecodeBatchTunable` — serving slot count.
"""

from __future__ import annotations

from typing import Any, Callable, Mapping, Protocol, runtime_checkable

from ..core.search_space import SearchSpace, wg_ts_space
from ..core.wave_model import WaveParams, model_time


@runtime_checkable
class Tunable(Protocol):
    """What an engine needs to tune a workload.

    ``measure(cfg) -> float`` is an *optional* extra method: when present,
    engines asked to run with ``use_measure=True`` price configurations by
    executing them instead of through ``cost``, and the ``measure`` engine
    shortlists through ``cost`` then lets wall-clock pick the winner.
    A tunable that implements both must report ``cost`` and ``measure``
    in the same unit (the in-tree tunables use microseconds), so modeled
    and measured times stay comparable in results and cache entries.
    """

    name: str

    def space(self) -> SearchSpace:
        """The configuration lattice to search."""
        ...

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled execution time of one configuration (the machine
        model's ``time`` variable; lower is better, ``inf`` = infeasible)."""
        ...

    def fingerprint(self) -> Mapping[str, Any]:
        """JSON-serializable identity for the persistent cache: everything
        the tuned config depends on *except* the platform (the cache adds
        backend/chip-generation itself)."""
        ...


def _space_fingerprint(space: SearchSpace) -> dict[str, Any]:
    return {"params": {p.name: list(p.values) for p in space.params},
            "n_constraints": len(space.constraints)}


def _function_identity(fn: Callable) -> dict[str, Any]:
    """Best-effort identity of a cost function for cache keying: code
    location + bytecode hash + captured closure values.  Two lambdas
    with the same body but different captured constants (e.g.
    ``lambda c: cost(c, n=n)`` for different n) key differently."""

    import hashlib
    ident: dict[str, Any] = {
        "module": getattr(fn, "__module__", None),
        "qualname": getattr(fn, "__qualname__", repr(fn)),
    }
    code = getattr(fn, "__code__", None)
    if code is not None:
        ident["code_sha"] = hashlib.sha256(
            code.co_code + repr(code.co_consts).encode()).hexdigest()
    closure = getattr(fn, "__closure__", None)
    if closure:
        try:
            ident["closure"] = [repr(c.cell_contents) for c in closure]
        except ValueError:                             # pragma: no cover
            pass
    return ident


class FunctionTunable:
    """Adapt a bare ``cost_fn`` + space to the protocol (the seed's
    ``FunctionTuner`` calling convention).

    For reliable caching pass an explicit ``fingerprint``; the default
    derives one from the space plus the cost function's code/closure
    identity (best effort — opaque callables without ``__code__`` fall
    back to their repr)."""

    def __init__(self, cost_fn: Callable[[Mapping[str, Any]], float],
                 space: SearchSpace, *, name: str = "function",
                 fingerprint: Mapping[str, Any] | None = None):
        self._cost_fn = cost_fn
        self._space = space
        self.name = name
        self._fingerprint = fingerprint

    def space(self) -> SearchSpace:
        return self._space

    def cost(self, cfg: Mapping[str, Any]) -> float:
        return self._cost_fn(cfg)

    def fingerprint(self) -> Mapping[str, Any]:
        if self._fingerprint is not None:
            return dict(self._fingerprint)
        return {"tunable": self.name,
                "cost_fn": _function_identity(self._cost_fn),
                "space": _space_fingerprint(self._space)}


class PlatformTunable:
    """The paper's abstract platform as a tunable: the (WG, TS) lattice
    priced by the closed-form wave model; the explicit-state engines
    additionally read ``spec``/``config_vars`` to build the full process
    model and search it with counterexample oracles."""

    def __init__(self, spec, space: SearchSpace | None = None,
                 config_vars: tuple[str, ...] = ("WG", "TS")):
        self.spec = spec
        self.config_vars = config_vars
        self._space = space
        self.wave = WaveParams(size=spec.size, NP=spec.NP, GMT=spec.GMT,
                               L=spec.L, kind=spec.kind)
        self.name = f"platform.{spec.kind}"

    def space(self) -> SearchSpace:
        return self._space if self._space is not None \
            else wg_ts_space(self.spec.size)

    def cost(self, cfg: Mapping[str, Any]) -> float:
        return model_time(self.wave, cfg["WG"], cfg["TS"])

    def fingerprint(self) -> Mapping[str, Any]:
        s = self.spec
        fp: dict[str, Any] = {
            "tunable": self.name, "size": s.size, "NP": s.NP,
            "GMT": s.GMT, "L": s.L, "kind": s.kind,
            "fixed_WG": s.fixed_WG, "fixed_TS": s.fixed_TS,
            "config_vars": list(self.config_vars)}
        if self._space is not None:     # restricted lattice ≠ full lattice
            fp["space"] = _space_fingerprint(self._space)
        return fp


__all__ = ["Tunable", "FunctionTunable", "PlatformTunable"]
