"""Entry point: ``python -m repro.tune <warmup|export|merge|ls|prune>``."""

import sys

from .cli import main

if __name__ == "__main__":
    sys.exit(main())
