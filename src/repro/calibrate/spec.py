"""Measured platform specification — the constants every cost model
prices against.

The paper's premise is model-checking a *faithful* platform model; a
model is only as faithful as its constants.  :class:`PlatformSpec` is
the single source of those constants — peak FLOP/s, memory bandwidth,
interconnect bandwidth, dispatch latency — either the **defaults**
(TPU v5e datasheet numbers, exactly the values the repo hardcoded
before this subsystem) or **calibrated** values fitted from the
microbenchmark probes in :mod:`repro.calibrate.probes` and persisted as
a schema-versioned JSON artifact.

Not to be confused with :class:`repro.core.platform.PlatformSpec` (the
abstract Promela NP/GMT platform): that one parameterizes the *process
model*, this one carries the *physical device* numbers that the serving
and distributed cost models divide by.  :func:`~repro.core.wave_model.\
gmt_from_spec` bridges the two — it derives the abstract GMT ratio from
a measured spec.

Resolution order (:func:`get_platform_spec`):

1. an explicitly installed spec (:func:`set_platform_spec` — tests,
   benches, and the CLI use this),
2. a calibration artifact on disk (``$REPRO_PLATFORM_SPEC`` or
   ``~/.cache/repro/platform_spec.json``) whose schema is current and
   whose backend/device match the running process,
3. :data:`DEFAULT_SPEC` (the TPU v5e constants).

:meth:`PlatformSpec.calibration_hash` is mixed into the tuning-cache
platform fingerprint (:func:`repro.tune.cache.platform_fingerprint`) so
configs tuned under calibrated constants never collide with
default-constant entries.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from dataclasses import asdict, dataclass, field, replace
from pathlib import Path
from typing import Any, Mapping

SPEC_SCHEMA = 1
SPEC_KIND = "repro.calibrate/platform-spec"
_ENV_VAR = "REPRO_PLATFORM_SPEC"
_DEFAULT_PATH = "~/.cache/repro/platform_spec.json"

# the constants that identify a calibration (everything a cost model
# divides by); probe metadata is provenance, not identity
_FITTED_FIELDS = ("peak_flops", "hbm_bw", "link_bw", "links", "dci_bw",
                  "dispatch_us")


class CalibrationError(ValueError):
    """The file is not a usable calibration artifact (wrong kind or a
    stale schema version)."""


def device_fingerprint() -> dict[str, str]:
    """Backend + chip generation of the running process (same shape as
    :func:`repro.tune.cache.platform_fingerprint`'s device part, local
    so the import graph stays calibrate -> nothing)."""

    try:
        import jax
        dev = jax.devices()[0]
        return {"backend": jax.default_backend(),
                "device_kind": str(getattr(dev, "device_kind", "unknown"))}
    except Exception:                                  # pragma: no cover
        return {"backend": "unknown", "device_kind": "unknown"}


@dataclass(frozen=True)
class PlatformSpec:
    """Fitted (or default) device constants + probe provenance."""

    peak_flops: float            # FLOP/s per device (bf16 on TPU)
    hbm_bw: float                # main-memory bytes/s per device
    link_bw: float = 50e9        # bytes/s per interconnect link
    links: int = 4               # usable links per device
    dci_bw: float = 25e9         # inter-pod bytes/s per device pair
    dispatch_us: float = 50.0    # per-dispatch host->device latency
    source: str = "default"      # "default" | "calibrated"
    backend: str = ""            # JAX backend the probes ran on
    device_kind: str = ""        # chip generation string
    created: float = 0.0         # unix time of the calibration run
    # raw probe sweeps + which constants were actually fitted (an
    # unfittable probe — e.g. the collective probe on one device —
    # leaves its constant at the default and is absent from "fitted")
    probes: Mapping[str, Any] = field(default_factory=dict)
    schema: int = SPEC_SCHEMA

    @property
    def ici_bw(self) -> float:
        """Aggregate interconnect bandwidth (links x per-link)."""

        return self.links * self.link_bw

    @property
    def dispatch_s(self) -> float:
        """Dispatch latency in seconds (cost models work in seconds)."""

        return self.dispatch_us * 1e-6

    def calibration_hash(self) -> str:
        """Short stable id of the fitted constants; the literal string
        ``"default"`` for the uncalibrated spec, so default-constant
        cache fingerprints stay byte-identical across hosts."""

        if self.source == "default":
            return "default"
        doc = {f: getattr(self, f) for f in _FITTED_FIELDS}
        doc["backend"] = self.backend
        doc["device_kind"] = self.device_kind
        blob = json.dumps(doc, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:12]

    def constants(self) -> dict[str, float]:
        """The fitted constants as a plain dict (CLI/bench reporting)."""

        return {f: getattr(self, f) for f in _FITTED_FIELDS}

    # -- JSON round-trip ----------------------------------------------------

    def to_json(self) -> dict[str, Any]:
        doc = asdict(self)
        doc["probes"] = dict(self.probes)
        doc["kind"] = SPEC_KIND
        return doc

    @classmethod
    def from_json(cls, doc: Mapping[str, Any]) -> "PlatformSpec":
        if doc.get("kind") != SPEC_KIND:
            raise CalibrationError(
                f"not a platform-spec artifact (kind="
                f"{doc.get('kind')!r}, want {SPEC_KIND!r})")
        if doc.get("schema") != SPEC_SCHEMA:
            raise CalibrationError(
                f"stale platform-spec schema {doc.get('schema')!r} "
                f"(current {SPEC_SCHEMA}); re-run "
                f"`python -m repro.calibrate run --force`")
        fields = {k: v for k, v in doc.items() if k != "kind"}
        return cls(**fields)

    def save(self, path: str | os.PathLike) -> Path:
        """Write the spec as a JSON artifact (atomic replace)."""

        path = Path(path).expanduser()
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                                   prefix=path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_json(), f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        return path

    def matches_device(self) -> bool:
        """Does this calibration describe the running process's device?"""

        dev = device_fingerprint()
        return (self.backend == dev["backend"]
                and self.device_kind == dev["device_kind"])


def load_spec(path: str | os.PathLike) -> PlatformSpec:
    """Load a calibration artifact; :class:`CalibrationError` on a
    foreign file or a stale schema, ``OSError`` when missing."""

    text = Path(path).expanduser().read_text()
    try:
        doc = json.loads(text)
    except ValueError as e:
        raise CalibrationError(f"unparseable platform spec {path}: {e}")
    if not isinstance(doc, Mapping):
        raise CalibrationError(f"platform spec {path} is not a JSON object")
    return PlatformSpec.from_json(doc)


# the TPU v5e constants every cost model used before calibration existed
# (197 TFLOP/s bf16 MXU, 819 GB/s HBM, 4 x 50 GB/s ICI, 25 GB/s DCI,
# 50 us dispatch) — now stated exactly once, here
DEFAULT_SPEC = PlatformSpec(peak_flops=197e12, hbm_bw=819e9,
                            link_bw=50e9, links=4, dci_bw=25e9,
                            dispatch_us=50.0, source="default",
                            backend="tpu", device_kind="TPU v5e")


def spec_path(path: str | os.PathLike | None = None) -> Path:
    """The calibration-artifact location: explicit ``path``, else
    ``$REPRO_PLATFORM_SPEC``, else ``~/.cache/repro/platform_spec.json``."""

    if path is None:
        path = os.environ.get(_ENV_VAR, _DEFAULT_PATH)
    return Path(path).expanduser()


_active_spec: PlatformSpec | None = None
_loaded: tuple[Path, PlatformSpec | None] | None = None


def set_platform_spec(spec: PlatformSpec | None) -> PlatformSpec | None:
    """Install ``spec`` as the process-wide platform spec (``None``
    re-enables disk/default resolution); returns the previous override
    so callers can restore it."""

    global _active_spec, _loaded
    prev = _active_spec
    _active_spec = spec
    _loaded = None                    # force a re-read on next resolve
    return prev


def get_platform_spec() -> PlatformSpec:
    """Resolve the active platform spec (see module docstring for the
    order).  A disk artifact is only honored when its schema is current
    AND it was calibrated on this process's backend/device — a spec
    fitted on a TPU must not price CPU runs."""

    global _loaded
    if _active_spec is not None:
        return _active_spec
    path = spec_path()
    if _loaded is not None and _loaded[0] == path:
        return _loaded[1] or DEFAULT_SPEC
    resolved: PlatformSpec | None = None
    try:
        spec = load_spec(path)
        if spec.matches_device():
            resolved = spec
    except (OSError, CalibrationError):
        resolved = None
    _loaded = (path, resolved)
    return resolved or DEFAULT_SPEC


def calibration_hash() -> str:
    """The active spec's calibration id (``"default"`` when running on
    defaults) — the value the tuning-cache platform fingerprint mixes
    in."""

    return get_platform_spec().calibration_hash()


def calibrated_replace(spec: PlatformSpec, **fitted: Any) -> PlatformSpec:
    """A copy of ``spec`` with fitted constants applied and the source
    flipped to ``"calibrated"`` (probe helpers build through here)."""

    return replace(spec, source="calibrated", created=time.time(), **fitted)


__all__ = ["PlatformSpec", "CalibrationError", "DEFAULT_SPEC", "SPEC_SCHEMA",
           "SPEC_KIND", "load_spec", "spec_path", "get_platform_spec",
           "set_platform_spec", "calibration_hash", "calibrated_replace",
           "device_fingerprint"]
