"""Modeled-vs-measured trajectory: is the cost model still honest?

"Tuning the Tuner" argues the tuner's own search quality must be
measured over time, not assumed.  The measure engine already produces
the raw material on every run: the *modeled pick* (the cost model's
argmin) and the *measured pick* (the wall-clock winner), each with its
measured time.  This module distills that into one scalar per tunable —

    gap = modeled_pick.measured / measured_pick.measured  (>= 1.0)

the factor of real time the cost model's pick leaves on the table
(1.0 = the model agreed with the hardware) — and appends a run record
to ``BENCH_calibration.json``, an append-over-runs artifact CI uploads.
A drifting gap means either the cost model or the kernels regressed;
the trajectory makes that visible before it silently mistunes a fleet.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Mapping, Sequence

from .spec import CalibrationError, get_platform_spec

TRAJECTORY_KIND = "repro.calibrate/trajectory"
TRAJECTORY_SCHEMA = 1
TRAJECTORY_PATH = "BENCH_calibration.json"


def gap_from_stats(stats: Mapping[str, Any]) -> dict[str, Any]:
    """One trajectory record from a measure-engine ``stats`` dict
    (needs ``modeled_pick`` and ``measured_pick``)."""

    try:
        modeled = stats["modeled_pick"]
        measured = stats["measured_pick"]
    except KeyError:
        raise CalibrationError(
            "stats have no modeled_pick/measured_pick — the trajectory "
            "needs a measure-engine result") from None
    best_us = float(measured["measured"])
    model_us = float(modeled["measured"])
    return {
        "modeled_config": dict(modeled["config"]),
        "measured_config": dict(measured["config"]),
        "modeled_pick_measured_us": model_us,
        "best_measured_us": best_us,
        "gap": model_us / best_us if best_us > 0 else 1.0,
        "agree": dict(modeled["config"]) == dict(measured["config"]),
        "candidates": len(stats.get("candidates", ())),
    }


def measure_gap(tunable, *, top_k: int = 4, repeats: int = 3,
                label: str | None = None) -> dict[str, Any]:
    """Run the measure engine on ``tunable`` (uncached — the trajectory
    wants today's hardware, not last week's entry) and distill the gap.
    ``label`` overrides the record's ``tunable`` name (two shapes of the
    same tunable need distinct trajectory rows)."""

    from ..tune.engines import get_engine
    result = get_engine("measure").run(tunable, top_k=top_k,
                                       repeats=repeats)
    rec = gap_from_stats(result.stats)
    rec["tunable"] = label or getattr(tunable, "name",
                                      type(tunable).__name__)
    return rec


def load_trajectory(path: str | os.PathLike = TRAJECTORY_PATH
                    ) -> dict[str, Any]:
    """The on-disk trajectory doc; a fresh empty one when the file is
    missing or unparseable, :class:`CalibrationError` when the file is
    some OTHER artifact (never silently clobber foreign data)."""

    p = Path(path).expanduser()
    try:
        doc = json.loads(p.read_text())
    except (OSError, ValueError):
        return {"kind": TRAJECTORY_KIND, "schema": TRAJECTORY_SCHEMA,
                "runs": []}
    if not isinstance(doc, Mapping) or doc.get("kind") != TRAJECTORY_KIND:
        raise CalibrationError(
            f"{p} exists but is not a calibration trajectory "
            f"(kind={doc.get('kind') if isinstance(doc, Mapping) else '?'!r})")
    if doc.get("schema") != TRAJECTORY_SCHEMA:
        raise CalibrationError(
            f"stale trajectory schema {doc.get('schema')!r} in {p} "
            f"(current {TRAJECTORY_SCHEMA})")
    out = dict(doc)
    out["runs"] = list(doc.get("runs", ()))
    return out


def append_run(records: Sequence[Mapping[str, Any]], *,
               path: str | os.PathLike = TRAJECTORY_PATH,
               extra: Mapping[str, Any] | None = None) -> dict[str, Any]:
    """Append one run (a list of per-tunable gap records) to the
    trajectory artifact at ``path`` (atomic replace); returns the run
    doc that was written."""

    from ..tune.artifact import provenance_meta
    spec = get_platform_spec()
    meta = provenance_meta()
    run = {
        "meta": meta,
        # created_utc predates the shared provenance block; kept as a
        # top-level key (same value) for existing trajectory readers
        "created_utc": meta["created_utc"],
        "platform": {"backend": spec.backend,
                     "device_kind": spec.device_kind},
        "source": spec.source,
        "calibration": spec.calibration_hash(),
        "tunables": [dict(r) for r in records],
    }
    if extra:
        run.update(dict(extra))
    doc = load_trajectory(path)
    doc["runs"].append(run)

    p = Path(path).expanduser()
    if p.parent != Path("."):
        p.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=str(p.parent) or ".",
                               prefix=p.name, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return run


def run_trajectory(tunables: Sequence[Any], *,
                   path: str | os.PathLike = TRAJECTORY_PATH,
                   top_k: int = 4, repeats: int = 3,
                   extra: Mapping[str, Any] | None = None
                   ) -> dict[str, Any]:
    """Measure the modeled-vs-measured gap for every tunable and append
    the run to the trajectory artifact; items are Tunables or
    ``(label, tunable)`` pairs.  Returns the run doc."""

    records = []
    for item in tunables:
        label, tb = item if isinstance(item, tuple) else (None, item)
        records.append(measure_gap(tb, top_k=top_k, repeats=repeats,
                                   label=label))
    return append_run(records, path=path, extra=extra)


__all__ = ["TRAJECTORY_KIND", "TRAJECTORY_SCHEMA", "TRAJECTORY_PATH",
           "gap_from_stats", "measure_gap", "load_trajectory",
           "append_run", "run_trajectory"]
