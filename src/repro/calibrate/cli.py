"""``python -m repro.calibrate`` — run/show/export the platform spec.

::

    python -m repro.calibrate run             # probe (or load) + save
    python -m repro.calibrate run --force     # always re-probe
    python -m repro.calibrate run --quick     # CI-sized ladders
    python -m repro.calibrate show            # active spec + provenance
    python -m repro.calibrate export out.json # copy artifact elsewhere

``run`` is load-or-probe: a schema-current artifact for this device
makes the second invocation a pure artifact load (``probes_run: 0``,
``status: "loaded"``) — the property the CI calibrate-smoke asserts via
``--json``.
"""

from __future__ import annotations

import argparse
import json
import sys

from .probes import ensure_calibrated
from .spec import (DEFAULT_SPEC, CalibrationError, get_platform_spec,
                   load_spec, set_platform_spec, spec_path)


def _fmt_constants(spec) -> str:
    default = DEFAULT_SPEC.constants()
    lines = [f"{'constant':<12} {'value':>14} {'default':>14}  note"]
    units = {"peak_flops": "FLOP/s", "hbm_bw": "B/s", "link_bw": "B/s",
             "dci_bw": "B/s", "links": "", "dispatch_us": "us"}
    fitted = set((spec.probes or {}).get("fitted", ()))
    for name, value in spec.constants().items():
        note = "fitted" if name in fitted else (
            "default" if value == default[name] else "set")
        lines.append(f"{name:<12} {value:>14.4g} {default[name]:>14.4g}"
                     f"  {note} {units[name]}")
    return "\n".join(lines)


def _cmd_run(args) -> int:
    spec, probed = ensure_calibrated(
        args.spec, force=args.force, quick=args.quick)
    n_probes = 0
    if probed:
        probes = spec.probes or {}
        n_probes = sum(1 for k in ("matmul", "triad", "dispatch",
                                   "collective") if probes.get(k))
    out = {"status": "calibrated" if probed else "loaded",
           "probes_run": n_probes,
           "path": str(spec_path(args.spec)),
           "calibration": spec.calibration_hash(),
           "backend": spec.backend, "device_kind": spec.device_kind,
           "constants": spec.constants()}
    if args.json:
        print(json.dumps(out, indent=1, sort_keys=True))
    else:
        print(f"[calibrate] {out['status']} ({n_probes} probes) "
              f"-> {out['path']}")
        print(f"[calibrate] device: {spec.backend}/{spec.device_kind} "
              f"hash={out['calibration']}")
        print(_fmt_constants(spec))
    return 0


def _cmd_show(args) -> int:
    prev = None
    if args.spec is not None:
        # explicit path: show THAT artifact, not the resolution chain
        prev = set_platform_spec(load_spec(spec_path(args.spec)))
    try:
        spec = get_platform_spec()
        if args.json:
            print(json.dumps({
                "source": spec.source,
                "calibration": spec.calibration_hash(),
                "backend": spec.backend, "device_kind": spec.device_kind,
                "created": spec.created,
                "constants": spec.constants()}, indent=1, sort_keys=True))
        else:
            print(f"[calibrate] source={spec.source} "
                  f"hash={spec.calibration_hash()} "
                  f"device={spec.backend}/{spec.device_kind}")
            print(_fmt_constants(spec))
    finally:
        if args.spec is not None:
            set_platform_spec(prev)
    return 0


def _cmd_export(args) -> int:
    spec = load_spec(spec_path(args.spec))
    out = spec.save(args.out)
    print(f"[calibrate] exported {spec.calibration_hash()} -> {out}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.calibrate",
        description="measure/inspect the platform calibration artifact")
    ap.add_argument("--spec", default=None, metavar="PATH",
                    help="artifact path (default: $REPRO_PLATFORM_SPEC or "
                         "~/.cache/repro/platform_spec.json)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_run = sub.add_parser("run", help="probe the device (or load a "
                                       "current artifact) and save")
    p_run.add_argument("--force", action="store_true",
                       help="re-probe even if a valid artifact exists")
    p_run.add_argument("--quick", action="store_true",
                       help="small ladders (CI-sized, seconds not minutes)")
    p_run.add_argument("--json", action="store_true",
                       help="machine-readable status on stdout")
    p_run.set_defaults(fn=_cmd_run)

    p_show = sub.add_parser("show", help="print the active spec")
    p_show.add_argument("--json", action="store_true")
    p_show.set_defaults(fn=_cmd_show)

    p_exp = sub.add_parser("export", help="copy the artifact to a path")
    p_exp.add_argument("out", help="destination file")
    p_exp.set_defaults(fn=_cmd_export)

    args = ap.parse_args(argv)
    try:
        return args.fn(args)
    except (OSError, CalibrationError) as e:
        print(f"[calibrate] error: {e}", file=sys.stderr)
        return 1


__all__ = ["main"]
