"""ERT-style microbenchmark probes that fit a :class:`PlatformSpec`.

Four probes, each a *sweep* (raw ``{size -> wall-clock}`` samples) and a
pure *fit* (sweep -> one constant), deliberately separated so fits are
deterministic and unit-testable on synthetic data:

* **matmul ladder** -> ``peak_flops``: square jitted matmuls of rising
  size; the best achieved FLOP/s across the ladder is the empirical
  compute roof (big tiles saturate the MXU / FMA pipes, small ones show
  dispatch — taking the max is the standard ERT reading).
* **streaming triad footprint sweep** -> ``hbm_bw``: ``z = x + 1.5 y``
  over rising working sets; the fit reads the bandwidth at the LARGEST
  footprint, i.e. past the cache hierarchy — the roofline's memory roof
  is main-memory bandwidth, not L2.
* **tiny-kernel dispatch probe** -> ``dispatch_us``: a jitted scalar
  add timed one dispatch at a time; the median sample is the per-call
  launch overhead every serving cost model charges as ``dispatch_s``.
* **collective ping** (optional) -> ``link_bw``: a psum across devices;
  skipped (constant stays at the default) on single-device hosts.

All timing goes through :func:`repro.kernels.common.time_fn` — the one
warmup + ``block_until_ready`` + median discipline every ``measure()``
in the repo uses.

:func:`run_calibration` runs the probes and returns a calibrated
:class:`PlatformSpec`; :func:`ensure_calibrated` is the load-or-probe
front door (a valid on-disk artifact for this device short-circuits the
probes entirely — the property the CI calibrate-smoke step asserts).
"""

from __future__ import annotations

import time
from typing import Any, Mapping, Sequence

from .spec import (DEFAULT_SPEC, CalibrationError, PlatformSpec,
                   calibrated_replace, device_fingerprint, load_spec,
                   set_platform_spec, spec_path)

# ladder/footprint defaults: big enough to saturate a CPU's FMA pipes /
# fall out of L2, small enough that the whole calibration stays seconds
MATMUL_SIZES = (128, 256, 384, 512)
TRIAD_FOOTPRINTS = (1 << 20, 4 << 20, 16 << 20, 64 << 20)   # bytes
QUICK_MATMUL_SIZES = (64, 128)
QUICK_TRIAD_FOOTPRINTS = (1 << 18, 1 << 20)


def _probe_dtype():
    import jax
    return "bfloat16" if jax.default_backend() == "tpu" else "float32"


# -- sweeps (hardware in the loop) ------------------------------------------


def matmul_flops_sweep(sizes: Sequence[int] = MATMUL_SIZES, *,
                       warmup: int = 1, iters: int = 3
                       ) -> list[dict[str, float]]:
    """Time an ``n x n @ n x n`` jitted matmul per ladder rung; each
    entry carries the rung, its FLOP count (2n^3) and the median us."""

    import jax
    import jax.numpy as jnp
    from ..kernels.common import time_fn
    dtype = _probe_dtype()
    f = jax.jit(lambda a, b: a @ b)
    out = []
    for n in sizes:
        a = jnp.ones((n, n), dtype)
        b = jnp.ones((n, n), dtype)
        us = time_fn(lambda: f(a, b), warmup=warmup, iters=iters)
        out.append({"n": n, "flops": float(2 * n ** 3), "us": us})
    return out


def memory_bw_sweep(footprints: Sequence[int] = TRIAD_FOOTPRINTS, *,
                    warmup: int = 1, iters: int = 3
                    ) -> list[dict[str, float]]:
    """Time a jitted streaming triad ``z = x + 1.5 y`` per working-set
    size; each entry carries the footprint, the bytes moved (read x,
    read y, write z) and the median us."""

    import jax
    import jax.numpy as jnp
    from ..kernels.common import time_fn
    f = jax.jit(lambda x, y: x + 1.5 * y)
    out = []
    for fp in footprints:
        n = max(1, int(fp) // (3 * 4))      # 3 f32 arrays in the set
        x = jnp.ones((n,), "float32")
        y = jnp.ones((n,), "float32")
        us = time_fn(lambda: f(x, y), warmup=warmup, iters=iters)
        out.append({"footprint": float(fp), "bytes": float(3 * n * 4),
                    "us": us})
    return out


def dispatch_latency_sweep(reps: int = 16, *, warmup: int = 4
                           ) -> list[float]:
    """Per-dispatch wall-clock us of a tiny jitted kernel (a scalar
    add): each sample is ONE timed dispatch, so the sweep captures the
    launch-latency distribution rather than a throughput average."""

    import jax
    import jax.numpy as jnp
    from ..kernels.common import time_fn
    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros(())
    for _ in range(max(0, warmup)):
        jax.block_until_ready(f(x))
    return [time_fn(lambda: f(x), warmup=0, iters=1)
            for _ in range(max(1, reps))]


def collective_bw_sweep(sizes: Sequence[int] = (1 << 20,), *,
                        warmup: int = 1, iters: int = 3
                        ) -> list[dict[str, float]]:
    """Time an all-reduce (psum) across local devices; empty on
    single-device hosts — the fit then leaves ``link_bw`` at the
    default and omits it from the spec's ``fitted`` list."""

    import jax
    n_dev = len(jax.devices())
    if n_dev < 2:
        return []
    import jax.numpy as jnp
    from ..kernels.common import time_fn
    f = jax.pmap(lambda x: jax.lax.psum(x, "i"), axis_name="i")
    out = []
    for size in sizes:
        n = max(1, int(size) // 4)
        x = jnp.ones((n_dev, n), "float32")
        us = time_fn(lambda: f(x), warmup=warmup, iters=iters)
        # ring all-reduce moves 2*(n-1)/n of the payload per device
        bytes_per_dev = 2 * (n_dev - 1) / n_dev * n * 4
        out.append({"size": float(size), "devices": n_dev,
                    "bytes_per_device": bytes_per_dev, "us": us})
    return out


# -- fits (pure, deterministic) ---------------------------------------------


def fit_peak_flops(sweep: Sequence[Mapping[str, float]]) -> float:
    """Best achieved FLOP/s across the matmul ladder (the ERT reading
    of the compute roof)."""

    if not sweep:
        raise CalibrationError("empty matmul sweep")
    return max(p["flops"] / (p["us"] * 1e-6) for p in sweep)


def fit_bandwidth(sweep: Sequence[Mapping[str, float]]) -> float:
    """Bytes/s at the LARGEST footprint — the main-memory roof, past
    the cache hierarchy (small footprints report cache bandwidth)."""

    if not sweep:
        raise CalibrationError("empty memory sweep")
    biggest = max(sweep, key=lambda p: p["bytes"])
    return biggest["bytes"] / (biggest["us"] * 1e-6)


def fit_dispatch_us(samples: Sequence[float]) -> float:
    """Median per-dispatch latency in us."""

    if not samples:
        raise CalibrationError("empty dispatch sweep")
    from ..kernels.common import median
    return median(samples)


def fit_link_bw(sweep: Sequence[Mapping[str, float]]) -> float | None:
    """Per-link bytes/s from the collective sweep (aggregate achieved
    bandwidth split over the default link count); ``None`` when the
    probe could not run (single device)."""

    if not sweep:
        return None
    best = max(p["bytes_per_device"] / (p["us"] * 1e-6) for p in sweep)
    return best / DEFAULT_SPEC.links


# -- calibration front door --------------------------------------------------


def run_calibration(*, matmul_sizes: Sequence[int] | None = None,
                    footprints: Sequence[int] | None = None,
                    dispatch_reps: int = 16, warmup: int = 1,
                    iters: int = 3, quick: bool = False) -> PlatformSpec:
    """Run every probe and fit a calibrated :class:`PlatformSpec` for
    the running device.  ``quick=True`` shrinks the ladders to the CI /
    test sizes (same probes, smaller working sets)."""

    if matmul_sizes is None:
        matmul_sizes = QUICK_MATMUL_SIZES if quick else MATMUL_SIZES
    if footprints is None:
        footprints = QUICK_TRIAD_FOOTPRINTS if quick else TRIAD_FOOTPRINTS

    mm = matmul_flops_sweep(matmul_sizes, warmup=warmup, iters=iters)
    tr = memory_bw_sweep(footprints, warmup=warmup, iters=iters)
    dp = dispatch_latency_sweep(dispatch_reps)
    co = collective_bw_sweep(warmup=warmup, iters=iters)

    fitted: dict[str, float] = {
        "peak_flops": fit_peak_flops(mm),
        "hbm_bw": fit_bandwidth(tr),
        "dispatch_us": fit_dispatch_us(dp),
    }
    link = fit_link_bw(co)
    if link is not None:
        fitted["link_bw"] = link

    dev = device_fingerprint()
    return calibrated_replace(
        DEFAULT_SPEC, backend=dev["backend"],
        device_kind=dev["device_kind"],
        probes={"matmul": mm, "triad": tr, "dispatch": dp,
                "collective": co, "fitted": sorted(fitted),
                "quick": bool(quick)},
        **fitted)


def ensure_calibrated(path=None, *, force: bool = False,
                      install: bool = True, save: bool = True,
                      quick: bool = False,
                      **probe_kw: Any) -> tuple[PlatformSpec, bool]:
    """Load-or-probe: return ``(spec, probed)`` where ``probed`` says
    whether the probes actually ran.

    A schema-current artifact at ``path`` (default: :func:`spec_path`)
    calibrated on THIS device is a pure load — zero probes, the
    property the CI smoke asserts.  Otherwise (missing, stale schema,
    foreign device, or ``force=True``) the probes run and the fitted
    spec is written back.  ``install=True`` makes the result the
    process-wide active spec (:func:`set_platform_spec`)."""

    path = spec_path(path)
    if not force:
        try:
            spec = load_spec(path)
            if spec.matches_device():
                if install:
                    set_platform_spec(spec)
                return spec, False
        except (OSError, CalibrationError):
            pass
    spec = run_calibration(quick=quick, **probe_kw)
    if save:
        spec.save(path)
    if install:
        set_platform_spec(spec)
    return spec, True


__all__ = ["MATMUL_SIZES", "TRIAD_FOOTPRINTS", "QUICK_MATMUL_SIZES",
           "QUICK_TRIAD_FOOTPRINTS", "matmul_flops_sweep",
           "memory_bw_sweep", "dispatch_latency_sweep",
           "collective_bw_sweep", "fit_peak_flops", "fit_bandwidth",
           "fit_dispatch_us", "fit_link_bw", "run_calibration",
           "ensure_calibrated"]
