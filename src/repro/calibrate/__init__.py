"""Platform calibration: measured device constants for the cost models.

The paper model-checks a *faithful* platform model; faithfulness starts
with the constants.  This package measures them — ERT-style probes
(:mod:`.probes`) fit peak FLOP/s, memory bandwidth, dispatch latency
and (multi-device) link bandwidth into a schema-versioned
:class:`PlatformSpec` artifact (:mod:`.spec`), every cost model resolves
its constants through :func:`get_platform_spec`, the tuning cache keys
on :func:`calibration_hash`, and :mod:`.trajectory` tracks the
modeled-vs-measured gap per tunable over time.

CLI: ``python -m repro.calibrate run|show|export``.
"""

from .probes import (collective_bw_sweep, dispatch_latency_sweep,
                     ensure_calibrated, fit_bandwidth, fit_dispatch_us,
                     fit_link_bw, fit_peak_flops, matmul_flops_sweep,
                     memory_bw_sweep, run_calibration)
from .spec import (DEFAULT_SPEC, SPEC_KIND, SPEC_SCHEMA, CalibrationError,
                   PlatformSpec, calibration_hash, device_fingerprint,
                   get_platform_spec, load_spec, set_platform_spec,
                   spec_path)
from .trajectory import (TRAJECTORY_PATH, append_run, gap_from_stats,
                         load_trajectory, measure_gap, run_trajectory)

__all__ = [
    # spec + resolver
    "PlatformSpec", "CalibrationError", "DEFAULT_SPEC", "SPEC_SCHEMA",
    "SPEC_KIND", "load_spec", "spec_path", "get_platform_spec",
    "set_platform_spec", "calibration_hash", "device_fingerprint",
    # probes
    "matmul_flops_sweep", "memory_bw_sweep", "dispatch_latency_sweep",
    "collective_bw_sweep", "fit_peak_flops", "fit_bandwidth",
    "fit_dispatch_us", "fit_link_bw", "run_calibration",
    "ensure_calibrated",
    # trajectory
    "TRAJECTORY_PATH", "gap_from_stats", "measure_gap",
    "load_trajectory", "append_run", "run_trajectory",
]
