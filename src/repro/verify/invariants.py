"""The properties: each a ``G p`` safety/liveness invariant evaluated
over a model's global variables, so the existing reachability reduction
(:mod:`repro.core.properties`: a violation of ``G p`` is a reachable
state with ``not p``) applies unchanged.

Liveness properties ("the oldest slot always eventually progresses",
"no request is starved past the aging barrier") are encoded as bounded
ghost counters in the model (``stall``, ``skips``) so "eventually"
becomes "within B steps" — a safety invariant the DFS can refute with a
concrete trail.

All allocator-level invariants read the canonical projection
``G["alloc"] == (pt, ref, own, free, top)`` shared by every model AND
by the real :meth:`~repro.runtime.kv.PagedKVAllocator.project`, so the
same predicates double as the concrete-state check during conformance
replay."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from ..runtime.kv import NO_PAGE
from .harness import ServerConfig
from .models import SpecConfig


@dataclass(frozen=True)
class Invariant:
    name: str
    description: str
    violates: Callable[[dict], bool]


def violated(invariants: list[Invariant], G: dict) -> list[str]:
    """Names of every invariant ``G`` breaks."""

    return [inv.name for inv in invariants if inv.violates(G)]


def violates_any(invariants: list[Invariant]) -> Callable[[dict], bool]:
    """The explorer-facing predicate: True when any invariant breaks."""

    def _violates(G: dict) -> bool:
        return any(inv.violates(G) for inv in invariants)
    return _violates


# ---------------------------------------------------------------------------
# allocator safety (shared by all three models and the concrete check)
# ---------------------------------------------------------------------------


def _mapped(pt) -> list[int]:
    return [p for row in pt for p in row if p != NO_PAGE]


def _conservation(G) -> bool:
    pt, ref, own, free, top = G["alloc"]
    return sum(ref) != len(_mapped(pt))


def _no_lost_pages(G) -> bool:
    pt, ref, own, free, top = G["alloc"]
    held = {p for p in range(len(ref)) if ref[p] > 0}
    return len(free) + len(held) != len(ref) or bool(held & set(free))


def _no_double_free(G) -> bool:
    free = G["alloc"][3]
    return len(set(free)) != len(free)


def _freed_never_mapped(G) -> bool:
    pt, ref, own, free, top = G["alloc"]
    freed = set(free)
    return any(p in freed or ref[p] < 1 for p in _mapped(pt))


def _owner_consistent(G) -> bool:
    pt, ref, own, free, top = G["alloc"]
    for p in range(len(ref)):
        if ref[p] > 0:
            if own[p] == NO_PAGE or p not in pt[own[p]]:
                return True
        elif own[p] != NO_PAGE:
            return True
    return False


def _high_water(G) -> bool:
    pt, ref, own, free, top = G["alloc"]
    return any(pt[s][lp] != NO_PAGE
               for s in range(len(pt))
               for lp in range(top[s] + 1, len(pt[s])))


def allocator_invariants() -> list[Invariant]:
    """Refcount conservation and friends; shape-free (everything is
    read off the projection itself)."""

    return [
        Invariant("refcount_conservation",
                  "sum(refcounts) == number of live page-table entries",
                  _conservation),
        Invariant("no_lost_pages",
                  "every page is free xor held; the two sets partition "
                  "the pool", _no_lost_pages),
        Invariant("no_double_free",
                  "the free list never holds a page twice",
                  _no_double_free),
        Invariant("freed_never_mapped",
                  "no live table entry points at a freed page",
                  _freed_never_mapped),
        Invariant("owner_consistent",
                  "a held page's owner maps it; a free page has no owner",
                  _owner_consistent),
        Invariant("high_water_clean",
                  "no table entry above the slot's high-water mark",
                  _high_water),
    ]


# ---------------------------------------------------------------------------
# scheduler x server
# ---------------------------------------------------------------------------


def server_invariants(cfg: ServerConfig) -> list[Invariant]:
    def _progress_lost(G) -> bool:
        return bool(G["err"] & 1)

    def _livelock(G) -> bool:
        return G["stall"] > cfg.stall_bound

    def _starved(G) -> bool:
        limit = cfg.age_limit + cfg.aging_slack
        return any(t[1] > limit for t in G["rq"])

    def _backing_misaligned(G) -> bool:
        pt, ref, own, free, top = G["alloc"]
        ps = cfg.page_size
        for s in range(cfg.batch):
            if G["slots"][s] >= 0:
                need = -(-max(0, G["pos"][s]) // ps)
                if top[s] != need - 1:
                    return True
        return False

    return allocator_invariants() + [
        Invariant("progress_kept",
                  "a request's generated-token count never decreases "
                  "(preemption keeps progress)", _progress_lost),
        Invariant("no_livelock",
                  f"the oldest live slot makes fresh progress within "
                  f"{cfg.stall_bound} ticks (OOM-defer-youngest cannot "
                  f"starve it)", _livelock),
        Invariant("aging_barrier",
                  f"no queued request is bypassed more than age_limit"
                  f"+{cfg.aging_slack} times", _starved),
        Invariant("slot_backing",
                  "every live slot's pages exactly back its position",
                  _backing_misaligned),
    ]


def drain_incomplete(G: dict) -> list[str]:
    """Terminal-state check (deadlock-freedom half of liveness): when
    no op is enabled anymore, every submitted request must have retired
    with at least one generated token."""

    bad = []
    for rid, t in enumerate(G["rq"]):
        if t[0] != 3 or t[2] < 1:
            bad.append(f"request {rid} ended in status {t[0]} "
                       f"with {t[2]} tokens")
    return bad


# ---------------------------------------------------------------------------
# speculate-commit-rewind
# ---------------------------------------------------------------------------


def spec_invariants(cfg: SpecConfig) -> list[Invariant]:
    def _contract(G) -> bool:
        return bool(G["err"] & 1)

    def _prefix_moved(G) -> bool:
        return bool(G["err"] & 2)

    def _rewind_incomplete(G) -> bool:
        pt, ref, own, free, top = G["alloc"]
        ps = cfg.page_size
        for s in (0, 1):
            if G["done"][s]:
                if top[s] != -1 or any(p != NO_PAGE for p in pt[s]):
                    return True
            else:
                need = -(-max(0, G["pos"][s]) // ps)
                if top[s] != need - 1:
                    return True
        return False

    return allocator_invariants() + [
        Invariant("spec_alloc_contract",
                  "guarded ensure/rewind calls succeed as the real "
                  "allocator's contract promises", _contract),
        Invariant("spec_prefix_stable",
                  "the committed prefix's page mapping survives the "
                  "speculate-commit-rewind cycle", _prefix_moved),
        Invariant("spec_rewind_complete",
                  "after every cycle a slot backs exactly its committed "
                  "positions (no page leaked to rejected drafts)",
                  _rewind_incomplete),
    ]


__all__ = ["Invariant", "allocator_invariants", "server_invariants",
           "spec_invariants", "drain_incomplete", "violated",
           "violates_any"]
