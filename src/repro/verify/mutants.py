"""Deliberately broken allocator variants: the checker's own test bed.

A verifier that has never found a bug is indistinguishable from one
that cannot.  Each mutant plants a realistic single-edit bug in a
:class:`~repro.runtime.kv.PagedKVAllocator` subclass — the refcount
dropped on the wrong side of a copy, a loop guard that quietly skips
shared pages — and the test suite (plus ``python -m repro.verify
mutants``) asserts that :func:`~repro.verify.conformance.coupled_explore`
catches every one with a counterexample trail that
:func:`~repro.verify.conformance.replay_ops` reproduces as a concrete
real-allocator failure.
"""

from __future__ import annotations

from ..runtime.kv import NO_PAGE, PagedKVAllocator, _traced

MUTANTS: dict[str, type[PagedKVAllocator]] = {}

# the base allocator's op-trace surface; mutant overrides of these must
# re-wrap in ``_traced`` or the buggy op itself vanishes from the op
# stream an online monitor records (the violation would still be caught
# via state projection, but the dumped trail could not reproduce it)
_TRACED_OPS = ("ensure", "share", "cow_pages", "release", "rewind", "trim")


def _mutant(name: str):
    def deco(cls):
        for op in _TRACED_OPS:
            if op in vars(cls):
                setattr(cls, op, _traced(vars(cls)[op]))
        MUTANTS[name] = cls
        return cls
    return deco


@_mutant("cow-deref-before-copy")
class CowDerefBeforeCopy(PagedKVAllocator):
    """cow_pages drops the old page's reference BEFORE remapping the
    table entry and checks the free list per page instead of up front:
    the owner-handoff logic sees the stale mapping (owner ends up naming
    a slot that no longer maps the page), and on free-list exhaustion
    the op bails mid-loop, breaking all-or-nothing."""

    def cow_pages(self, slot, start_pos, end_pos):
        if end_pos <= start_pos:
            return []
        ps = self.spec.page_size
        lo = start_pos // ps
        hi = min((end_pos - 1) // ps, self.spec.pages_per_slot - 1)
        pairs = []
        for lp in range(lo, hi + 1):
            if not self.is_shared(slot, lp):
                continue
            old = int(self.page_table[slot, lp])
            self._deref(old)                  # BUG: before the copy
            if not self._free:
                return None                   # BUG: partial on failure
            new = self._free.pop()
            self.page_table[slot, lp] = new
            self.owner[new] = slot
            self.refcount[new] = 1
            pairs.append((old, new))
        return pairs


@_mutant("rewind-keeps-shared")
class RewindKeepsShared(PagedKVAllocator):
    """rewind skips refcount>1 pages entirely — the table keeps mapping
    them ABOVE the lowered high-water mark, and the sharer's refcount
    never comes back down."""

    def rewind(self, slot, n_tokens):
        keep = self.pages_needed(n_tokens)
        freed = 0
        for lp in range(keep, int(self._top[slot]) + 1):
            page = int(self.page_table[slot, lp])
            if page != NO_PAGE and int(self.refcount[page]) == 1:  # BUG
                self.page_table[slot, lp] = NO_PAGE
                if self._deref(page):
                    freed += 1
        self._top[slot] = min(int(self._top[slot]), keep - 1)
        return freed


@_mutant("release-leaks-shared")
class ReleaseLeaksShared(PagedKVAllocator):
    """release clears the table but forgets to deref pages other slots
    still share: their refcount stays one too high forever (a page leak
    once the sharer retires too)."""

    def release(self, slot):
        pages = self.slot_pages(slot)
        self.page_table[slot] = NO_PAGE
        self._top[slot] = -1
        for page in pages:
            if int(self.refcount[page]) == 1:   # BUG: shared pages skipped
                self._deref(page)
        return len(pages)


@_mutant("ensure-partial-on-oom")
class EnsurePartialOnOOM(PagedKVAllocator):
    """ensure allocates page by page and returns False when the free
    list runs dry mid-growth — the pages already grabbed stay mapped,
    breaking the all-or-nothing contract callers rely on for eviction
    retries."""

    def ensure(self, slot, n_tokens):
        if n_tokens <= 0:
            return True
        top_needed = (n_tokens - 1) // self.spec.page_size
        if top_needed >= self.spec.pages_per_slot:
            raise ValueError("exceeds page table")
        for lp in range(int(self._top[slot]) + 1, top_needed + 1):
            if not self._free:
                return False                    # BUG: keeps partial growth
            page = self._free.pop()
            self.page_table[slot, lp] = page
            self.owner[page] = slot
            self.refcount[page] = 1
            self._top[slot] = lp
        return True


@_mutant("trim-stale-entry")
class TrimStaleEntry(PagedKVAllocator):
    """trim frees the page but forgets to clear the table entry: the
    slot keeps a live mapping to a page back on the free list (the
    freed-page-referenced class of bug)."""

    def trim(self, slot, keep_from_pos):
        ps = self.spec.page_size
        freed = 0
        for lp in range(min(keep_from_pos // ps, self.spec.pages_per_slot)):
            page = int(self.page_table[slot, lp])
            if page != NO_PAGE:
                if self._deref(page):           # BUG: entry not cleared
                    freed += 1
        return freed


@_mutant("share-skips-refcount")
class ShareSkipsRefcount(PagedKVAllocator):
    """share maps the source's pages into the destination table without
    bumping refcounts — the first release by either slot frees pages
    the other still reads."""

    def share(self, src_slot, dst_slot, n_tokens):
        if n_tokens <= 0:
            return 0
        if int(self._top[dst_slot]) != -1 or self.slot_pages(dst_slot):
            raise ValueError("share: dst not empty")
        need = self.pages_needed(n_tokens)
        row = self.page_table[src_slot, :need]
        if (row == NO_PAGE).any():
            raise ValueError("share: src does not back the range")
        for lp in range(need):
            self.page_table[dst_slot, lp] = int(row[lp])   # BUG: no ref++
        self._top[dst_slot] = need - 1
        return need


__all__ = ["MUTANTS", "CowDerefBeforeCopy", "RewindKeepsShared",
           "ReleaseLeaksShared", "EnsurePartialOnOOM", "TrimStaleEntry",
           "ShareSkipsRefcount"]
