"""AST lint for the runtime tree: three rules codifying hard-won
serving-runtime lessons, each a bug class the model checker cannot see
because it lives in the device/host seam or in incidental dict order.

* ``alias-dispatch`` — ``jnp.asarray`` at a dispatch site must take a
  provably FRESH host buffer (assigned in the same function from a
  ``np.*`` constructor, ``.copy()``, or ``_snapshot``).  Passing a
  long-lived mutable buffer (``self.slot_pos``, a ``getattr`` alias)
  relies on asarray's zero-copy aliasing *not* observing a later
  in-place write — a race the jit boundary hides until it corrupts a
  batch.  The same rule flags raw host-buffer attributes
  (``page_table``, ``slot_pos``, ...) passed straight into
  ``_step``/``_prefill_step``/``_verify_step``.
* ``pool-write`` — in-place overwrite of a shared pool entry's
  ``"kv"`` leaf.  The prefix-cache blocks are shared across requests;
  an unguarded write invalidates other holders' views.  Audited sites
  carry a waiver.
* ``ordered-policy`` — in scheduler modules, iterating a dict's
  ``.values()``/``.items()``/``.keys()`` in a loop or comprehension
  (or ``min``/``max`` with ``key=`` over one) makes a *policy
  decision* depend on insertion order; wrap in ``sorted(...)``.

Waivers: ``# verify: waive(<rule>) -- <reason>`` on the finding's line
or the line above.  The reason is mandatory — a bare waiver does not
waive (the point is an audit trail, not an off switch).
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path

# host-side buffers the Server mutates in place between dispatches
HOST_BUFFERS = {"slot_pos", "page_table", "refcount", "owner",
                "_top", "_slot_seq"}
DISPATCH_FNS = {"_step", "_prefill_step", "_verify_step"}
FRESH_NP_CTORS = {"zeros", "array", "ones", "full", "empty", "arange",
                  "asarray", "zeros_like", "ones_like", "full_like",
                  "empty_like", "copy", "ascontiguousarray", "stack",
                  "concatenate"}
ORDERED_METHODS = {"values", "items", "keys"}

_WAIVE_RE = re.compile(r"#\s*verify:\s*waive\(([a-z-]+)\)(?:\s*--\s*(.*))?")

RULES = {
    "alias-dispatch": "jnp.asarray / dispatch call takes a host buffer "
                      "that is not provably fresh in this function",
    "pool-write": "in-place overwrite of a shared pool entry's 'kv' leaf",
    "ordered-policy": "scheduler decision iterates a dict in insertion "
                      "order (wrap in sorted(...))",
}


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str
    waived: bool = False

    def __str__(self) -> str:
        tag = " (waived)" if self.waived else ""
        return f"{self.path}:{self.line}: [{self.rule}]{tag} {self.message}"


def _is_np_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id in ("np", "numpy")
            and node.func.attr in FRESH_NP_CTORS)


def _is_fresh_value(node: ast.AST) -> bool:
    """A value that cannot alias long-lived mutable host state."""

    if isinstance(node, (ast.Constant, ast.List, ast.Tuple, ast.ListComp,
                         ast.GeneratorExp)):
        return True
    if _is_np_call(node):
        return True
    if isinstance(node, ast.Call):
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "copy":
            return True
        name = f.id if isinstance(f, ast.Name) else (
            f.attr if isinstance(f, ast.Attribute) else "")
        if name.endswith("_snapshot"):
            return True
    return False


class _FnLint(ast.NodeVisitor):
    """Per-function pass: freshness environment + the two aliasing
    rules (alias-dispatch, pool-write)."""

    def __init__(self, path: str, findings: list[Finding]):
        self.path = path
        self.findings = findings
        self.fresh: set[str] = set()
        self.tainted: set[str] = set()

    # -- freshness environment ----------------------------------------------

    def _scan_assignments(self, fn: ast.AST) -> None:
        for node in ast.walk(fn):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets, value = list(node.targets), node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            else:
                continue
            for t in targets:
                if isinstance(t, ast.Name):
                    if _is_fresh_value(value):
                        self.fresh.add(t.id)
                    else:
                        self.tainted.add(t.id)

    def _name_fresh(self, name: str) -> bool:
        return name in self.fresh and name not in self.tainted

    # -- the rules ----------------------------------------------------------

    def _check_asarray_arg(self, call: ast.Call, arg: ast.expr) -> None:
        if _is_fresh_value(arg):
            return
        if isinstance(arg, ast.Name):
            if self._name_fresh(arg.id):
                return
            self.findings.append(Finding(
                self.path, call.lineno, "alias-dispatch",
                f"jnp.asarray({arg.id}) — '{arg.id}' is not assigned "
                f"from a fresh buffer in this function"))
        elif isinstance(arg, ast.Attribute):
            self.findings.append(Finding(
                self.path, call.lineno, "alias-dispatch",
                f"jnp.asarray(...{arg.attr}) aliases an attribute — "
                f"long-lived host state at a dispatch boundary"))
        elif isinstance(arg, ast.Subscript):
            self.findings.append(Finding(
                self.path, call.lineno, "alias-dispatch",
                "jnp.asarray(<subscript>) may alias a view of "
                "long-lived host state"))

    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "asarray" \
                and isinstance(f.value, ast.Name) and f.value.id == "jnp" \
                and node.args:
            self._check_asarray_arg(node, node.args[0])
        if isinstance(f, ast.Attribute) and f.attr in DISPATCH_FNS:
            for arg in node.args:
                if isinstance(arg, ast.Attribute) \
                        and arg.attr in HOST_BUFFERS:
                    self.findings.append(Finding(
                        self.path, node.lineno, "alias-dispatch",
                        f"raw host buffer .{arg.attr} passed to "
                        f"{f.attr}() — snapshot it first"))
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            if isinstance(t, ast.Subscript) \
                    and isinstance(t.slice, ast.Constant) \
                    and t.slice.value == "kv":
                self.findings.append(Finding(
                    self.path, node.lineno, "pool-write",
                    "in-place overwrite of a shared pool entry's "
                    "'kv' leaf"))
        self.generic_visit(node)


def _lint_ordered_policy(path: str, tree: ast.AST,
                         findings: list[Finding]) -> None:
    def dict_method(node: ast.AST) -> str | None:
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ORDERED_METHODS:
            return node.func.attr
        return None

    for node in ast.walk(tree):
        iters: list[ast.expr] = []
        if isinstance(node, (ast.For, ast.AsyncFor)):
            iters = [node.iter]
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            iters = [g.iter for g in node.generators]
        for it in iters:
            m = dict_method(it)
            if m:
                findings.append(Finding(
                    path, it.lineno, "ordered-policy",
                    f"iteration over .{m}() in a scheduler module "
                    f"depends on dict insertion order"))
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("min", "max") \
                and any(kw.arg == "key" for kw in node.keywords):
            for arg in node.args:
                m = dict_method(arg)
                if m:
                    findings.append(Finding(
                        path, node.lineno, "ordered-policy",
                        f"{node.func.id}(key=...) over .{m}() picks by "
                        f"dict insertion order on ties"))


# ---------------------------------------------------------------------------
# waivers + entry points
# ---------------------------------------------------------------------------


@dataclass
class LintReport:
    findings: list[Finding] = field(default_factory=list)   # unwaived
    waived: list[Finding] = field(default_factory=list)
    bad_waivers: list[Finding] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings and not self.bad_waivers

    def extend(self, other: "LintReport") -> None:
        self.findings.extend(other.findings)
        self.waived.extend(other.waived)
        self.bad_waivers.extend(other.bad_waivers)


def _apply_waivers(findings: list[Finding],
                   lines: list[str]) -> LintReport:
    rep = LintReport()
    for f in findings:
        waived = False
        # the finding's own line, then upward through the contiguous
        # comment block above it (a waiver may open a multi-line
        # justification)
        candidates = [f.line]
        ln = f.line - 1
        while 1 <= ln <= len(lines) and \
                lines[ln - 1].lstrip().startswith("#"):
            candidates.append(ln)
            ln -= 1
        for ln in candidates:
            if not 1 <= ln <= len(lines):
                continue
            m = _WAIVE_RE.search(lines[ln - 1])
            if m and m.group(1) == f.rule:
                if m.group(2) and m.group(2).strip():
                    waived = True
                else:
                    rep.bad_waivers.append(Finding(
                        f.path, ln, f.rule,
                        "waiver without a reason (use "
                        "'# verify: waive(rule) -- why')"))
                break
        if waived:
            rep.waived.append(Finding(f.path, f.line, f.rule,
                                      f.message, waived=True))
        else:
            rep.findings.append(f)
    return rep


def lint_source(src: str, path: str = "<string>") -> LintReport:
    tree = ast.parse(src, filename=path)
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn = _FnLint(path, findings)
            fn._scan_assignments(node)
            for stmt in node.body:
                fn.visit(stmt)
    if "scheduler" in Path(path).name:
        _lint_ordered_policy(path, tree, findings)
    dedup: dict[tuple, Finding] = {}
    for f in findings:
        dedup.setdefault((f.path, f.line, f.rule, f.message), f)
    return _apply_waivers(sorted(dedup.values(),
                                 key=lambda f: (f.path, f.line)),
                          src.splitlines())


def lint_paths(paths: list[str | Path]) -> LintReport:
    rep = LintReport()
    for root in paths:
        root = Path(root)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for py in files:
            rep.extend(lint_source(py.read_text(), str(py)))
    return rep


__all__ = ["Finding", "LintReport", "RULES", "lint_paths", "lint_source"]
