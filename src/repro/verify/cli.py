"""``python -m repro.verify`` — the runtime verification gate.

::

    python -m repro.verify check            # exhaustive model checks
    python -m repro.verify check --json     # machine-readable report
    python -m repro.verify lint [paths...]  # AST rules on the runtime
    python -m repro.verify mutants          # the checker must catch all
    python -m repro.verify replay --trail verify_trails/<name>.json

``check`` explores every model/invariant pair exhaustively on the
bounded configs below; a violation writes a replayable trail JSON to
``--trail-dir`` and exits 1.  ``mutants`` proves the detector detects:
every planted allocator bug must yield a counterexample trail that
``replay`` then reproduces as a concrete real-allocator failure.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

from ..core.explorer import explore
from .conformance import (ConformanceError, coupled_explore, ops_from_trail,
                          replay_ops)
from .harness import ServerConfig, ServerScenario
from .invariants import (allocator_invariants, drain_incomplete,
                         server_invariants, spec_invariants, violated,
                         violates_any)
from .lint import lint_paths
from .models import (AllocConfig, AllocatorSemantics, ServerSemantics,
                     SpecConfig, SpecSemantics, build_driver_model)
from .mutants import MUTANTS

DEFAULT_LINT_PATHS = ["src/repro/runtime"]
DEFAULT_TRAIL_DIR = "verify_trails"

# the acceptance matrix: every config keeps >=2 slots and, for the
# allocator/server machines, the full 6-page over-committed pool with
# share + preemption + rewind reachable
ALLOC_CFG = AllocConfig()          # 3 slots x 3 pages > 6 physical

SERVER_CASES: dict[str, tuple[ServerConfig, ServerScenario]] = {
    "server-fcfs-pressure": (
        ServerConfig(policy="fcfs", batch=3),
        ServerScenario(name="pressure",
                       prompts=((3, 3, 3, 3), (4, 4, 4, 4), (5, 5, 5, 5)),
                       max_new=(2, 2, 2))),
    "server-fcfs-share": (
        ServerConfig(policy="fcfs", batch=3, share_prefix=True),
        ServerScenario(name="share",
                       prompts=((7, 7, 7, 7), (7, 7, 7, 5), (7, 7)),
                       max_new=(2, 1, 1))),
    "server-priority": (
        ServerConfig(policy="priority", batch=2, aging_slack=3),
        ServerScenario(name="slo-mix",
                       prompts=((3, 3, 3), (4, 4), (5, 5, 5)),
                       max_new=(2, 1, 1),
                       slo=("batch", "interactive", "interactive"))),
    "server-prefix": (
        ServerConfig(policy="prefix", batch=3, share_prefix=True),
        ServerScenario(name="prefix-family",
                       prompts=((7, 7, 7, 7), (7, 7, 7, 5), (9, 9)),
                       max_new=(2, 1, 1))),
}

SPEC_CFG = SpecConfig()


def _write_trail(trail_dir: Path, name: str, payload: dict) -> Path:
    trail_dir.mkdir(parents=True, exist_ok=True)
    path = trail_dir / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2))
    return path


def _record(name: str, res, *, kind: str, violations=(), message="",
            trail: str | None = None) -> dict:
    return {
        "name": name,
        "kind": kind,
        "status": res.status,
        "states": res.states,
        "transitions": res.transitions,
        "max_depth": res.max_depth,
        "frontier_peak": getattr(res, "frontier_peak", 0),
        "bound_reason": getattr(res, "bound_reason", None),
        "elapsed_s": round(res.elapsed_s, 3),
        "violations": list(violations),
        "message": message,
        "trail": trail,
    }


# ---------------------------------------------------------------------------
# check
# ---------------------------------------------------------------------------


def _check_alloc_invariants(trail_dir: Path, max_states: int) -> dict:
    sem = AllocatorSemantics(ALLOC_CFG, canonical=True)
    invs = allocator_invariants()
    res = explore(build_driver_model(sem), violates_any(invs),
                  schedule="por", max_states=max_states)
    violations, message, trail = [], "", None
    if res.counterexample is not None:
        violations = violated(invs, res.counterexample.globals)
        message = f"allocator invariants broken: {violations}"
        trail = str(_write_trail(trail_dir, "alloc-invariants", {
            "model": "allocator", "allocator": "real",
            "config": dataclasses.asdict(ALLOC_CFG),
            "ops": ops_from_trail(res.counterexample.trail),
            "violations": violations, "message": message}))
    return _record("alloc-invariants", res, kind="model",
                   violations=violations, message=message, trail=trail)


def _check_alloc_conformance(trail_dir: Path, max_states: int) -> dict:
    sem = AllocatorSemantics(ALLOC_CFG, canonical=True)
    res = coupled_explore(sem, max_states=max_states)
    trail = None
    if not res.ok:
        trail = str(_write_trail(trail_dir, "alloc-conformance", {
            "model": "allocator", "allocator": "real",
            "config": dataclasses.asdict(ALLOC_CFG),
            "ops": [list(op) for op in res.ops],
            "message": res.message}))
    return _record("alloc-conformance", res, kind="conformance",
                   violations=["conformance"] if not res.ok else [],
                   message=res.message, trail=trail)


def _check_server(name: str, cfg: ServerConfig, scen: ServerScenario,
                  trail_dir: Path, max_states: int) -> dict:
    sem = ServerSemantics(cfg, scen)
    invs = server_invariants(cfg)
    res = explore(build_driver_model(sem), violates_any(invs),
                  schedule="por", max_states=max_states,
                  collect_terminals=True)
    violations, message, trail = [], "", None
    if res.counterexample is not None:
        violations = violated(invs, res.counterexample.globals)
        message = f"server invariants broken: {violations}"
        bad_trail = res.counterexample.trail
    else:
        drain = [(t, b) for t in res.terminals
                 for b in drain_incomplete(t.globals)]
        if drain:
            violations = ["drain_complete"]
            message = "; ".join(b for _, b in drain[:3])
            bad_trail = drain[0][0].trail
        else:
            bad_trail = None
    if bad_trail is not None:
        trail = str(_write_trail(trail_dir, name, {
            "model": "server", "policy": cfg.policy,
            "config": dataclasses.asdict(cfg),
            "scenario": dataclasses.asdict(scen),
            "ops": [list(op) for op in ops_from_trail(bad_trail)],
            "violations": violations, "message": message}))
        if not violations:   # pragma: no cover - defensive
            violations = ["unknown"]
    rec = _record(name, res, kind="model", violations=violations,
                  message=message, trail=trail)
    if violations and rec["status"] == "verified":
        rec["status"] = "violated"           # drain failures at terminals
    return rec


def _check_spec(trail_dir: Path, max_states: int) -> dict:
    sem = SpecSemantics(SPEC_CFG)
    invs = spec_invariants(SPEC_CFG)
    res = explore(build_driver_model(sem), violates_any(invs),
                  schedule="por", max_states=max_states)
    violations, message, trail = [], "", None
    if res.counterexample is not None:
        violations = violated(invs, res.counterexample.globals)
        message = f"speculation invariants broken: {violations}"
        trail = str(_write_trail(trail_dir, "spec-cycle", {
            "model": "spec", "config": dataclasses.asdict(SPEC_CFG),
            "ops": [list(op) for op in
                    ops_from_trail(res.counterexample.trail)],
            "violations": violations, "message": message}))
    return _record("spec-cycle", res, kind="model",
                   violations=violations, message=message, trail=trail)


def _cmd_check(args) -> int:
    trail_dir = Path(args.trail_dir)
    checks = [_check_alloc_invariants(trail_dir, args.max_states),
              _check_alloc_conformance(trail_dir, args.max_states)]
    for name, (cfg, scen) in SERVER_CASES.items():
        checks.append(_check_server(name, cfg, scen, trail_dir,
                                    args.max_states))
    checks.append(_check_spec(trail_dir, args.max_states))
    ok = all(c["status"] != "violated" for c in checks)
    exhaustive = all(c["status"] == "verified" for c in checks)
    report = {"ok": ok, "exhaustive": exhaustive, "checks": checks}
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        for c in checks:
            line = (f"{c['name']:<24} {c['status']:<9} "
                    f"states={c['states']:<8} trans={c['transitions']:<8} "
                    f"depth={c['max_depth']:<5} {c['elapsed_s']:.1f}s")
            print(line)
            if c["violations"]:
                print(f"  VIOLATED: {c['violations']}  {c['message']}")
                if c["trail"]:
                    print(f"  trail: {c['trail']}")
            elif c["status"] == "bounded":
                print(f"  bound exhausted ({c['bound_reason']}) — NOT a "
                      f"verification result")
        print("result:", "PASS" if ok else "FAIL",
              "(exhaustive)" if exhaustive else "(bounded)")
    return 0 if ok else 1


# ---------------------------------------------------------------------------
# lint / mutants / replay
# ---------------------------------------------------------------------------


def _cmd_lint(args) -> int:
    rep = lint_paths(args.paths or DEFAULT_LINT_PATHS)
    if args.json:
        print(json.dumps({
            "ok": rep.ok,
            "findings": [dataclasses.asdict(f) for f in rep.findings],
            "bad_waivers": [dataclasses.asdict(f) for f in rep.bad_waivers],
            "waived": [dataclasses.asdict(f) for f in rep.waived],
        }, indent=2))
    else:
        for f in rep.findings:
            print(f)
        for f in rep.bad_waivers:
            print(f)
        print(f"lint: {len(rep.findings)} finding(s), "
              f"{len(rep.bad_waivers)} bad waiver(s), "
              f"{len(rep.waived)} waived")
    return 0 if rep.ok else 1


def _cmd_mutants(args) -> int:
    trail_dir = Path(args.trail_dir)
    sem = AllocatorSemantics(ALLOC_CFG, canonical=True)
    rows, all_ok = [], True
    for name, cls in MUTANTS.items():
        res = coupled_explore(sem, cls, max_states=args.max_states)
        caught = not res.ok
        reproduced = False
        trail = None
        if caught:
            trail = str(_write_trail(trail_dir, f"mutant-{name}", {
                "model": "allocator", "allocator": name,
                "config": dataclasses.asdict(ALLOC_CFG),
                "ops": [list(op) for op in res.ops],
                "message": res.message}))
            try:
                replay_ops(sem, list(res.ops), cls)
            except ConformanceError:
                reproduced = True
        all_ok &= caught and reproduced
        rows.append({"mutant": name, "caught": caught,
                     "reproduced": reproduced, "ops": len(res.ops),
                     "states": res.states, "message": res.message,
                     "trail": trail})
    if args.json:
        print(json.dumps({"ok": all_ok, "mutants": rows}, indent=2))
    else:
        for r in rows:
            print(f"{r['mutant']:<24} caught={r['caught']} "
                  f"reproduced={r['reproduced']} ops={r['ops']}")
            if r["caught"]:
                print(f"  {r['message'][:100]}")
        print("result:", "PASS (checker catches every planted bug)"
              if all_ok else "FAIL (a mutant escaped)")
    return 0 if all_ok else 1


def _scenario_from_json(d: dict) -> ServerScenario:
    return ServerScenario(
        name=d["name"],
        prompts=tuple(tuple(p) for p in d["prompts"]),
        max_new=tuple(d["max_new"]),
        slo=tuple(d.get("slo") or ()),
        deadline=tuple(d.get("deadline") or ()))


def _cmd_replay(args) -> int:
    payload = json.loads(Path(args.trail).read_text())
    ops = [tuple(op) for op in payload["ops"]]
    model = payload["model"]
    print(f"replaying {len(ops)} op(s) from {args.trail} "
          f"(model={model})")
    if model == "allocator":
        cfg = AllocConfig(**payload["config"])
        sem = AllocatorSemantics(cfg, canonical=True)
        cls = MUTANTS.get(payload.get("allocator", "real"))
        from ..runtime.kv import PagedKVAllocator
        try:
            replay_ops(sem, ops, cls or PagedKVAllocator, log=print)
        except ConformanceError as exc:
            print(f"REPRODUCED: {exc}")
            return 1
        print("trail replays clean (no divergence)")
        return 0
    # server/spec trails: guided simulation through the semantics,
    # checking invariants after every op
    if model == "server":
        cfg = ServerConfig(**payload["config"])
        sem = ServerSemantics(cfg, _scenario_from_json(payload["scenario"]))
        invs = server_invariants(cfg)
    elif model == "spec":
        cfg = SpecConfig(**{k: tuple(v) if isinstance(v, list) else v
                            for k, v in payload["config"].items()})
        sem = SpecSemantics(cfg)
        invs = spec_invariants(cfg)
    else:
        print(f"unknown trail model {model!r}")
        return 2
    G = sem.init_globals()
    bad: list[str] = []
    for i, op in enumerate(ops):
        sem.apply(G, op)
        bad = violated(invs, G)
        print(f"  [{i}] {op!r}" + (f"  VIOLATES {bad}" if bad else ""))
        if bad:
            break
    if not bad and model == "server":
        bad = drain_incomplete(G)
        for b in bad:
            print(f"  terminal: {b}")
    print("REPRODUCED" if bad else "trail replays clean")
    return 1 if bad else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="model-check the serving runtime's state machines")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_check = sub.add_parser("check", help="exhaustive invariant + "
                             "conformance checks on bounded configs")
    p_check.add_argument("--json", action="store_true")
    p_check.add_argument("--max-states", type=int, default=2_000_000)
    p_check.add_argument("--trail-dir", default=DEFAULT_TRAIL_DIR)
    p_check.set_defaults(fn=_cmd_check)

    p_lint = sub.add_parser("lint", help="AST rules over the runtime tree")
    p_lint.add_argument("paths", nargs="*")
    p_lint.add_argument("--json", action="store_true")
    p_lint.set_defaults(fn=_cmd_lint)

    p_mut = sub.add_parser("mutants", help="the checker must catch every "
                           "planted allocator bug")
    p_mut.add_argument("--json", action="store_true")
    p_mut.add_argument("--max-states", type=int, default=200_000)
    p_mut.add_argument("--trail-dir", default=DEFAULT_TRAIL_DIR)
    p_mut.set_defaults(fn=_cmd_mutants)

    p_rep = sub.add_parser("replay", help="re-run a counterexample trail "
                           "against the real code")
    p_rep.add_argument("--trail", required=True)
    p_rep.set_defaults(fn=_cmd_replay)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":   # pragma: no cover
    sys.exit(main())
