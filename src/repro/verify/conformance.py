"""The bridge that makes this static analysis rather than a toy: the
abstract allocator model and the real
:class:`~repro.runtime.kv.PagedKVAllocator` speak one trace vocabulary
(op tuples whose first element is the real method name; state agreement
is :meth:`~repro.runtime.kv.PagedKVAllocator.project` equality), and
this module drives them against each other in both directions:

* :func:`coupled_explore` — exhaustive DFS over the model where EVERY
  transition is also executed on a real allocator reconstructed at the
  pre-state; any return-value or state disagreement (or concrete
  invariant breach) yields a counterexample op trail.  Run against the
  shipped allocator it is a bounded conformance proof; run against a
  :mod:`~repro.verify.mutants` variant it is the bug detector.
* :func:`replay_ops` — SPIN guided-simulation analogue: re-run a trail
  op-for-op on ONE persistent real allocator from the initial state,
  asserting agreement at every step (``python -m repro.verify replay``).
* :func:`trace_accepted` — direction 2: every trace a *real* allocator
  records (the ``trace`` hook in :mod:`repro.runtime.kv`) must be a
  legal path of the model with identical returns and states.
"""

from __future__ import annotations

import ast
import time as _time
from dataclasses import dataclass

from ..core.promela import freeze
from ..runtime.kv import PagedKVAllocator
from .harness import restore_allocator
from .invariants import allocator_invariants, violated
from .models import AllocatorSemantics


class ConformanceError(AssertionError):
    """Real code and abstract model disagreed (op index + detail in
    ``args[0]``)."""


def ops_from_trail(trail: tuple[str, ...]) -> list[tuple]:
    """Recover the op sequence from explorer trail labels: a driver
    model's ``select`` labels end in ``:select=(op...)``."""

    ops = []
    for label in trail:
        if ":select=" in label:
            ops.append(ast.literal_eval(label.split(":select=", 1)[1]))
    return ops


def _norm(ret):
    """Real returns -> model returns (lists of pairs freeze to tuples)."""

    if isinstance(ret, list):
        return tuple(tuple(p) for p in ret)
    return ret


def _rets_match(sem, op, got, want) -> bool:
    """Exact return comparison, except ``cow_pages`` under a canonical
    (page-renamed) semantics in *cross-step* replay: there the model's
    pair list carries canonical ids while the real allocator's carries
    concrete ids, so only the shape (None-ness + pair count) is
    comparable.  ``coupled_explore`` never takes this branch — it
    reconstructs the real allocator at the model's own pre-state, so
    even canonical cow pairs compare exactly."""

    if sem.canonical and op[0] == "cow_pages":
        if got is None or want is None:
            return got is None and want is None
        return len(got) == len(want)
    return got == want


def _check_concrete(alloc: PagedKVAllocator) -> list[str]:
    """The allocator invariant suite evaluated on the REAL allocator's
    projection — the concrete half of every conformance step."""

    return violated(allocator_invariants(), {"alloc": alloc.project()})


@dataclass
class CoupledResult:
    ok: bool
    states: int = 0
    transitions: int = 0
    max_depth: int = 0
    truncated: bool = False
    elapsed_s: float = 0.0
    ops: tuple[tuple, ...] = ()       # counterexample op trail
    message: str = ""

    @property
    def status(self) -> str:
        if not self.ok:
            return "violated"
        return "bounded" if self.truncated else "verified"


def coupled_explore(sem: AllocatorSemantics,
                    allocator_cls: type[PagedKVAllocator] = PagedKVAllocator,
                    *, max_states: int = 500_000,
                    check_invariants: bool = True) -> CoupledResult:
    """DFS the allocator model; at every transition reconstruct a real
    ``allocator_cls`` at the pre-state, run the real method, and demand
    projection + return agreement with the model step (plus the
    concrete invariant suite).  Divergence is detected before visited
    pruning, so keying the visited set on the model state alone is
    sound: along agreeing paths the real state is a function of the
    model state."""

    t0 = _time.perf_counter()
    res = CoupledResult(ok=True)
    cfg = sem.cfg
    G0 = sem.init_globals()
    visited = {hash(freeze(G0))}
    stack: list[tuple[dict, tuple[tuple, ...]]] = [(G0, ())]
    res.states = 1
    scratch = allocator_cls(cfg.kv_spec(), cfg.n_slots)

    while stack:
        G, ops = stack.pop()
        res.max_depth = max(res.max_depth, len(ops))
        for op in sem.enabled_ops(G):
            res.transitions += 1
            G2 = dict(G)
            want_ret = sem.apply(G2, op)
            restore_allocator(scratch, G["alloc"])
            trail = ops + (op,)
            try:
                got_ret = _norm(getattr(scratch, op[0])(*op[1:]))
            except Exception as exc:   # mutants may blow up outright
                res.ok = False
                res.ops, res.message = trail, (
                    f"real {op!r} raised {type(exc).__name__}: {exc}")
                break
            if got_ret != want_ret:
                res.ok = False
                res.ops, res.message = trail, (
                    f"return mismatch on {op!r}: real {got_ret!r} "
                    f"!= model {want_ret!r}")
                break
            if sem.observe(scratch.project()) != G2["alloc"]:
                res.ok = False
                res.ops, res.message = trail, (
                    f"state divergence after {op!r}: real "
                    f"{sem.observe(scratch.project())} != model "
                    f"{G2['alloc']}")
                break
            if check_invariants:
                bad = _check_concrete(scratch)
                if bad:
                    res.ok = False
                    res.ops, res.message = trail, (
                        f"real allocator violates {bad} after {op!r}")
                    break
            h = hash(freeze(G2))
            if h in visited:
                continue
            visited.add(h)
            res.states += 1
            if res.states > max_states:
                res.truncated = True
                stack.clear()
                break
            stack.append((G2, trail))
        if not res.ok:
            break

    res.elapsed_s = _time.perf_counter() - t0
    return res


def replay_ops(sem: AllocatorSemantics, ops: list[tuple],
               allocator_cls: type[PagedKVAllocator] = PagedKVAllocator,
               *, log=None) -> PagedKVAllocator:
    """Replay an op trail on ONE persistent real allocator from the
    initial state (the concrete reproduction of an explorer
    counterexample).  Raises :class:`ConformanceError` at the first
    disagreement or concrete invariant breach; returns the final
    allocator on full agreement."""

    G = sem.init_globals()
    alloc = allocator_cls(sem.cfg.kv_spec(), sem.cfg.n_slots)
    for i, op in enumerate(ops):
        op = tuple(op)
        want_ret = sem.apply(G, op)
        try:
            got_ret = _norm(getattr(alloc, op[0])(*op[1:]))
        except Exception as exc:
            raise ConformanceError(
                f"op {i} {op!r}: real allocator raised "
                f"{type(exc).__name__}: {exc}") from exc
        if log is not None:
            log(f"  [{i}] {op!r} -> {got_ret!r}")
        if not _rets_match(sem, op, got_ret, want_ret):
            raise ConformanceError(
                f"op {i} {op!r}: return mismatch real {got_ret!r} "
                f"!= model {want_ret!r}")
        if sem.observe(alloc.project()) != G["alloc"]:
            raise ConformanceError(
                f"op {i} {op!r}: state divergence\n"
                f"  real:  {sem.observe(alloc.project())}\n"
                f"  model: {G['alloc']}")
        bad = _check_concrete(alloc)
        if bad:
            raise ConformanceError(
                f"op {i} {op!r}: real allocator violates {bad}")
    return alloc


class TraceChecker:
    """Incremental direction-2 conformance: feed ``(method, args,
    ret)`` records one at a time, as a real allocator emits them.

    The checker walks the abstract model alongside the real op stream —
    each record must be legal at the model's current state and return
    exactly what the model returns — which is what lets the online
    monitor (:mod:`repro.obs.monitor`) validate a LIVE drain without
    re-scanning the trace prefix every tick.  :meth:`state_divergence`
    adds the stronger check an offline trace cannot make: compare the
    real allocator's projection against the tracked model state, which
    catches mutations whose per-op returns still agree (leaked
    refcounts, stale table entries) at the first poll after the bad
    op rather than N ops later."""

    def __init__(self, sem: AllocatorSemantics):
        if sem.canonical:
            raise ValueError(
                "TraceChecker needs an exact (non-canonical) semantics: "
                "real traces carry concrete page ids")
        self.sem = sem
        self.G = sem.init_globals()
        self.count = 0

    def feed(self, record: tuple) -> None:
        method, args, real_ret = record
        op = (method, *tuple(args))
        if not self.sem.legal(self.G, op):
            raise ConformanceError(
                f"trace step {self.count} {op!r}: not a legal model op "
                f"at this state")
        want_ret = self.sem.apply(self.G, op)
        if _norm(real_ret) != want_ret:
            raise ConformanceError(
                f"trace step {self.count} {op!r}: real returned "
                f"{real_ret!r}, model {want_ret!r}")
        self.count += 1

    def state_divergence(self, alloc: PagedKVAllocator) -> str | None:
        real = self.sem.observe(alloc.project())
        if real != self.G["alloc"]:
            return (f"state divergence after trace step "
                    f"{self.count - 1}:\n  real:  {real}\n"
                    f"  model: {self.G['alloc']}")
        return None


def trace_accepted(sem: AllocatorSemantics,
                   trace: list[tuple]) -> None:
    """Direction 2: a ``(method, args, ret)`` trace recorded by a real
    allocator (the ``trace`` hook) must be a path of the model — every
    op legal at its state, every return matching the model's.  Raises
    :class:`ConformanceError` otherwise."""

    checker = TraceChecker(sem)
    for record in trace:
        checker.feed(record)


__all__ = ["ConformanceError", "CoupledResult", "TraceChecker",
           "coupled_explore", "ops_from_trail", "replay_ops",
           "trace_accepted"]
