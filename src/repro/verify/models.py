"""Abstract models of the runtime's three concurrent state machines,
expressed in the existing Promela-subset substrate.

Each model is a single driver proctype of the shape

    loop:  select op in enabled_ops(G)   (nondeterministic adversary)
           apply(G, op)                  (deterministic effect)
           goto loop

so the existing DFS explorer enumerates every reachable interleaving of
runtime operations, and every ``select`` label carries the op tuple —
``driver[0]:1:select=('ensure', 0, 3)`` — which is the shared trace
vocabulary :mod:`repro.verify.conformance` replays against the real
code: the op's first element IS the real allocator method name.

Three machines:

* :class:`AllocatorSemantics` — the paged COW allocator under an
  adversarial op stream (ensure/share/cow_pages/release/rewind/trim),
  a token-for-token mirror of :class:`repro.runtime.kv.PagedKVAllocator`
  including the LIFO free list and the owner-handoff rule,
* :class:`ServerSemantics` — the scheduler × server loop: arrivals from
  a bounded scenario interleaved with engine ticks, where each tick IS
  a :class:`repro.verify.harness.MiniServer` step driven by the real
  policy objects and the real allocator,
* :class:`SpecSemantics` — the speculate-commit-rewind cycle on a
  deliberately tight page pool, mirroring ``Server.tick``'s
  opportunistic draft shrinking and post-commit ``rewind``.

State-space hygiene: globals hold only canonical, bounded values —
allocator state via ``project()``, admission order as a rank
permutation (live slots renumbered 0..k), liveness encoded as bounded
ghost stall/skip counters so every property is a ``G p`` reachability
check (the reduction in :mod:`repro.core.properties`).
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.promela import Expr, Goto, Model, Proctype, Select
from ..runtime.kv import NO_PAGE, PagedKVAllocator, PagedKVSpec
from .harness import (MiniServer, ServerConfig, ServerScenario, VReq,
                      canon_pages, empty_projection, restore_allocator)


def build_driver_model(sem) -> Model:
    """Wrap a semantics object (``init_globals``/``enabled_ops``/
    ``apply``) into the one-process driver model described above."""

    body = [
        "loop",
        Select(var="op", choices=lambda G, L: sem.enabled_ops(G)),
        Expr(fn=lambda G, L: sem.apply(G, L.pop("op")), label_hint="apply"),
        Goto("loop"),
    ]
    proc = Proctype.compile("driver", body)
    return Model({"driver": proc}, sem.init_globals(), "driver")


# ---------------------------------------------------------------------------
# 1. the paged COW allocator under an adversarial op stream
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AllocConfig:
    """Bounded allocator instance.  The default is intentionally
    over-committed (3 slots x 3 pages > 6 physical pages) so ensure
    failure, eviction pressure and every share/cow interleaving are
    reachable."""

    n_slots: int = 3
    page_size: int = 2
    pages_per_slot: int = 3
    n_pages: int = 6
    share: bool = True
    rewind: bool = True
    trim: bool = True

    @property
    def context(self) -> int:
        return self.page_size * self.pages_per_slot

    def kv_spec(self) -> PagedKVSpec:
        return PagedKVSpec(n_pages=self.n_pages, page_size=self.page_size,
                           pages_per_slot=self.pages_per_slot)


class AllocatorSemantics:
    """Mirror of :class:`~repro.runtime.kv.PagedKVAllocator`'s mutation
    semantics over the canonical projection ``(pt, ref, own, free,
    top)``.  ``apply`` returns exactly what the real method returns so
    conformance can compare op by op; ``legal`` mirrors the real
    method's raise conditions (an op is legal iff the real call returns
    instead of raising)."""

    def __init__(self, cfg: AllocConfig, *, canonical: bool = False):
        self.cfg = cfg
        # canonical=True quotients every post-state by page renaming
        # (harness.canon_pages) — the symmetry reduction that makes
        # exhaustive exploration of over-committed configs tractable.
        # Exact mode (False) tracks concrete page ids and is what
        # direction-2 trace conformance uses.
        self.canonical = canonical

    def observe(self, proj: tuple) -> tuple:
        """Map a REAL allocator projection into this semantics' state
        space (identity in exact mode)."""

        return canon_pages(proj) if self.canonical else proj

    def init_globals(self) -> dict:
        return {"alloc": empty_projection(self.cfg.n_slots, self.cfg.kv_spec())}

    # -- helpers ------------------------------------------------------------

    def pages_needed(self, n_tokens: int) -> int:
        return -(-max(0, n_tokens) // self.cfg.page_size)

    def backed_tokens(self, top: int) -> int:
        return (top + 1) * self.cfg.page_size

    # -- the adversary: a small, canonical op menu --------------------------

    def enabled_ops(self, G: dict) -> list[tuple]:
        """Each distinct *effect* once: ensure targets are one page of
        growth and full backing (any token count mapping to the same
        page count has the identical effect), failing ensures included
        (the all-or-nothing contract is exactly what they test)."""

        c = self.cfg
        pt, ref, own, free, top = G["alloc"]
        ops: list[tuple] = []
        empty = [s for s in range(c.n_slots)
                 if top[s] == -1 and all(p == NO_PAGE for p in pt[s])]
        for s in range(c.n_slots):
            bt = self.backed_tokens(top[s])
            grows = {t for t in (bt + c.page_size, c.context)
                     if bt < t <= c.context}
            for t in sorted(grows):
                ops.append(("ensure", s, t))
            if c.share:
                shared_lps = [lp for lp in range(c.pages_per_slot)
                              if pt[s][lp] != NO_PAGE and ref[pt[s][lp]] > 1]
                if shared_lps:
                    lp = shared_lps[0]
                    # one representative single-page write plus the
                    # everything-at-once range
                    ops.append(("cow_pages", s, lp * c.page_size,
                                lp * c.page_size + 1))
                    ops.append(("cow_pages", s, 0, c.context))
                for d in empty:
                    if d == s or bt <= 0:
                        continue
                    for t in sorted({t for t in (c.page_size,
                                                 c.page_size + 1, bt)
                                     if 1 <= t <= bt}):
                        if self._share_legal(G, s, d, t):
                            ops.append(("share", s, d, t))
            if c.rewind and top[s] >= 0:
                keeps = {0, top[s]}       # drop everything / last page only
                for k in sorted(keeps):
                    ops.append(("rewind", s, k * c.page_size))
            if c.trim:
                for k in (c.page_size, 2 * c.page_size):
                    low = min(k // c.page_size, c.pages_per_slot)
                    if any(pt[s][lp] != NO_PAGE for lp in range(low)):
                        ops.append(("trim", s, k))
            if top[s] >= 0 or any(p != NO_PAGE for p in pt[s]):
                ops.append(("release", s))
        return ops

    # -- legality (the real method returns rather than raises) --------------

    def _share_legal(self, G: dict, src: int, dst: int, t: int) -> bool:
        pt, ref, own, free, top = G["alloc"]
        if t <= 0:
            return True               # real share(n<=0) returns 0
        if top[dst] != -1 or any(p != NO_PAGE for p in pt[dst]):
            return False
        need = self.pages_needed(t)
        if need > self.cfg.pages_per_slot:
            return False
        return all(pt[src][lp] != NO_PAGE for lp in range(need))

    def legal(self, G: dict, op: tuple) -> bool:
        c = self.cfg
        name, args = op[0], op[1:]
        if name == "ensure":
            slot, t = args
            if not 0 <= slot < c.n_slots:
                return False
            return t <= 0 or (t - 1) // c.page_size < c.pages_per_slot
        if name == "share":
            return self._share_legal(G, *args)
        if name in ("cow_pages", "release", "rewind", "trim"):
            return 0 <= args[0] < c.n_slots
        return False

    # -- effect (mutates G in place; returns the real method's return) ------

    def apply(self, G: dict, op: tuple):
        c = self.cfg
        ps = c.page_size
        pt = [list(r) for r in G["alloc"][0]]
        ref = list(G["alloc"][1])
        own = list(G["alloc"][2])
        free = list(G["alloc"][3])
        top = list(G["alloc"][4])

        def deref(page: int) -> bool:
            ref[page] -= 1
            if ref[page] <= 0:
                ref[page] = 0
                own[page] = NO_PAGE
                free.append(page)
                return True
            if page not in pt[own[page]]:
                # owner handoff: first holder in slot order (argwhere)
                holder = next((s for s in range(c.n_slots)
                               if page in pt[s]), NO_PAGE)
                own[page] = holder
            return False

        name, args = op[0], op[1:]
        ret: object
        if name == "ensure":
            slot, t = args
            if t <= 0:
                ret = True
            else:
                top_needed = (t - 1) // ps
                grow = top_needed - top[slot]
                if grow <= 0:
                    ret = True
                elif grow > len(free):
                    ret = False
                else:
                    for lp in range(top[slot] + 1, top_needed + 1):
                        page = free.pop()
                        pt[slot][lp] = page
                        own[page] = slot
                        ref[page] = 1
                    top[slot] = top_needed
                    ret = True
        elif name == "share":
            src, dst, t = args
            if t <= 0:
                ret = 0
            else:
                need = self.pages_needed(t)
                for lp in range(need):
                    page = pt[src][lp]
                    pt[dst][lp] = page
                    ref[page] += 1
                top[dst] = need - 1
                ret = need
        elif name == "cow_pages":
            slot, start, end = args
            if end <= start:
                ret = ()
            else:
                lo = start // ps
                hi = min((end - 1) // ps, c.pages_per_slot - 1)
                todo = [lp for lp in range(lo, hi + 1)
                        if pt[slot][lp] != NO_PAGE and ref[pt[slot][lp]] > 1]
                if len(todo) > len(free):
                    ret = None
                else:
                    pairs = []
                    for lp in todo:
                        old = pt[slot][lp]
                        new = free.pop()
                        pt[slot][lp] = new
                        own[new] = slot
                        ref[new] = 1
                        deref(old)
                        pairs.append((old, new))
                    ret = tuple(pairs)
        elif name == "release":
            (slot,) = args
            pages = [p for p in pt[slot] if p != NO_PAGE]
            pt[slot] = [NO_PAGE] * c.pages_per_slot
            top[slot] = -1
            for page in pages:
                deref(page)
            ret = len(pages)
        elif name == "rewind":
            slot, t = args
            keep = self.pages_needed(t)
            freed = 0
            for lp in range(keep, top[slot] + 1):
                page = pt[slot][lp]
                if page != NO_PAGE:
                    pt[slot][lp] = NO_PAGE
                    if deref(page):
                        freed += 1
            top[slot] = min(top[slot], keep - 1)
            ret = freed
        elif name == "trim":
            slot, keep_from = args
            freed = 0
            for lp in range(min(keep_from // ps, c.pages_per_slot)):
                page = pt[slot][lp]
                if page != NO_PAGE:
                    pt[slot][lp] = NO_PAGE
                    if deref(page):
                        freed += 1
            ret = freed
        else:  # pragma: no cover - defensive
            raise ValueError(f"unknown allocator op {op!r}")

        post = (tuple(tuple(r) for r in pt), tuple(ref), tuple(own),
                tuple(free), tuple(top))
        G["alloc"] = canon_pages(post) if self.canonical else post
        return ret


# ---------------------------------------------------------------------------
# 2. the scheduler x server loop
# ---------------------------------------------------------------------------


class ServerSemantics:
    """Arrivals interleaved with engine ticks; each tick decodes the
    globals into a :class:`MiniServer` (real scheduler + real
    allocator), runs one real tick, and re-encodes — so the explored
    state machine IS the shipped admission/eviction/aging logic.

    Per-request tuple: ``(status, skips, n_out, cursor, target)`` with
    status 0=unsubmitted, 1=queued, 2=live, 3=done; skips normalized to
    0 outside the queue (it is only read there).  Liveness ghosts:
    ``maxout[rid]`` (progress-keeps monotone check), ``hicur[rid]``
    (deepest prefill ever reached — re-prefilling after preemption only
    counts as fresh progress once it passes the old high-water mark),
    ``stall`` (consecutive ticks the oldest live slot made no fresh
    progress).  ``err`` is a sticky violation bitmask (bit 0: a
    generated-token count decreased)."""

    def __init__(self, cfg: ServerConfig, scenario: ServerScenario, *,
                 canonical: bool = True,
                 allocator_cls: type[PagedKVAllocator] = PagedKVAllocator):
        self.cfg = cfg
        self.scenario = scenario
        # page-renaming quotient on the embedded allocator state; every
        # scheduler/placement decision is id-free, so this is sound
        # (see harness.canon_pages) and collapses free-list orderings.
        self.canonical = canonical
        # a mutants.* class here plants the bug inside every tick
        self.allocator_cls = allocator_cls

    def init_globals(self) -> dict:
        n = self.scenario.n_requests
        b = self.cfg.batch
        return {
            "rq": ((0, 0, 0, 0, 0),) * n,
            "queue": (),
            "slots": (-1,) * b,
            "pos": (0,) * b,
            "rank": (-1,) * b,
            "alloc": empty_projection(b, self.cfg.kv_spec()),
            "nsub": 0,
            "maxout": (0,) * n,
            "hicur": (0,) * n,
            "stall": 0,
            "err": 0,
        }

    def enabled_ops(self, G: dict) -> list[tuple]:
        ops: list[tuple] = []
        if G["nsub"] < self.scenario.n_requests:
            ops.append(("submit", G["nsub"]))
        if G["queue"] or any(r >= 0 for r in G["slots"]):
            ops.append(("tick",))
        return ops

    # -- globals <-> MiniServer ---------------------------------------------

    def decode(self, G: dict) -> MiniServer:
        ms = MiniServer(self.cfg, self.scenario,
                        allocator_cls=self.allocator_cls)
        ms.nsub = G["nsub"]
        for rid, (st, skips, n_out, cursor, target) in enumerate(G["rq"]):
            if st == 0:
                continue
            req = ms.requests[rid] = VReq(
                rid=rid, prompt=list(self.scenario.prompts[rid]),
                max_new=self.scenario.max_new[rid],
                out=[self.scenario.gen(rid, i) for i in range(n_out)],
                done=(st == 3), slo=self.scenario.slo_of(rid),
                deadline=self.scenario.deadline_of(rid),
                skips=skips, cursor=cursor, target=target)
            if st == 3:
                ms.completed.append(req)
        ms.queue = [ms.requests[r] for r in G["queue"]]
        live = [(G["rank"][s], s) for s in range(self.cfg.batch)
                if G["slots"][s] >= 0]
        for rank, s in live:
            ms.slot_req[s] = ms.requests[G["slots"][s]]
            ms.slot_pos[s] = G["pos"][s]
            ms._slot_seq[s] = rank
        ms._seq = len(live)
        restore_allocator(ms.alloc, G["alloc"])
        return ms

    def encode(self, ms: MiniServer, G: dict) -> None:
        queued = {r.rid for r in ms.queue}
        live_by_rid = {r.rid: s for s, r in enumerate(ms.slot_req)
                       if r is not None}
        rq = []
        for rid in range(self.scenario.n_requests):
            req = ms.requests.get(rid)
            if req is None:
                rq.append((0, 0, 0, 0, 0))
            elif req.done:
                rq.append((3, 0, len(req.out), 0, 0))
            elif rid in queued:
                rq.append((1, req.skips, len(req.out), 0, 0))
            elif rid in live_by_rid:
                rq.append((2, 0, len(req.out), req.cursor, req.target))
            else:  # pragma: no cover - defensive
                raise AssertionError(f"request {rid} in limbo")
        # canonical admission order: live slots renumbered by rank so
        # the monotonically-growing _seq never enters the state
        order = sorted((s for s in range(self.cfg.batch)
                        if ms.slot_req[s] is not None),
                       key=lambda s: ms._slot_seq[s])
        rank = [-1] * self.cfg.batch
        for i, s in enumerate(order):
            rank[s] = i
        G["rq"] = tuple(rq)
        G["queue"] = tuple(r.rid for r in ms.queue)
        G["slots"] = tuple(r.rid if r is not None else -1
                           for r in ms.slot_req)
        G["pos"] = tuple(int(p) for p in ms.slot_pos)
        G["rank"] = tuple(rank)
        proj = ms.alloc.project()
        G["alloc"] = canon_pages(proj) if self.canonical else proj
        G["nsub"] = ms.nsub

    # -- effect -------------------------------------------------------------

    def apply(self, G: dict, op: tuple) -> None:
        ms = self.decode(G)
        if op[0] == "submit":
            ms.submit(op[1])
            self.encode(ms, G)
            return
        # snapshot for the liveness ghosts
        pre = {rid: (t[2], G["hicur"][rid])
               for rid, t in enumerate(G["rq"])}
        oldest = next((s for s in range(self.cfg.batch)
                       if G["rank"][s] == 0), None)
        oldest_rid = G["slots"][oldest] if oldest is not None else None
        ms.tick()
        self.encode(ms, G)
        maxout = list(G["maxout"])
        hicur = list(G["hicur"])
        err = G["err"]
        for rid in range(self.scenario.n_requests):
            req = ms.requests.get(rid)
            n_out = len(req.out) if req is not None else 0
            if n_out < maxout[rid]:
                err |= 1          # generated progress was lost
            maxout[rid] = max(maxout[rid], n_out)
            if req is not None:
                hicur[rid] = max(hicur[rid], req.cursor)
        if oldest_rid is not None:
            req = ms.requests[oldest_rid]
            pre_out, pre_hi = pre[oldest_rid]
            progressed = (len(req.out) > pre_out or req.done
                          or hicur[oldest_rid] > pre_hi)
            G["stall"] = 0 if progressed else G["stall"] + 1
        else:
            G["stall"] = 0
        G["maxout"] = tuple(maxout)
        G["hicur"] = tuple(hicur)
        G["err"] = err


# ---------------------------------------------------------------------------
# 3. the speculate-commit-rewind cycle
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SpecConfig:
    """Two slots on a deliberately tight pool: slot 0 speculates,
    slot 1 plain-decodes alongside, so the opportunistic
    draft-shrinking loop and post-commit rewind run under real page
    pressure.  ``page_size=1`` makes draft depth map 1:1 onto pages —
    a full-depth draft can need more pages than are free while a
    shallower one fits, which is exactly the shrink path.  ``caps``
    are per-slot retirement positions.  Demand (``caps`` sum) exceeds
    the pool on purpose; the OOM escape is the ``preempt`` op on
    slot 1 (mirroring ``Server._ensure_pages``'s eviction fallback),
    which keeps the model deadlock-free."""

    page_size: int = 1
    pages_per_slot: int = 5
    n_pages: int = 5
    max_depth: int = 2
    caps: tuple[int, int] = (5, 2)

    @property
    def context(self) -> int:
        return self.page_size * self.pages_per_slot

    def kv_spec(self) -> PagedKVSpec:
        return PagedKVSpec(n_pages=self.n_pages, page_size=self.page_size,
                           pages_per_slot=self.pages_per_slot)


class SpecSemantics:
    """Mirror of ``Server.tick``'s speculation branch: draft depth d is
    shrunk to what the free list covers WITHOUT eviction, the verifier
    nondeterministically accepts k of the drafts, commit advances
    ``min(k, d_eff) + 1`` positions, then ``rewind`` hands back every
    page grabbed for rejected positions.  ``err`` bit 0: an op's
    ensure/rewind disagreed with the real contract; bit 1: the
    committed prefix's page mapping changed across the cycle."""

    def __init__(self, cfg: SpecConfig, *, canonical: bool = True):
        self.cfg = cfg
        # the inner allocator semantics stays EXACT so the within-op
        # prefix-stability check compares concrete page ids across the
        # ensure -> rewind window; the quotient is applied once per op.
        self.canonical = canonical
        self.alloc_sem = AllocatorSemantics(AllocConfig(
            n_slots=2, page_size=cfg.page_size,
            pages_per_slot=cfg.pages_per_slot, n_pages=cfg.n_pages))

    def init_globals(self) -> dict:
        return {
            "alloc": empty_projection(2, self.cfg.kv_spec()),
            "pos": (0, 0),
            "done": (0, 0),
            "err": 0,
        }

    def _grow_fits(self, G: dict, slot: int, t: int) -> bool:
        _, _, _, free, top = G["alloc"]
        grow = (t - 1) // self.cfg.page_size - top[slot]
        return grow <= 0 or grow <= len(free)

    def enabled_ops(self, G: dict) -> list[tuple]:
        ops: list[tuple] = []
        for s in (0, 1):
            if not G["done"][s] and G["pos"][s] < self.cfg.caps[s] \
                    and self._grow_fits(G, s, G["pos"][s] + 1):
                ops.append(("decode", s))
        pos0 = G["pos"][0]
        if not G["done"][0] and pos0 >= 1 \
                and self._grow_fits(G, 0, pos0 + 1):
            dmax = min(self.cfg.max_depth, self.cfg.caps[0] - pos0 - 1)
            for d in range(1, dmax + 1):
                for k in range(d + 1):
                    ops.append(("spec", d, k))
        # the OOM escape serve.py gets from _ensure_pages eviction:
        # when the pool is dry, the neighbour can be preempted (its
        # pages released, its position reset for re-prefill)
        if not G["done"][1] and G["pos"][1] > 0 and not G["alloc"][3]:
            ops.append(("preempt", 1))
        return ops

    def apply(self, G: dict, op: tuple) -> None:
        c = self.cfg
        pos = list(G["pos"])
        done = list(G["done"])
        err = G["err"]
        if op[0] == "preempt":
            s = op[1]
            self.alloc_sem.apply(G, ("release", s))
            pos[s] = 0
            G["alloc"] = canon_pages(G["alloc"]) if self.canonical \
                else G["alloc"]
            G["pos"] = tuple(pos)
            return
        if op[0] == "decode":
            s = op[1]
            ok = self.alloc_sem.apply(G, ("ensure", s, pos[s] + 1))
            if ok is not True:
                err |= 1           # guard said this fits
            pos[s] += 1
        else:
            (_, d, k) = op
            s = 0
            # opportunistic shrink: largest dd the free list covers
            # without evicting the neighbour (serve.py's loop)
            d_eff = 0
            for dd in range(d, 0, -1):
                if self._grow_fits(G, s, pos[s] + dd + 1):
                    ok = self.alloc_sem.apply(G, ("ensure", s,
                                                  pos[s] + dd + 1))
                    if ok is not True:
                        err |= 1
                    d_eff = dd
                    break
            if d_eff == 0:
                ok = self.alloc_sem.apply(G, ("ensure", s, pos[s] + 1))
                if ok is not True:
                    err |= 1
            e = min(k, d_eff) + 1
            new_pos = pos[s] + e
            keep = self.alloc_sem.pages_needed(new_pos)
            prefix_before = G["alloc"][0][s][:keep]
            self.alloc_sem.apply(G, ("rewind", s, new_pos))
            if G["alloc"][0][s][:keep] != prefix_before:
                err |= 2           # committed positions remapped
            pos[s] = new_pos
        if pos[s] >= c.caps[s]:    # retirement, as _retire_if_done
            self.alloc_sem.apply(G, ("release", s))
            done[s] = 1
        if self.canonical:
            G["alloc"] = canon_pages(G["alloc"])
        G["pos"] = tuple(pos)
        G["done"] = tuple(done)
        G["err"] = err


__all__ = ["AllocConfig", "AllocatorSemantics", "ServerSemantics",
           "SpecConfig", "SpecSemantics", "build_driver_model"]
