"""The concrete half of the server model: a jax-free mirror of
:class:`repro.runtime.serve.Server`'s paged bookkeeping.

The scheduler × server model needs the *real* policy objects
(:mod:`repro.runtime.scheduler`) and the *real*
:class:`~repro.runtime.kv.PagedKVAllocator` making decisions inside
every abstract transition — otherwise the model would re-implement the
policies and verify the re-implementation instead of the shipped code.
:class:`MiniServer` keeps the server's control flow line-for-line
(admission → per-slot page ensure in admission order → decode/prefill
advance → retirement) but strips the device halves: no jitted steps, no
KV tensors, synthetic generated tokens.  Documented divergences from
``Server.tick``:

* no speculation (the speculate-commit-rewind cycle is its own model,
  :class:`repro.verify.models.SpecSemantics`),
* no sliding-window trim (``api.cfg.window`` is None for the modeled
  dense configs),
* no encoder-decoder frames and no recurrent-state hygiene (device
  state does not exist here),
* generated tokens come from ``scenario.gen`` instead of logits — the
  scheduling/paging state machine never reads token *values* except
  for prefix matching, which the scenario controls.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..runtime.kv import NO_PAGE, PagedKVAllocator, PagedKVSpec
from ..runtime.scheduler import make_scheduler


def restore_allocator(alloc: PagedKVAllocator, proj: tuple) -> PagedKVAllocator:
    """Overwrite ``alloc``'s mutable state with a
    :meth:`~repro.runtime.kv.PagedKVAllocator.project` projection —
    the inverse direction of the shared trace vocabulary, used to
    reconstruct the real allocator at any explored model state."""

    pt, ref, own, free, top = proj
    alloc.page_table[:] = np.array(pt, np.int32)
    alloc.refcount[:] = np.array(ref, np.int32)
    alloc.owner[:] = np.array(own, np.int32)
    alloc._free = list(free)
    alloc._top[:] = np.array(top, np.int64)
    return alloc


def empty_projection(n_slots: int, spec: PagedKVSpec) -> tuple:
    """The projection of a freshly-constructed allocator."""

    return (
        tuple((NO_PAGE,) * spec.pages_per_slot for _ in range(n_slots)),
        (0,) * spec.n_pages,
        (NO_PAGE,) * spec.n_pages,
        tuple(range(spec.n_pages - 1, -1, -1)),
        (-1,) * n_slots,
    )


def canon_pages(proj: tuple) -> tuple:
    """Quotient a projection by physical-page renaming (SPIN-style
    symmetry reduction).  Pages are relabeled in first-occurrence order
    — page-table row-major, then the free list in POP order, then any
    leaked page — which maps the initial projection to itself and is
    idempotent.

    Soundness: the op vocabulary names slots and token counts, never
    page ids, and every allocator rule (LIFO pop, owner handoff by slot
    order, refcount tests) is equivariant under page renaming, so each
    canonical reachable state represents its whole renaming orbit and
    every invariant in :mod:`repro.verify.invariants` is
    renaming-symmetric.  The price: a hypothetical bug that special-
    cases a concrete page id would be invisible — that class is covered
    by the exact-mode (non-canonical) conformance paths and the
    randomized direct tests."""

    pt, ref, own, free, top = proj
    n_pages = len(ref)
    rename: dict[int, int] = {}
    for row in pt:
        for p in row:
            if p != NO_PAGE and p not in rename:
                rename[p] = len(rename)
    for p in reversed(free):          # pop order: free[-1] pops first
        if p not in rename:
            rename[p] = len(rename)
    for p in range(n_pages):          # leaked pages (mutant states)
        if p not in rename:
            rename[p] = len(rename)
    new_ref = [0] * n_pages
    new_own = [NO_PAGE] * n_pages
    for p in range(n_pages):
        q = rename[p]
        new_ref[q] = ref[p]
        new_own[q] = own[p]
    return (
        tuple(tuple(NO_PAGE if p == NO_PAGE else rename[p] for p in row)
              for row in pt),
        tuple(new_ref),
        tuple(new_own),
        tuple(rename[p] for p in free),
        tuple(top),
    )


# ---------------------------------------------------------------------------
# scenario / config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ServerScenario:
    """A bounded request mix: the model nondeterministically interleaves
    these arrivals (in order) with engine ticks."""

    name: str
    prompts: tuple[tuple[int, ...], ...]
    max_new: tuple[int, ...]
    slo: tuple[str, ...] = ()
    deadline: tuple[float | None, ...] = ()

    @property
    def n_requests(self) -> int:
        return len(self.prompts)

    def slo_of(self, rid: int) -> str:
        return self.slo[rid] if self.slo else "interactive"

    def deadline_of(self, rid: int) -> float | None:
        return self.deadline[rid] if self.deadline else None

    def gen(self, rid: int, i: int) -> int:
        """Deterministic synthetic generated token: per-request constant
        so two requests' outputs never accidentally extend a shared
        prefix the scenario didn't plan."""

        return 100 + rid


@dataclass(frozen=True)
class ServerConfig:
    """Bounded slot/page configuration for the scheduler × server model."""

    policy: str = "fcfs"
    batch: int = 3
    page_size: int = 2
    pages_per_slot: int = 3
    n_pages: int = 6
    prefill_chunk: int = 2
    age_limit: int = 2
    share_prefix: bool = False
    # liveness bounds (ghost-counter encodings of "eventually"):
    # consecutive ticks the oldest live slot may fail to make fresh
    # progress, and how far past age_limit skips may run (priority's
    # aged-pool picks bump other aged entries; fcfs/prefix never do)
    stall_bound: int = 4
    aging_slack: int = 0

    @property
    def context(self) -> int:
        return self.page_size * self.pages_per_slot

    def kv_spec(self) -> PagedKVSpec:
        return PagedKVSpec(n_pages=self.n_pages, page_size=self.page_size,
                           pages_per_slot=self.pages_per_slot)

    def make_scheduler(self):
        return make_scheduler(self.policy, age_limit=self.age_limit)


@dataclass
class VReq:
    """Request mirror: the fields the scheduler contract and the paged
    bookkeeping actually read (``_cursor``/``_prefill_target`` become
    plain attributes)."""

    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = field(default_factory=list)
    done: bool = False
    slo: str = "interactive"
    deadline: float | None = None
    skips: int = 0
    preempted: int = 0
    shared_prefix: int = 0
    cursor: int = 0
    target: int = 0


# ---------------------------------------------------------------------------
# the server mirror
# ---------------------------------------------------------------------------


class MiniServer:
    """Paged-serving bookkeeping with the device halves stripped; every
    control-flow decision is delegated to the REAL scheduler policy and
    the REAL page allocator (or a planted mutant)."""

    def __init__(self, cfg: ServerConfig, scenario: ServerScenario, *,
                 allocator_cls: type[PagedKVAllocator] = PagedKVAllocator):
        self.cfg = cfg
        self.scenario = scenario
        self.batch = cfg.batch
        self.context = cfg.context
        self.prefill_chunk = cfg.prefill_chunk
        self.share_prefix = cfg.share_prefix
        self.paged = True
        self.alloc = allocator_cls(cfg.kv_spec(), cfg.batch)
        self.scheduler = cfg.make_scheduler()
        self.requests: dict[int, VReq] = {}
        self.queue: list[VReq] = []
        self.completed: list[VReq] = []
        self.slot_req: list[VReq | None] = [None] * cfg.batch
        self.slot_pos = [0] * cfg.batch
        self._slot_seq = [0] * cfg.batch
        self._seq = 0
        self.nsub = 0

    # -- arrivals -----------------------------------------------------------

    def submit(self, rid: int) -> VReq:
        req = VReq(rid=rid, prompt=list(self.scenario.prompts[rid]),
                   max_new=self.scenario.max_new[rid],
                   slo=self.scenario.slo_of(rid),
                   deadline=self.scenario.deadline_of(rid))
        self.requests[rid] = req
        self.queue.append(req)
        self.nsub = max(self.nsub, rid + 1)
        return req

    # -- scheduler-facing queries (the policy contract, as in serve.py) -----

    def live_slots(self) -> list[int]:
        return [s for s in range(self.batch) if self.slot_req[s] is not None]

    def has_free_slot(self) -> bool:
        return any(r is None for r in self.slot_req)

    def slot_seq(self, slot: int) -> int:
        return int(self._slot_seq[slot])

    def slot_request(self, slot: int) -> VReq | None:
        return self.slot_req[slot]

    def admit_fits(self, req: VReq) -> bool:
        total = len(req.prompt) + len(req.out)
        need = self.alloc.pages_needed(total)
        if self.share_prefix:
            _, shared = self._find_share_source(req)
            need -= shared // self.alloc.spec.page_size
        return (need <= self.alloc.spec.pages_per_slot
                and need <= self.alloc.free_pages)

    def shared_prefix_len(self, req: VReq) -> int:
        if not self.share_prefix:
            return 0
        _, shared = self._find_share_source(req)
        return shared

    def is_share_source(self, slot: int) -> bool:
        return any(int(self.alloc.refcount[p]) > 1
                   for p in self.alloc.slot_pages(slot))

    # -- admission / placement / preemption (serve.py line-for-line) --------

    def _admit(self) -> None:
        for _ in range(self.batch):
            if not self.queue:
                break
            victim = self.scheduler.preempt_for(self)
            if victim is None:
                break
            self._preempt(victim)
        for slot in range(self.batch):
            if self.slot_req[slot] is None and self.queue:
                idx = self.scheduler.pick(self)
                if idx is None:
                    return
                self._place(slot, self.queue.pop(idx))

    def _place(self, slot: int, req: VReq) -> None:
        self.slot_req[slot] = req
        self._slot_seq[slot] = self._seq
        self._seq += 1
        req.target = len(req.prompt) + len(req.out)
        start = 0
        if self.share_prefix:
            src, shared = self._find_share_source(req)
            if src is not None and self.alloc.share(src, slot, shared):
                start = shared
                req.shared_prefix = max(req.shared_prefix, shared)
        self.slot_pos[slot] = start
        req.cursor = start

    def _backed_prefix(self, slot: int) -> int:
        n = 0
        for p in self.alloc.page_table[slot]:
            if p == NO_PAGE:
                break
            n += 1
        return n * self.alloc.spec.page_size

    def _find_share_source(self, req: VReq) -> tuple[int | None, int]:
        stream = req.prompt + req.out
        cap = len(stream) - 1
        best, best_len = None, 0
        for s in range(self.batch):
            src = self.slot_req[s]
            if src is None:
                continue
            written = (src.prompt + src.out)[:int(self.slot_pos[s])]
            m = min(len(written), cap, self._backed_prefix(s))
            n = 0
            while n < m and stream[n] == written[n]:
                n += 1
            if n > best_len:
                best, best_len = s, n
        if best_len < self.alloc.spec.page_size:
            return None, 0
        return best, best_len

    def _preempt(self, slot: int) -> None:
        req = self.slot_req[slot]
        req.cursor = 0
        req.preempted += 1
        self.queue.insert(0, req)
        self.alloc.release(slot)
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0

    def _evict_for(self, slot: int) -> int | None:
        victim = self.scheduler.victim(self)
        if victim is not None:
            self._preempt(victim)
        return victim

    def _ensure_pages(self, slot: int, n_tokens: int) -> bool:
        while not self.alloc.ensure(slot, n_tokens):
            victim = self._evict_for(slot)
            if victim is None or victim == slot:
                return False
        return True

    def _cow_range(self, slot: int, start: int,
                   end: int) -> list[tuple[int, int]]:
        while True:
            pairs = self.alloc.cow_pages(slot, start, end)
            if pairs is not None:
                return pairs
            victim = self._evict_for(slot)
            if victim is None or victim == slot:
                return []

    def _phase(self, slot: int) -> str:
        req = self.slot_req[slot]
        return "prefill" if req.cursor < req.target else "decode"

    def _retire_if_done(self, slot: int) -> None:
        req = self.slot_req[slot]
        if len(req.out) >= req.max_new or \
                self.slot_pos[slot] >= self.context - 1:
            req.done = True
            self.completed.append(req)
            self.slot_req[slot] = None
            self.alloc.release(slot)

    # -- the tick (serve.py's paged path, device halves stripped) -----------

    def tick(self) -> int:
        self._admit()
        order = sorted((s for s in range(self.batch)
                        if self.slot_req[s] is not None),
                       key=lambda s: self._slot_seq[s])
        for s in order:
            req = self.slot_req[s]
            if req is None:          # evicted as an earlier victim
                continue
            pos = int(self.slot_pos[s])
            if self._phase(s) == "decode":
                end = pos + 1
                if not self._ensure_pages(s, pos + 1):
                    continue
            else:
                n = min(self.prefill_chunk, req.target - req.cursor)
                end = pos + n
                if not self._ensure_pages(s, end):
                    continue
            if self.share_prefix and self.slot_req[s] is req:
                self._cow_range(s, pos, end)
        active = [s for s in range(self.batch)
                  if self.slot_req[s] is not None]
        if not active:
            return 0
        decode = [s for s in active if self._phase(s) == "decode"]
        prefill = [s for s in active if self._phase(s) == "prefill"]
        for s in decode:
            req = self.slot_req[s]
            req.cursor += 1
            self.slot_pos[s] += 1
            req.out.append(self.scenario.gen(req.rid, len(req.out)))
            self._retire_if_done(s)
        for s in prefill:
            req = self.slot_req[s]
            n = min(self.prefill_chunk, req.target - req.cursor)
            req.cursor += n
            self.slot_pos[s] += n
            if req.cursor >= req.target:
                req.out.append(self.scenario.gen(req.rid, len(req.out)))
                self._retire_if_done(s)
        return len(active)


__all__ = ["MiniServer", "ServerConfig", "ServerScenario", "VReq",
           "canon_pages", "restore_allocator", "empty_projection"]
