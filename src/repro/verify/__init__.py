"""Turn the paper's model checker on the serving runtime itself.

The repo's Promela substrate (:mod:`repro.core.promela`) and
explicit-state explorer (:mod:`repro.core.explorer`) were built to
verify the *paper's* tuning models.  This package points the same
machinery at the runtime's own concurrent state machines — the paged
COW allocator, the scheduler × server loop, and the
speculate-commit-rewind cycle — and backs the abstract verdicts with a
conformance bridge to the real code:

* :mod:`~repro.verify.models` — abstract models of the three state
  machines, each a one-process driver whose ``select`` branches over
  runtime operations (every transition names a real allocator method),
* :mod:`~repro.verify.invariants` — the safety/liveness properties
  (``G p`` form, checked by exhaustive DFS),
* :mod:`~repro.verify.conformance` — trail replay against the real
  :class:`~repro.runtime.kv.PagedKVAllocator` (state agreement op by
  op) and the every-real-trace-is-a-model-path cross-check,
* :mod:`~repro.verify.mutants` — deliberately broken allocators the
  checker must catch (the detector is itself tested),
* :mod:`~repro.verify.lint` — AST rules codifying runtime hard-won
  lessons (host-aliasing at dispatch, shared-pool writes, dict-order
  scheduling),
* ``python -m repro.verify`` — the ``check`` / ``lint`` / ``replay`` /
  ``mutants`` CLI wired into CI as a gate.
"""

from .conformance import (ConformanceError, coupled_explore, ops_from_trail,
                          replay_ops, trace_accepted)
from .harness import (MiniServer, ServerConfig, ServerScenario, VReq,
                      canon_pages, restore_allocator)
from .invariants import (Invariant, allocator_invariants, server_invariants,
                         spec_invariants, violated, violates_any)
from .models import (AllocConfig, AllocatorSemantics, ServerSemantics,
                     SpecConfig, SpecSemantics, build_driver_model)
from .mutants import MUTANTS

__all__ = [
    "AllocConfig", "AllocatorSemantics", "SpecConfig", "SpecSemantics",
    "ServerConfig", "ServerScenario", "ServerSemantics", "MiniServer",
    "VReq", "canon_pages", "restore_allocator", "build_driver_model",
    "Invariant", "allocator_invariants", "server_invariants",
    "spec_invariants", "violated", "violates_any",
    "ConformanceError", "coupled_explore", "replay_ops", "trace_accepted",
    "ops_from_trail", "MUTANTS",
]
