"""Assigned architecture config: hymba_15b (see registry.py for the spec)."""
from .registry import hymba_15b as CONFIG  # noqa: F401
