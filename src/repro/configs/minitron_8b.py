"""Assigned architecture config: minitron_8b (see registry.py for the spec)."""
from .registry import minitron_8b as CONFIG  # noqa: F401
