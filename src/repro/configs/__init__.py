"""Architecture configs: one module per assigned arch + registry."""
from .base import SHAPES, ArchConfig, MoECfg, SSMCfg, ShapeSpec, supports
from .registry import ARCHS, get_config

__all__ = ["SHAPES", "ArchConfig", "MoECfg", "SSMCfg", "ShapeSpec",
           "supports", "ARCHS", "get_config"]
