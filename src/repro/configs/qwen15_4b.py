"""Assigned architecture config: qwen15_4b (see registry.py for the spec)."""
from .registry import qwen15_4b as CONFIG  # noqa: F401
