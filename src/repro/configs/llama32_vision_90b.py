"""Assigned architecture config: llama32_vision_90b (see registry.py for the spec)."""
from .registry import llama32_vision_90b as CONFIG  # noqa: F401
