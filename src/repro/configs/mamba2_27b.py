"""Assigned architecture config: mamba2_27b (see registry.py for the spec)."""
from .registry import mamba2_27b as CONFIG  # noqa: F401
