"""Assigned architecture config: smollm_135m (see registry.py for the spec)."""
from .registry import smollm_135m as CONFIG  # noqa: F401
