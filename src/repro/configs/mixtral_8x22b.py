"""Assigned architecture config: mixtral_8x22b (see registry.py for the spec)."""
from .registry import mixtral_8x22b as CONFIG  # noqa: F401
