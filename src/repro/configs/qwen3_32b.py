"""Assigned architecture config: qwen3_32b (see registry.py for the spec)."""
from .registry import qwen3_32b as CONFIG  # noqa: F401
