"""Assigned architecture config: whisper_medium (see registry.py for the spec)."""
from .registry import whisper_medium as CONFIG  # noqa: F401
