"""Assigned architecture config: llama4_maverick (see registry.py for the spec)."""
from .registry import llama4_maverick as CONFIG  # noqa: F401
