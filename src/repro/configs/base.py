"""Architecture + shape configuration schema.

One :class:`ArchConfig` per assigned architecture (see the sibling
modules); every config also provides ``reduced()`` — a same-family tiny
variant for CPU smoke tests.  :class:`ShapeSpec` describes the assigned
input shapes; ``supports()`` encodes the applicability matrix
(DESIGN.md §4): ``long_500k`` needs sub-quadratic attention, decode
shapes need a decoder.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    every: int = 1              # MoE layer every N layers (llama4: 2)
    capacity_factor: float = 1.25
    shared_experts: int = 0     # llama4: 1 shared expert


@dataclass(frozen=True)
class SSMCfg:
    state: int = 128            # N (SSD state dim)
    headdim: int = 64           # P
    expand: int = 2             # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128            # SSD chunk length (tuning parameter)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | ssm | moe | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None          # default d_model // n_heads
    qk_norm: bool = False                # qwen3
    qkv_bias: bool = False               # qwen1.5
    window: int | None = None            # sliding-window attention width
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    mlp_act: str = "swiglu"              # swiglu | gelu
    moe: MoECfg | None = None
    ssm: SSMCfg | None = None
    cross_attn_every: int | None = None  # vlm: 1 cross-attn per N layers
    n_img_tokens: int = 1024             # vlm stub frontend output length
    encoder_layers: int = 0              # audio enc-dec
    enc_seq: int = 1500                  # audio stub frame count
    logits_dtype: str = "float32"
    use_flash: bool = False              # route full-seq self-attention
    #   through the @autotune'd Pallas flash kernel (shapes the kernel
    #   cannot tile fall back to the pure-JAX math per call site)
    remat: str = "full"                  # none | dots | full (tunable)
    ssd_dtype: str = "float32"           # SSD intra-chunk compute dtype (tunable)
    loss_seq_chunk: int = 0              # 0 = whole-sequence CE; else chunked
    source: str = ""                     # provenance tag

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can decode a 500k context without O(S^2) attention state?"""

        return self.family == "ssm" or self.window is not None

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""

        kw: dict = dict(
            n_layers=max(2, (self.cross_attn_every or 2)),
            d_model=64, n_heads=4, head_dim=16,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128, vocab=256, n_img_tokens=8, enc_seq=16,
        )
        if self.window is not None:
            kw["window"] = 8
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state=8, headdim=8, chunk=8)
        if self.encoder_layers:
            kw["encoder_layers"] = 2
        if self.cross_attn_every:
            kw["cross_attn_every"] = 2
            kw["n_layers"] = 4
        if self.moe is not None and self.moe.every > 1:
            kw["n_layers"] = 4
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    def reduced(self) -> "ShapeSpec":
        return ShapeSpec(self.name, min(self.seq_len, 32), min(self.global_batch, 2),
                         self.kind)


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def supports(arch: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-not) per the assignment's applicability rules."""

    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "long_500k needs sub-quadratic attention; " \
                      f"{arch.name} is pure full-attention"
    return True, ""


__all__ = ["ArchConfig", "ShapeSpec", "MoECfg", "SSMCfg", "SHAPES", "supports"]
