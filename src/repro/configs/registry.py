"""The 10 assigned architectures (exact public configs) + registry."""

from __future__ import annotations

from .base import ArchConfig, MoECfg, SSMCfg

minitron_8b = ArchConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=16384, vocab=256_000, head_dim=128,
    source="pruned nemotron [arXiv:2407.14679; hf]")

qwen3_32b = ArchConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=64, n_kv_heads=8, d_ff=25600, vocab=151_936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    source="qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]")

qwen15_4b = ArchConfig(
    name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
    n_heads=20, n_kv_heads=20, d_ff=6912, vocab=151_936, qkv_bias=True,
    use_flash=True,   # flash-path default: full-size shapes tile by 128;
                      # untileable smoke shapes fall back per call site
    source="QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]")

smollm_135m = ArchConfig(
    name="smollm-135m", family="dense", n_layers=30, d_model=576,
    n_heads=9, n_kv_heads=3, d_ff=1536, vocab=49_152, tie_embeddings=True,
    source="llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]")

mamba2_27b = ArchConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=80, n_kv_heads=80, d_ff=0, vocab=50_280, head_dim=64,
    ssm=SSMCfg(state=128, headdim=64, expand=2, chunk=128),
    source="SSD (state-space duality) [arXiv:2405.21060; unverified]")

mixtral_8x22b = ArchConfig(
    name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32_768, head_dim=128,
    window=4096, moe=MoECfg(num_experts=8, top_k=2),
    source="8 experts top-2, SWA [arXiv:2401.04088; hf]")

llama4_maverick = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202_048,
    head_dim=128,
    moe=MoECfg(num_experts=128, top_k=1, every=2, shared_experts=1),
    source="MoE, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E; "
           "unverified] — fused image tokens arrive via the token stream "
           "(frontend stubbed); public Llama-4 uses chunked attention on "
           "some layers, unpinned here -> modeled as full attention")

llama32_vision_90b = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm", n_layers=100, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128_256, head_dim=128,
    cross_attn_every=5, n_img_tokens=1024,
    source="cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision; "
           "unverified] — vision frontend stubbed: input_specs() provides "
           "precomputed patch embeddings")

hymba_15b = ArchConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32_001, head_dim=64,
    window=1024, ssm=SSMCfg(state=16, headdim=64, expand=2, chunk=128),
    source="parallel attn+mamba heads [arXiv:2411.13676; hf] — SWA window "
           "1024 on the attention half, per-layer learned output mix")

whisper_medium = ArchConfig(
    name="whisper-medium", family="audio", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51_865,
    encoder_layers=24, enc_seq=1500, use_rope=False, mlp_act="gelu",
    source="enc-dec, conv frontend (stub) [arXiv:2212.04356; unverified] — "
           "input_specs() provides precomputed frame embeddings")

ARCHS: dict[str, ArchConfig] = {c.name: c for c in [
    minitron_8b, qwen3_32b, qwen15_4b, smollm_135m, mamba2_27b,
    mixtral_8x22b, llama4_maverick, llama32_vision_90b, hymba_15b,
    whisper_medium,
]}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "get_config"] + [k.replace("-", "_").replace(".", "")
                                     for k in ()]
