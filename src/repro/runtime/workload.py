"""Seeded serving traces: the workload half of scheduler tuning.

A *trace* is a list of :class:`TraceRequest` — arrival tick, prompt,
output budget, SLO class, per-class deadline — generated
deterministically from a :class:`TraceConfig` seed, so every policy
(and every tuning ``measure()`` call) drains the IDENTICAL workload and
differences in p50/p99/goodput are attributable to the policy alone.

The clock is the ENGINE TICK, not wall time: arrivals, deadlines, and
latencies are all counted in ``Server.tick()`` calls.  That keeps the
trace and its summary bit-reproducible across machines — wall-clock
enters only through :func:`repro.runtime.tunables.timed_trace_drain`,
which times the same deterministic drain.

Two arrival processes:

* ``poisson`` — geometric inter-arrival gaps at ``rate`` requests/tick
  (the memoryless discrete analogue), the steady-load baseline;
* ``bursty`` — ``burst`` requests land together every ``burst_every``
  ticks; the workload where admission order and preemption actually
  matter (a burst of interactive arrivals behind a batch house is the
  p99 story ``bench_traffic`` tables).

``shared_frac`` of requests open with one common ``prefix_len``-token
system prompt — the traffic shape copy-on-write prefix sharing
(:meth:`~repro.runtime.kv.PagedKVAllocator.share`) exists for.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

import numpy as np

SLO_CLASSES = ("interactive", "batch")


@dataclass(frozen=True)
class TraceRequest:
    """One arrival: everything :meth:`~repro.runtime.serve.Server.submit`
    needs, plus the absolute deadline tick the summary scores against."""

    rid: int
    arrival: int                 # tick the request becomes visible
    prompt: tuple[int, ...]
    max_new: int
    slo: str = "interactive"
    deadline: int = 0            # absolute tick; 0 = no deadline


@dataclass(frozen=True)
class TraceConfig:
    """Knobs of the generator; every field participates in the
    ``serve.scheduler`` fingerprint via the tunable that embeds them."""

    requests: int = 24
    arrival: str = "bursty"             # "poisson" | "bursty"
    rate: float = 1.0                   # poisson: mean arrivals per tick
    burst: int = 6                      # bursty: arrivals per burst
    burst_every: int = 12               # bursty: ticks between bursts
    prompt_len: tuple[int, int] = (6, 24)       # uniform [lo, hi]
    max_new: tuple[int, int] = (4, 12)          # uniform [lo, hi]
    interactive_frac: float = 0.5
    deadlines: Mapping[str, int] = field(       # ticks after arrival
        default_factory=lambda: {"interactive": 48, "batch": 400})
    shared_frac: float = 0.0            # share of requests opening with
    prefix_len: int = 16                # the common system prompt
    vocab: int = 256
    seed: int = 0


def generate_trace(cfg: TraceConfig) -> list[TraceRequest]:
    """The deterministic trace for ``cfg`` (same config -> same trace,
    token for token), sorted by arrival tick."""

    if cfg.arrival not in ("poisson", "bursty"):
        raise ValueError(f"unknown arrival process {cfg.arrival!r}")
    rng = np.random.default_rng(cfg.seed)
    lo_p, hi_p = cfg.prompt_len
    lo_n, hi_n = cfg.max_new
    prefix = [int(t) for t in
              rng.integers(1, cfg.vocab, max(1, cfg.prefix_len))]

    arrivals: list[int] = []
    t = 0
    if cfg.arrival == "poisson":
        p = min(1.0, max(1e-6, cfg.rate))
        for _ in range(cfg.requests):
            t += int(rng.geometric(p))
            arrivals.append(t)
    else:
        while len(arrivals) < cfg.requests:
            n = min(cfg.burst, cfg.requests - len(arrivals))
            arrivals.extend([t] * n)
            t += cfg.burst_every

    out: list[TraceRequest] = []
    for rid, arr in enumerate(arrivals):
        slo = ("interactive" if rng.random() < cfg.interactive_frac
               else "batch")
        plen = int(rng.integers(lo_p, hi_p + 1))
        body = [int(x) for x in rng.integers(1, cfg.vocab, plen)]
        if cfg.shared_frac > 0 and rng.random() < cfg.shared_frac:
            prompt = tuple(prefix + body)
        else:
            prompt = tuple(body)
        max_new = int(rng.integers(lo_n, hi_n + 1))
        deadline = arr + int(cfg.deadlines.get(slo, 0))
        out.append(TraceRequest(rid=rid, arrival=arr, prompt=prompt,
                                max_new=max_new, slo=slo,
                                deadline=deadline))
    return out


def drive_trace(server, trace: list[TraceRequest], *,
                max_ticks: int = 200_000,
                recorder=None) -> dict[int, dict]:
    """Feed ``trace`` into ``server`` on the tick clock and drain it.

    Requests are submitted when the clock reaches their arrival tick
    (idle gaps fast-forward), the server ticks until every request
    retires, and each request's record — finish tick, latency, deadline
    met, output tokens — is returned keyed by trace rid.

    The per-request bookkeeping is TRACE EVENTS, not a private dict:
    the driver emits ``workload.submitted`` / ``workload.retired``
    instants (driver-clock arrival/finish in their args) into the
    server's attached :class:`~repro.obs.observe.Observability`
    recorder — or a local recorder when none is attached — and
    :func:`records_from_events` rebuilds the records from them.  One
    source of truth: the numbers :func:`summarize` reports are exactly
    the numbers a Perfetto view of the trace shows."""

    from ..obs.trace import TraceRecorder
    rec = recorder
    if rec is None:
        obs = getattr(server, "obs", None)
        rec = obs.recorder if obs is not None else None
    if rec is None:
        rec = TraceRecorder()
    pending = sorted(trace, key=lambda r: (r.arrival, r.rid))
    nxt = 0
    clock = 0
    requests: dict[int, "object"] = {}  # trace rid -> Request
    live: dict[int, int] = {}           # server rid -> trace rid
    seen_done = 0
    while nxt < len(pending) or server.queue or \
            any(r is not None for r in server.slot_req):
        if nxt < len(pending) and not server.queue and \
                not any(r is not None for r in server.slot_req) and \
                pending[nxt].arrival > clock:
            clock = pending[nxt].arrival        # idle: jump to next burst
        while nxt < len(pending) and pending[nxt].arrival <= clock:
            tr = pending[nxt]
            nxt += 1
            req = server.submit(list(tr.prompt), tr.max_new, slo=tr.slo,
                                deadline=float(tr.deadline))
            live[req.rid] = tr.rid
            requests[tr.rid] = req
            rec.instant("workload.submitted",
                        track=("request", req.rid), tick=server.ticks,
                        rid=tr.rid, arrival=tr.arrival, slo=tr.slo,
                        deadline=tr.deadline)
        server.tick()
        clock += 1
        while seen_done < len(server.completed):
            req = server.completed[seen_done]
            seen_done += 1
            rec.instant("workload.retired",
                        track=("request", req.rid), tick=server.ticks,
                        rid=live[req.rid], finish=clock,
                        tokens=len(req.out))
        if clock > max_ticks:
            raise RuntimeError("trace did not drain")
    return records_from_events(rec.events, requests)


def records_from_events(events: list[dict],
                        requests: Mapping[int, "object"] | None = None,
                        ) -> dict[int, dict]:
    """Per-request records (the :func:`summarize` input) rebuilt from
    ``workload.submitted`` / ``workload.retired`` trace events, keyed
    by trace rid.  ``requests`` (trace rid -> live
    :class:`~repro.runtime.serve.Request`) attaches the concrete
    request objects the benchmarks read outputs from; records parsed
    back from an exported trace simply omit them."""

    records: dict[int, dict] = {}
    for ev in events:
        args = ev.get("args", ev)
        if ev["name"] == "workload.submitted":
            records[args["rid"]] = {"arrival": args["arrival"],
                                    "slo": args["slo"],
                                    "deadline": args["deadline"]}
        elif ev["name"] == "workload.retired":
            r = records[args["rid"]]
            r["finish"] = args["finish"]
            r["latency"] = args["finish"] - r["arrival"]
            r["met"] = (r["deadline"] <= 0
                        or args["finish"] <= r["deadline"])
            r["tokens"] = args["tokens"]
    if requests is not None:
        for rid, req in requests.items():
            if rid in records:
                records[rid]["request"] = req
    return records


def summarize(records: dict[int, dict], ticks: int) -> dict[str, float]:
    """Latency percentiles (per class and overall, in ticks), SLO
    attainment, and goodput = deadline-met tokens per tick — the
    objective ``serve.scheduler`` tunes."""

    summary: dict[str, float] = {"requests": float(len(records)),
                                 "ticks": float(ticks)}
    lats = {"all": []}
    for rec in records.values():
        lats["all"].append(rec["latency"])
        lats.setdefault(rec["slo"], []).append(rec["latency"])
    for cls, ls in lats.items():
        arr = np.asarray(ls, np.float64)
        summary[f"p50_{cls}"] = float(np.percentile(arr, 50))
        summary[f"p99_{cls}"] = float(np.percentile(arr, 99))
    met = [r for r in records.values() if r["met"]]
    summary["slo_attainment"] = len(met) / max(1, len(records))
    good = float(sum(r["tokens"] for r in met))
    summary["goodput_tokens"] = good
    summary["goodput_per_tick"] = good / max(1, ticks)
    summary["tokens"] = float(sum(r["tokens"]
                                  for r in records.values()))
    return summary


__all__ = ["SLO_CLASSES", "TraceRequest", "TraceConfig", "generate_trace",
           "drive_trace", "records_from_events", "summarize"]
