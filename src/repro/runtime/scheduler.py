"""Serving policy, factored out of the engine: WHICH request to admit,
WHICH slot to sacrifice under page pressure, WHEN to preempt.

:class:`~repro.runtime.serve.Server` owns mechanism — jitted steps,
state merges, page-table plumbing — and delegates every discretionary
decision to a :class:`Scheduler` through three hooks:

* ``pick(server)``: index into ``server.queue`` of the next request to
  place into a free slot (None = hold admission this round),
* ``victim(server)``: the slot to preempt when the page pool cannot
  cover a tick's allocations (the OOM backpressure path),
* ``preempt_for(server)``: a slot to preempt so a WAITING request can
  run — the proactive, SLO-driven sibling of ``victim`` (None = never,
  which is every policy except ``priority``).

The server-side contract the hooks may rely on: ``server.queue`` is the
live waiting list (mutating order is allowed, the server pops the index
``pick`` returns), ``server.admit_fits(req)`` says whether a request's
pages fit right now, ``server.live_slots()`` / ``server.slot_request`` /
``server.slot_seq`` expose the occupied slots, their requests, and
admission order, and ``server.shared_prefix_len(req)`` /
``server.is_share_source(slot)`` expose the copy-on-write prefix index
(:meth:`~repro.runtime.kv.PagedKVAllocator.share`).

Three policies ship behind the ``register_scheduler`` registry:

* ``fcfs`` — arrival order; in paged mode first-fit over the queue with
  an **aging barrier**: a request bypassed ``age_limit`` times blocks
  everything behind it until it fits, so a long prompt is never starved
  by a stream of short ones (the ``skips`` counter on
  :class:`~repro.runtime.serve.Request`).
* ``priority`` — SLO classes (``Request.slo``, e.g. ``interactive`` /
  ``batch``) ranked by per-class weights, earliest deadline first
  within a class, with the same aging escape hatch; under a full house
  it preempts the youngest lowest-class slot to admit a strictly
  higher-class arrival (generated tokens are kept and re-prefilled on
  resume — see ``Server._preempt``).
* ``prefix`` — fcfs plus **prefix affinity**: among fitting requests,
  prefer the one with the longest shared prefix against a live slot, so
  copy-on-write sharing triggers while the source's pages are still
  resident; OOM victims are chosen among non-source slots first to keep
  shared prefixes hot.

The policy choice itself is a tunable (``serve.scheduler``,
:class:`~repro.runtime.tunables.SchedulerTunable`): which scheduler
wins depends on the traffic mix, which is exactly the
per-workload-distribution tuning argument of the paper.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Mapping, Protocol, runtime_checkable

if TYPE_CHECKING:   # pragma: no cover - import cycle guard (serve imports us)
    from .serve import Server


@runtime_checkable
class Scheduler(Protocol):
    """Policy hooks the server calls; see the module docstring for the
    contract of each."""

    kind: str

    def pick(self, server: "Server") -> int | None: ...

    def victim(self, server: "Server") -> int | None: ...

    def preempt_for(self, server: "Server") -> int | None: ...


_REGISTRY: dict[str, type] = {}


def register_scheduler(kind: str):
    """Class decorator: make ``kind`` constructible via
    :func:`make_scheduler` (and listed in :data:`SCHEDULER_KINDS`)."""

    def deco(cls):
        cls.kind = kind
        _REGISTRY[kind] = cls
        return cls
    return deco


def make_scheduler(kind: str | Scheduler | None, **kwargs) -> Scheduler:
    """Resolve a policy: an instance passes through, a kind string is
    looked up in the registry (``prefix-affinity`` aliases ``prefix``),
    None means the default ``fcfs``."""

    if kind is None:
        kind = "fcfs"
    if not isinstance(kind, str):
        if kwargs:
            raise ValueError("scheduler kwargs only apply to kind strings")
        return kind
    key = {"prefix-affinity": "prefix"}.get(kind, kind)
    if key not in _REGISTRY:
        raise ValueError(f"unknown scheduler {kind!r}; "
                         f"known: {', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[key](**kwargs)


def _bump_skips(queue, picked: int) -> int:
    """Requests ahead of the pick were bypassed: age them.  Returns the
    pick unchanged so call sites can ``return _bump_skips(q, i)``."""

    for j in range(picked):
        queue[j].skips += 1
    return picked


@register_scheduler("fcfs")
@dataclass
class FCFSScheduler:
    """Arrival order; paged first-fit with an aging barrier.

    Contiguous mode admits strictly in order (a free slot always has a
    full ring reserved).  Paged mode admits the oldest request whose
    prompt fits the free pages — but a request bypassed ``age_limit``
    times becomes a barrier: nothing behind it is considered until it
    fits, so pages drained by retiring slots flow to the starved
    request instead of the next small arrival."""

    age_limit: int = 8

    def pick(self, server: "Server") -> int | None:
        q = server.queue
        if not q:
            return None
        if not server.paged:
            return 0
        barrier = next((i for i, r in enumerate(q)
                        if r.skips >= self.age_limit), len(q) - 1)
        for i in range(barrier + 1):
            if server.admit_fits(q[i]):
                return _bump_skips(q, i)
        return None

    def victim(self, server: "Server") -> int | None:
        """Youngest active slot: least sunk work, and the oldest slot is
        never sacrificed before all younger ones, so it always
        progresses and the server cannot livelock."""

        live = server.live_slots()
        return max(live, key=server.slot_seq) if live else None

    def preempt_for(self, server: "Server") -> int | None:
        return None


@register_scheduler("priority")
@dataclass
class PriorityScheduler:
    """SLO classes with per-class weights + earliest-deadline-first.

    ``weights`` maps ``Request.slo`` to a rank (lower runs first);
    unknown classes rank after every known one.  Within a class,
    requests order by deadline (unset = latest), then arrival.  Aging
    still applies ACROSS classes: a batch request bypassed ``age_limit``
    times is served next, bounding interactive-storm starvation.  With
    ``preempt=True`` (default) a waiting request of a strictly higher
    class evicts the youngest slot of the lowest live class when no
    slot is free — the preempted request keeps its generated tokens and
    re-prefills them on resume."""

    weights: Mapping[str, int] = field(
        default_factory=lambda: {"interactive": 0, "batch": 1})
    age_limit: int = 32
    preempt: bool = True

    def _rank(self, req) -> int:
        fallback = max(self.weights.values(), default=0) + 1
        return self.weights.get(req.slo, fallback)

    def _key(self, req, order: int):
        dl = req.deadline if req.deadline is not None else float("inf")
        return (self._rank(req), dl, order)

    def pick(self, server: "Server") -> int | None:
        q = server.queue
        if not q:
            return None
        aged = [i for i, r in enumerate(q) if r.skips >= self.age_limit]
        cand = aged or range(len(q))
        fits = [i for i in cand if server.admit_fits(q[i])]
        if not fits:
            return None
        best = min(fits, key=lambda i: self._key(q[i], i))
        return _bump_skips(q, best)

    def victim(self, server: "Server") -> int | None:
        """Lowest class first, youngest within it — batch slots absorb
        page pressure before any interactive slot is touched."""

        live = server.live_slots()
        if not live:
            return None
        return max(live, key=lambda s: (self._rank(server.slot_request(s)),
                                        server.slot_seq(s)))

    def preempt_for(self, server: "Server") -> int | None:
        if not self.preempt or not server.queue or server.has_free_slot():
            return None
        wait = min(self._rank(r) for r in server.queue)
        live = server.live_slots()
        victims = [s for s in live
                   if self._rank(server.slot_request(s)) > wait]
        if not victims:
            return None
        return max(victims, key=lambda s: (
            self._rank(server.slot_request(s)), server.slot_seq(s)))


@register_scheduler("prefix")
@dataclass
class PrefixAffinityScheduler:
    """fcfs first-fit, but among fitting requests prefer the longest
    live shared prefix — admitting a sharer while its source's pages
    are resident turns a prefill into a page-table copy
    (:meth:`~repro.runtime.kv.PagedKVAllocator.share`)."""

    age_limit: int = 8

    def pick(self, server: "Server") -> int | None:
        q = server.queue
        if not q:
            return None
        if not server.paged:
            return 0
        barrier = next((i for i, r in enumerate(q)
                        if r.skips >= self.age_limit), len(q) - 1)
        fits = [i for i in range(barrier + 1) if server.admit_fits(q[i])]
        if not fits:
            return None
        best = max(fits, key=lambda i: (server.shared_prefix_len(q[i]), -i))
        if server.shared_prefix_len(q[best]) <= 0:
            best = fits[0]           # nothing shares: plain first-fit
        return _bump_skips(q, best)

    def victim(self, server: "Server") -> int | None:
        """Youngest NON-SOURCE slot first: evicting a share source
        leaves its pages pinned by sharers anyway (refcounts), but
        keeping it live keeps the prefix admittable for free."""

        live = server.live_slots()
        if not live:
            return None
        pool = [s for s in live if not server.is_share_source(s)] or live
        return max(pool, key=server.slot_seq)

    def preempt_for(self, server: "Server") -> int | None:
        return None


class TracingScheduler:
    """Decorator policy: forwards every hook to ``inner`` and records
    the decisions as ``(hook, decision)`` tuples on :attr:`trace` — the
    scheduler-side half of the shared trace vocabulary
    (:mod:`repro.verify` replays server traces against the abstract
    model; the allocator side is the ``trace`` hook on
    :class:`~repro.runtime.kv.PagedKVAllocator`)."""

    def __init__(self, inner: Scheduler, recorder=None):
        self.inner = inner
        self.trace: list[tuple[str, int | None]] = []
        # optional repro.obs TraceRecorder: non-None decisions also land
        # as instants on the engine track, so a Perfetto timeline shows
        # WHY a slot changed hands next to the tick that did it
        self.recorder = recorder

    @property
    def kind(self) -> str:
        return f"traced-{self.inner.kind}"

    def _record(self, hook: str, out: int | None,
                server: "Server") -> None:
        self.trace.append((hook, out))
        if self.recorder is not None and out is not None:
            self.recorder.instant(f"sched.{hook}", tick=server.ticks,
                                  decision=out, policy=self.inner.kind)

    def pick(self, server: "Server") -> int | None:
        out = self.inner.pick(server)
        self._record("pick", out, server)
        return out

    def victim(self, server: "Server") -> int | None:
        out = self.inner.victim(server)
        self._record("victim", out, server)
        return out

    def preempt_for(self, server: "Server") -> int | None:
        out = self.inner.preempt_for(server)
        self._record("preempt_for", out, server)
        return out


SCHEDULER_KINDS: tuple[str, ...] = tuple(sorted(_REGISTRY))

__all__ = ["Scheduler", "FCFSScheduler", "PriorityScheduler",
           "PrefixAffinityScheduler", "TracingScheduler",
           "register_scheduler", "make_scheduler", "SCHEDULER_KINDS"]
