"""Runtime: train step builder, fault-tolerant supervisor, serving."""

from .kv import PagedKVAllocator, PagedKVSpec
from .loop import History, LoopConfig, SimulatedFailure, run_training
from .scheduler import (SCHEDULER_KINDS, FCFSScheduler,
                        PrefixAffinityScheduler, PriorityScheduler,
                        Scheduler, make_scheduler, register_scheduler)
from .serve import Request, Server
from .speculate import (Drafter, DraftModelDrafter, NGramDrafter,
                        SpecDepthTunable, choose_spec_depth, make_drafter,
                        spec_depth_tunable)
from .train import (TrainConfig, TrainState, abstract_train_state,
                    build_train_step, init_train_state)
from .tunables import (DecodeBatchTunable, KVPageTunable, PrefillChunkTunable,
                       SchedulerTunable, choose_batch, choose_kv_page,
                       choose_prefill_chunk, choose_scheduler,
                       decode_batch_tunable, kv_page_tunable,
                       prefill_chunk_tunable, scheduler_tunable,
                       timed_server_drain, timed_trace_drain)
from .workload import (SLO_CLASSES, TraceConfig, TraceRequest, drive_trace,
                       generate_trace, summarize)

__all__ = ["History", "LoopConfig", "SimulatedFailure", "run_training",
           "Request", "Server", "PagedKVAllocator", "PagedKVSpec",
           "Scheduler", "FCFSScheduler", "PriorityScheduler",
           "PrefixAffinityScheduler", "register_scheduler", "make_scheduler",
           "SCHEDULER_KINDS",
           "DecodeBatchTunable", "PrefillChunkTunable", "KVPageTunable",
           "SchedulerTunable",
           "choose_batch", "choose_prefill_chunk", "choose_kv_page",
           "choose_scheduler",
           "decode_batch_tunable", "prefill_chunk_tunable",
           "kv_page_tunable", "scheduler_tunable",
           "timed_server_drain", "timed_trace_drain",
           "SLO_CLASSES", "TraceRequest", "TraceConfig", "generate_trace",
           "drive_trace", "summarize",
           "Drafter", "NGramDrafter", "DraftModelDrafter", "make_drafter",
           "SpecDepthTunable", "spec_depth_tunable", "choose_spec_depth",
           "TrainConfig", "TrainState", "abstract_train_state",
           "build_train_step", "init_train_state"]
