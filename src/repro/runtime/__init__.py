"""Runtime: train step builder, fault-tolerant supervisor, serving."""

from .loop import History, LoopConfig, SimulatedFailure, run_training
from .serve import DecodeBatchTunable, Request, Server, choose_batch
from .train import (TrainConfig, TrainState, abstract_train_state,
                    build_train_step, init_train_state)

__all__ = ["History", "LoopConfig", "SimulatedFailure", "run_training",
           "Request", "Server", "DecodeBatchTunable", "choose_batch",
           "TrainConfig", "TrainState", "abstract_train_state",
           "build_train_step", "init_train_state"]
