"""Runtime: train step builder, fault-tolerant supervisor, serving."""

from .loop import History, LoopConfig, SimulatedFailure, run_training
from .serve import Request, Server
from .train import (TrainConfig, TrainState, abstract_train_state,
                    build_train_step, init_train_state)

__all__ = ["History", "LoopConfig", "SimulatedFailure", "run_training",
           "Request", "Server", "TrainConfig", "TrainState",
           "abstract_train_state", "build_train_step", "init_train_state"]
