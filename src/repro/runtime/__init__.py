"""Runtime: train step builder, fault-tolerant supervisor, serving."""

from .loop import History, LoopConfig, SimulatedFailure, run_training
from .serve import (DecodeBatchTunable, PrefillChunkTunable, Request,
                    Server, choose_batch, choose_prefill_chunk,
                    decode_batch_tunable, prefill_chunk_tunable)
from .train import (TrainConfig, TrainState, abstract_train_state,
                    build_train_step, init_train_state)

__all__ = ["History", "LoopConfig", "SimulatedFailure", "run_training",
           "Request", "Server", "DecodeBatchTunable", "PrefillChunkTunable",
           "choose_batch", "choose_prefill_chunk",
           "decode_batch_tunable", "prefill_chunk_tunable",
           "TrainConfig", "TrainState", "abstract_train_state",
           "build_train_step", "init_train_state"]
