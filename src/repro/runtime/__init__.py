"""Runtime: train step builder, fault-tolerant supervisor, serving."""

from .kv import PagedKVAllocator, PagedKVSpec
from .loop import History, LoopConfig, SimulatedFailure, run_training
from .serve import (DecodeBatchTunable, KVPageTunable, PrefillChunkTunable,
                    Request, Server, choose_batch, choose_kv_page,
                    choose_prefill_chunk, decode_batch_tunable,
                    kv_page_tunable, prefill_chunk_tunable,
                    timed_server_drain)
from .train import (TrainConfig, TrainState, abstract_train_state,
                    build_train_step, init_train_state)

__all__ = ["History", "LoopConfig", "SimulatedFailure", "run_training",
           "Request", "Server", "PagedKVAllocator", "PagedKVSpec",
           "DecodeBatchTunable", "PrefillChunkTunable", "KVPageTunable",
           "choose_batch", "choose_prefill_chunk", "choose_kv_page",
           "decode_batch_tunable", "prefill_chunk_tunable",
           "kv_page_tunable", "timed_server_drain",
           "TrainConfig", "TrainState", "abstract_train_state",
           "build_train_step", "init_train_state"]
