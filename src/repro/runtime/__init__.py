"""Runtime: train step builder, fault-tolerant supervisor, serving."""

from .kv import PagedKVAllocator, PagedKVSpec
from .loop import History, LoopConfig, SimulatedFailure, run_training
from .serve import (DecodeBatchTunable, KVPageTunable, PrefillChunkTunable,
                    Request, Server, choose_batch, choose_kv_page,
                    choose_prefill_chunk, decode_batch_tunable,
                    kv_page_tunable, prefill_chunk_tunable,
                    timed_server_drain)
from .speculate import (Drafter, DraftModelDrafter, NGramDrafter,
                        SpecDepthTunable, choose_spec_depth, make_drafter,
                        spec_depth_tunable)
from .train import (TrainConfig, TrainState, abstract_train_state,
                    build_train_step, init_train_state)

__all__ = ["History", "LoopConfig", "SimulatedFailure", "run_training",
           "Request", "Server", "PagedKVAllocator", "PagedKVSpec",
           "DecodeBatchTunable", "PrefillChunkTunable", "KVPageTunable",
           "choose_batch", "choose_prefill_chunk", "choose_kv_page",
           "decode_batch_tunable", "prefill_chunk_tunable",
           "kv_page_tunable", "timed_server_drain",
           "Drafter", "NGramDrafter", "DraftModelDrafter", "make_drafter",
           "SpecDepthTunable", "spec_depth_tunable", "choose_spec_depth",
           "TrainConfig", "TrainState", "abstract_train_state",
           "build_train_step", "init_train_state"]
