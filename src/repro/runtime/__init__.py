"""Runtime: train step builder, fault-tolerant supervisor, serving."""

from .loop import History, LoopConfig, SimulatedFailure, run_training
from .serve import (DecodeBatchTunable, Request, Server, choose_batch,
                    decode_batch_tunable)
from .train import (TrainConfig, TrainState, abstract_train_state,
                    build_train_step, init_train_state)

__all__ = ["History", "LoopConfig", "SimulatedFailure", "run_training",
           "Request", "Server", "DecodeBatchTunable", "choose_batch",
           "decode_batch_tunable",
           "TrainConfig", "TrainState", "abstract_train_state",
           "build_train_step", "init_train_state"]
