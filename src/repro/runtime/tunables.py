"""Serving-configuration tunables + their measurement harnesses.

Every serving knob the ``repro.tune`` registry exposes lives here —
:class:`DecodeBatchTunable` (``serve.decode_batch``),
:class:`PrefillChunkTunable` (``serve.prefill_chunk``),
:class:`KVPageTunable` (``serve.kv_page``), and the policy-level
:class:`SchedulerTunable` (``serve.scheduler``) — together with the two
harnesses their ``measure(cfg)`` implementations drain through:
:func:`timed_server_drain` (a fixed prompt list) and
:func:`timed_trace_drain` (a seeded :mod:`~repro.runtime.workload`
trace).  :class:`~repro.runtime.speculate.SpecDepthTunable` stays next
to its drafters but measures through the same harness.

This module was extracted from ``runtime/serve.py`` when the scheduler
subsystem landed; ``repro.runtime.serve`` re-exports every public name,
and the tunables keep their ``name`` ClassVars, so existing imports AND
existing cache fingerprints (keyed by tunable name, not module path)
are unchanged.  The :class:`~repro.runtime.serve.Server` import is
deferred to call time to keep the serve -> tunables re-export acyclic.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, ClassVar, Mapping

from ..calibrate.spec import get_platform_spec
from ..core.search_space import Param, SearchSpace

KV_CACHE_BYTES = 2          # bf16 cache entries
K_AND_V = 2                 # two tensors per layer

# Every cost() below prices bytes and FLOPs against the ACTIVE platform
# spec (repro.calibrate) — the TPU v5e defaults until a calibration
# artifact exists, the fitted constants after `python -m repro.calibrate
# run`.  The per-tunable dispatch_s fields stay explicit knobs (and part
# of the cache fingerprint); the calibrated dispatch latency is
# available as get_platform_spec().dispatch_s for callers sizing them.


def publish_drain_stats(registry, stats: Mapping[str, float], *,
                        prefix: str = "serve") -> None:
    """Publish a drain's scalar counters into a
    :class:`~repro.obs.metrics.MetricsRegistry` as ``prefix.``-dotted
    gauges (gauges, not counters: the values are per-drain snapshots,
    not monotone accumulations across drains)."""

    for key, value in stats.items():
        if isinstance(value, (int, float)):
            registry.gauge(f"{prefix}.{key}").set(float(value))


def timed_server_drain(api, params, *, batch: int, context: int,
                       prompts, max_new: int, prefill_chunk: int = 32,
                       paged: bool = False, page_size: int = 16,
                       kv_pages: int | None = None, speculate: Any = None,
                       spec_depth: int = 4, registry: Any = None,
                       stats_out: dict | None = None, warmup: int = 1,
                       iters: int = 1) -> float:
    """Median wall-clock microseconds to drain ``prompts`` (a list of
    token lists) through a fresh :class:`~repro.runtime.serve.Server` —
    the one measurement harness behind every serving tunable's
    ``measure(cfg)`` (:class:`DecodeBatchTunable`,
    :class:`PrefillChunkTunable`, :class:`KVPageTunable`,
    :class:`~repro.runtime.speculate.SpecDepthTunable`).  Warmup drains
    absorb the step compiles for the batch/chunk shape.
    ``speculate``/``spec_depth`` pass through to ``Server`` (hand a
    shared Drafter INSTANCE across calls to reuse a draft model's jit
    cache).

    The last drain's ``Server.stats`` snapshot is published into
    ``registry`` (a :class:`~repro.obs.metrics.MetricsRegistry`) as
    ``serve.``-prefixed gauges; ``stats_out`` (a dict) is the
    back-compat shim — it is rebuilt FROM the registry, so both views
    carry identical keys and values and existing callers (and the
    tuning-cache fingerprints built on them) are unchanged."""

    from ..kernels.common import time_fn
    from ..obs.metrics import MetricsRegistry
    from .serve import Server
    prompts = [list(p) for p in prompts]
    reg = registry if registry is not None else MetricsRegistry()

    def drain() -> None:
        srv = Server(api, params, batch=batch, context=context,
                     prefill_chunk=prefill_chunk, paged=paged,
                     page_size=page_size, kv_pages=kv_pages,
                     speculate=speculate, spec_depth=spec_depth)
        for prompt in prompts:
            srv.submit(prompt, max_new=max_new)
        srv.run_until_drained()
        publish_drain_stats(reg, srv.stats(), prefix="serve")
        if stats_out is not None:
            stats_out.clear()
            stats_out.update(reg.collect("serve"))

    return time_fn(drain, warmup=warmup, iters=iters)


def timed_trace_drain(api, params, trace, *, batch: int, context: int,
                      prefill_chunk: int = 32, paged: bool = True,
                      page_size: int = 16, kv_pages: int | None = None,
                      scheduler: Any = None, share_prefix: bool = False,
                      obs: Any = None, registry: Any = None,
                      stats_out: dict | None = None, warmup: int = 1,
                      iters: int = 1) -> float:
    """Median wall-clock microseconds to drain a
    :mod:`~repro.runtime.workload` trace through a fresh
    :class:`~repro.runtime.serve.Server` under ``scheduler`` — the
    harness behind :class:`SchedulerTunable.measure` and
    ``bench_traffic``.  The trace is pre-generated (seeded), so every
    policy drains the identical arrival sequence.

    The last drain's :func:`~repro.runtime.workload.summarize` record
    and selected engine counters are published into ``registry`` as
    ``traffic.``-prefixed gauges; ``stats_out`` is the back-compat shim
    rebuilt FROM the registry (plus the non-scalar ``records``
    passthrough the benchmarks read outputs from), so existing callers
    see the identical dict they always did.  ``obs`` (an
    :class:`~repro.obs.observe.Observability`) attaches to the LAST
    drain only — warmup drains and timing iterations before it stay
    untraced so the traced drain's span set covers exactly one
    drain."""

    from ..kernels.common import time_fn
    from ..obs.metrics import MetricsRegistry
    from .serve import Server
    from .workload import drive_trace, summarize

    reg = registry if registry is not None else MetricsRegistry()
    total = max(0, warmup) + max(1, iters)   # time_fn's call count
    calls = 0

    def drain() -> None:
        nonlocal calls
        calls += 1
        srv = Server(api, params, batch=batch, context=context,
                     prefill_chunk=prefill_chunk, paged=paged,
                     page_size=page_size, kv_pages=kv_pages,
                     scheduler=scheduler, share_prefix=share_prefix,
                     obs=obs if calls == total else None)
        records = drive_trace(srv, trace)
        summary = summarize(records, srv.ticks)
        st = srv.stats()
        for k in ("prefill_chunks", "deferrals", "preemptions",
                  "shared_tokens", "cow_copies", "peak_active",
                  "mean_active"):
            summary[k] = st[k]
        publish_drain_stats(reg, summary, prefix="traffic")
        if stats_out is not None:
            stats_out.clear()
            stats_out.update(reg.collect("traffic"))
            stats_out["records"] = records

    return time_fn(drain, warmup=warmup, iters=iters)


def _require_model(tunable, helper: str) -> None:
    if tunable.api is None or tunable.params is None:
        raise RuntimeError(
            f"{type(tunable).__name__}.measure needs the model attached: "
            f"construct with api=/params= ({helper})")


def kv_cache_stream_s(batch: int, layers: int, cache_len: int,
                      kv_width: int) -> float:
    """Seconds to stream every slot's KV cache once (one engine tick's
    cache traffic).  GQA caches are ``n_kv_heads * hd`` elements wide —
    modeling them as ``d_model`` overestimated KV reads by the
    ``n_heads / n_kv_heads`` grouping ratio and biased slot-count picks
    low.  Shared by :class:`DecodeBatchTunable` and
    :class:`PrefillChunkTunable`."""

    return (batch * layers * cache_len * kv_width
            * K_AND_V * KV_CACHE_BYTES / get_platform_spec().hbm_bw)


@dataclass(frozen=True)
class DecodeBatchTunable:
    """``repro.tune`` Tunable: the server's slot count.

    Decode is HBM-bound: each engine tick re-streams the weights once
    (amortized over every active slot) and reads each slot's KV cache.
    More slots amortize the weight stream but add KV traffic and admit
    waves of requests; the grid engine picks the drain-time optimum for
    an expected load (request count × mean new tokens).

    With ``api``/``params`` attached (``choose_batch(..., params=...)``)
    the tunable also implements ``measure(cfg)`` — a real
    :class:`~repro.runtime.serve.Server` drain at that slot count — so
    ``engine="measure"`` can refine the modeled pick against
    wall-clock."""

    param_bytes: int
    layers: int
    d_model: int
    context: int
    requests: int
    mean_new: int
    max_batch: int = 64
    dispatch_s: float = 50e-6
    # GQA KV-cache width in elements (n_kv_heads * hd); 0 falls back to
    # d_model (the pre-fix overestimate) for old call sites
    kv_width: int = 0
    # hardware-in-the-loop handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.decode_batch"

    def space(self) -> SearchSpace:
        sizes = []
        b = 1
        while b <= self.max_batch:
            sizes.append(b)
            b *= 2
        return SearchSpace(params=[Param("batch", tuple(sizes))])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds to drain the expected load (same unit
        as ``measure`` so modeled/measured entries are comparable)."""

        b = cfg["batch"]
        weight_s = self.param_bytes / get_platform_spec().hbm_bw
        kv_s = kv_cache_stream_s(b, self.layers, self.context,
                                 self.kv_width or self.d_model)
        tick_s = weight_s + kv_s + self.dispatch_s
        waves = -(-self.requests // b)
        return waves * self.mean_new * tick_s * 1e6

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1, prompt_len: int = 4) -> float:
        """Wall-clock microseconds to drain the expected load through a
        real :class:`~repro.runtime.serve.Server` at this slot count."""

        _require_model(self, "choose_batch(..., params=...)")
        plen = max(1, min(prompt_len, self.context - self.mean_new - 1))
        return timed_server_drain(
            self.api, self.params, batch=int(cfg["batch"]),
            context=self.context,
            prompts=[range(1, plen + 1)] * self.requests,
            max_new=self.mean_new, warmup=warmup, iters=iters)

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        # "unit" keys out stale entries from before cost() switched from
        # seconds to microseconds (same fields, 1e6-different meaning)
        return {"tunable": self.name, "unit": "us", **fp}


def decode_batch_tunable(api, *, context: int, requests: int,
                         max_new: int, params=None) -> DecodeBatchTunable:
    """The server-slot tunable for this model + expected load — the one
    place the sizing wiring lives (library ``choose_batch`` and the
    ``launch/serve --tune-batch`` CLI both build through here)."""

    return DecodeBatchTunable(param_bytes=api.param_count() * 2,
                              layers=api.cfg.n_layers,
                              d_model=api.cfg.d_model, context=context,
                              requests=requests, mean_new=max_new,
                              kv_width=api.cfg.n_kv_heads * api.cfg.hd,
                              api=api, params=params)


def choose_batch(api, *, context: int, requests: int,
                 max_new: int, cache="default", params=None,
                 engine: str = "grid", **tune_kw):
    """Pick the slot count for :class:`~repro.runtime.serve.Server` via
    ``repro.tune``; returns ``(batch, TuneResult)``.

    ``engine="measure"`` (requires ``params``) shortlists slot counts
    through the drain-time model, then times real server drains and
    returns the wall-clock winner."""

    from ..tune import tune as _tune
    tb = decode_batch_tunable(api, context=context, requests=requests,
                              max_new=max_new, params=params)
    res = _tune(tb, engine=engine, cache=cache, **tune_kw)
    return int(res.best_config["batch"]), res


@dataclass(frozen=True)
class PrefillChunkTunable:
    """``repro.tune`` Tunable: tokens per chunked-prefill tick
    (``Server(prefill_chunk=...)``).

    Chunked prefill amortizes the per-tick weight stream over ``chunk``
    prompt tokens, so a prompt costs ``ceil(len/chunk)`` ticks instead
    of ``len`` — but each tick spends chunk-linear matmul FLOPs and a
    chunk-quadratic attention-score term, so the optimum is a genuine
    tradeoff, not "as big as possible".  ``cost`` models the drain of
    the expected long-prompt load (``requests`` prompts of
    ``prompt_len`` tokens + ``mean_new`` decode steps each) in
    microseconds; with ``api``/``params`` attached, ``measure(cfg)``
    drains a real :class:`~repro.runtime.serve.Server` at that chunk
    size so ``engine="measure"`` can return the wall-clock winner."""

    param_bytes: int
    layers: int
    d_model: int
    kv_width: int               # GQA cache width, n_kv_heads * hd
    context: int
    prompt_len: int
    requests: int
    mean_new: int
    batch: int = 4
    max_chunk: int = 256
    dispatch_s: float = 50e-6
    # hardware-in-the-loop handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.prefill_chunk"

    def space(self) -> SearchSpace:
        sizes = []
        c = 1
        cap = min(self.max_chunk, self.context)
        while c <= cap:
            sizes.append(c)
            if c >= self.prompt_len:    # larger chunks cannot help
                break
            c *= 2
        return SearchSpace(params=[Param("chunk", tuple(sizes))])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds to drain the load (same unit as
        ``measure``): per prefill tick, one weight stream (amortized
        over the chunk — the term chunking exists to shrink), one KV
        stream (GQA width, shared with :class:`DecodeBatchTunable`),
        chunk-linear matmul FLOPs, and a chunk-quadratic score/HBM term;
        decode ticks follow the decode-batch model."""

        chunk = cfg["chunk"]
        spec = get_platform_spec()
        n_params = self.param_bytes / 2            # bf16 weights
        weight_s = self.param_bytes / spec.hbm_bw
        kv_s = kv_cache_stream_s(self.batch, self.layers, self.context,
                                 self.kv_width)
        flops_s = 2 * n_params * chunk * self.batch / spec.peak_flops
        score_s = (self.batch * self.layers * chunk
                   * (self.context + chunk) * 4 / spec.hbm_bw)
        prefill_tick_s = (weight_s + kv_s + flops_s + score_s
                          + self.dispatch_s)
        decode_tick_s = (weight_s + kv_s
                         + 2 * n_params * self.batch / spec.peak_flops
                         + self.dispatch_s)
        prefill_ticks = -(-self.prompt_len // chunk)
        waves = -(-self.requests // self.batch)
        return waves * (prefill_ticks * prefill_tick_s
                        + self.mean_new * decode_tick_s) * 1e6

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1) -> float:
        """Wall-clock microseconds to drain the long-prompt load through
        a real :class:`~repro.runtime.serve.Server` at this chunk
        size."""

        _require_model(self, "choose_prefill_chunk(..., params=...)")
        if self.prompt_len > self.context - self.mean_new:
            # silently clamping here would measure a different load than
            # cost() models and the cache fingerprint claims
            raise ValueError(
                f"prompt_len={self.prompt_len} + mean_new={self.mean_new} "
                f"exceeds context={self.context}; size the tunable to the "
                f"load it will actually serve (prefill_chunk_tunable "
                f"clamps for you)")
        vocab = self.api.cfg.vocab
        prompt = [i % (vocab - 1) + 1 for i in range(self.prompt_len)]
        return timed_server_drain(
            self.api, self.params, batch=self.batch, context=self.context,
            prompts=[prompt] * self.requests, max_new=self.mean_new,
            prefill_chunk=int(cfg["chunk"]), warmup=warmup, iters=iters)

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        return {"tunable": self.name, "unit": "us", **fp}


def prefill_chunk_tunable(api, *, context: int, prompt_len: int,
                          requests: int, max_new: int, batch: int,
                          max_chunk: int = 256,
                          params=None) -> PrefillChunkTunable:
    """The chunked-prefill tunable for this model + expected load — the
    one place the sizing wiring lives (library ``choose_prefill_chunk``
    and the ``launch/serve --tune-prefill`` CLI both build through
    here)."""

    # clamp UP FRONT so cost(), measure() and the cache fingerprint all
    # describe the same load
    prompt_len = max(1, min(prompt_len, context - max_new))
    return PrefillChunkTunable(param_bytes=api.param_count() * 2,
                               layers=api.cfg.n_layers,
                               d_model=api.cfg.d_model,
                               kv_width=api.cfg.n_kv_heads * api.cfg.hd,
                               context=context, prompt_len=prompt_len,
                               requests=requests, mean_new=max_new,
                               batch=batch, max_chunk=max_chunk,
                               api=api, params=params)


def choose_prefill_chunk(api, *, context: int, prompt_len: int,
                         requests: int, max_new: int, batch: int,
                         cache="default", params=None,
                         engine: str = "grid", **tune_kw):
    """Pick ``Server``'s ``prefill_chunk`` via ``repro.tune``; returns
    ``(chunk, TuneResult)``.  ``engine="measure"`` (requires ``params``)
    shortlists chunk sizes through the drain-time model, then times real
    long-prompt server drains and returns the wall-clock winner."""

    from ..tune import tune as _tune
    tb = prefill_chunk_tunable(api, context=context, prompt_len=prompt_len,
                               requests=requests, max_new=max_new,
                               batch=batch, params=params)
    res = _tune(tb, engine=engine, cache=cache, **tune_kw)
    return int(res.best_config["chunk"]), res


@dataclass(frozen=True)
class KVPageTunable:
    """``repro.tune`` Tunable: the paged KV-cache page size
    (``Server(paged=True, page_size=...)``).

    The page size trades **internal fragmentation** against **gather
    overhead**: every live request strands the unused tail of its last
    page (~``page/2`` tokens expected), shrinking how many requests a
    fixed pool holds concurrently — so big pages mean more drain waves;
    but every attended token is reached through the page table, and
    smaller pages mean more page descriptors per tick.  ``cost`` models
    the drain of a MIXED-length load (``prompt_lens`` cycled over
    ``requests``, ``mean_new`` decode steps each, ``batch`` slots
    sharing ``pool_tokens`` of page capacity) in microseconds; with
    ``api``/``params`` attached, ``measure(cfg)`` drains the same mixed
    load through a real paged :class:`~repro.runtime.serve.Server`."""

    param_bytes: int
    layers: int
    d_model: int
    kv_width: int               # GQA cache width, n_kv_heads * hd
    context: int
    prompt_lens: tuple[int, ...]
    requests: int
    mean_new: int
    batch: int = 4
    pool_tokens: int = 0        # 0 -> batch * context (contiguous parity)
    prefill_chunk: int = 32
    max_page: int = 128
    page_gather_s: float = 2e-6  # per page descriptor chased per tick
    dispatch_s: float = 50e-6
    # hardware-in-the-loop handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.kv_page"

    def __post_init__(self):
        # plan specs deliver JSON lists; the fingerprint and lattice
        # want a hashable tuple
        object.__setattr__(self, "prompt_lens", tuple(self.prompt_lens))
        if not self.prompt_lens:
            raise ValueError("prompt_lens must name at least one length")

    def _pool(self) -> int:
        return self.pool_tokens or self.batch * self.context

    def space(self) -> SearchSpace:
        sizes = []
        ps = 4
        cap = min(self.max_page, self.context)
        while ps <= cap:
            sizes.append(ps)
            ps *= 2
        return SearchSpace(params=[Param("page", tuple(sizes))])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds to drain the mixed load (same unit as
        ``measure``): requests occupy ``ceil(total/page)`` pages each —
        the page-rounding waste caps how many run concurrently in the
        pool — and each tick pays the weight stream, the live-KV
        stream, and one page-table chase per live page."""

        page = cfg["page"]
        totals = [min(L, self.context - self.mean_new) + self.mean_new
                  for L in self.prompt_lens]
        mean_total = sum(totals) / len(totals)
        # page-capacity footprint of one request, fragmentation included
        footprint = sum(-(-t // page) * page for t in totals) / len(totals)
        conc = max(1, min(self.batch, int(self._pool() // footprint)))
        waves = -(-self.requests // conc)
        mean_prompt = mean_total - self.mean_new
        ticks = -(-int(mean_prompt) // self.prefill_chunk) + self.mean_new
        weight_s = self.param_bytes / get_platform_spec().hbm_bw
        kv_s = kv_cache_stream_s(conc, self.layers, int(mean_total),
                                 self.kv_width)
        gather_s = conc * -(-int(mean_total) // page) * self.page_gather_s
        tick_s = weight_s + kv_s + gather_s + self.dispatch_s
        return waves * ticks * tick_s * 1e6

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1) -> float:
        """Wall-clock microseconds to drain the mixed-length load
        through a real paged :class:`~repro.runtime.serve.Server` at
        this page size."""

        _require_model(self, "choose_kv_page(..., params=...)")
        page = int(cfg["page"])
        vocab = self.api.cfg.vocab
        prompts = []
        for r in range(self.requests):
            plen = min(self.prompt_lens[r % len(self.prompt_lens)],
                       self.context - self.mean_new)
            prompts.append([(r + i) % (vocab - 1) + 1 for i in range(plen)])
        kv_pages = max(self._pool() // page, -(-self.context // page))
        return timed_server_drain(
            self.api, self.params, batch=self.batch, context=self.context,
            prompts=prompts, max_new=self.mean_new,
            prefill_chunk=self.prefill_chunk, paged=True, page_size=page,
            kv_pages=kv_pages, warmup=warmup, iters=iters)

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        fp["prompt_lens"] = list(self.prompt_lens)
        return {"tunable": self.name, "unit": "us", **fp}


def kv_page_tunable(api, *, context: int, prompt_lens,
                    requests: int, max_new: int, batch: int,
                    pool_tokens: int | None = None,
                    params=None) -> KVPageTunable:
    """The page-size tunable for this model + expected mixed-length
    load — the one place the sizing wiring lives (library
    ``choose_kv_page`` and the ``launch/serve --tune-page`` CLI both
    build through here)."""

    prompt_lens = tuple(max(1, min(p, context - max_new))
                        for p in prompt_lens)
    return KVPageTunable(param_bytes=api.param_count() * 2,
                         layers=api.cfg.n_layers, d_model=api.cfg.d_model,
                         kv_width=api.cfg.n_kv_heads * api.cfg.hd,
                         context=context, prompt_lens=prompt_lens,
                         requests=requests, mean_new=max_new, batch=batch,
                         pool_tokens=pool_tokens or 0,
                         api=api, params=params)


def choose_kv_page(api, *, context: int, prompt_lens,
                   requests: int, max_new: int, batch: int,
                   pool_tokens: int | None = None, cache="default",
                   params=None, engine: str = "grid", **tune_kw):
    """Pick ``Server(paged=True)``'s page size via ``repro.tune``;
    returns ``(page, TuneResult)``.  ``engine="measure"`` (requires
    ``params``) shortlists page sizes through the fragmentation/gather
    model, then times real mixed-length paged drains and returns the
    wall-clock winner."""

    from ..tune import tune as _tune
    tb = kv_page_tunable(api, context=context, prompt_lens=prompt_lens,
                         requests=requests, max_new=max_new, batch=batch,
                         pool_tokens=pool_tokens, params=params)
    res = _tune(tb, engine=engine, cache=cache, **tune_kw)
    return int(res.best_config["page"]), res


@dataclass(frozen=True)
class SchedulerTunable:
    """``repro.tune`` Tunable: the serving POLICY —
    ``Server(scheduler=..., share_prefix=...)`` — tuned against a seeded
    traffic trace (:mod:`~repro.runtime.workload`).

    The lattice is ``policy`` (:data:`~repro.runtime.scheduler.\
SCHEDULER_KINDS`: fcfs / prefix / priority — prefix also enables
    copy-on-write prefix sharing) × ``age_limit`` (the starvation
    threshold every policy carries).  The objective is **microseconds
    of wall-clock per goodput token** — goodput being deadline-met
    output tokens — so a policy only wins by actually serving the SLO
    mix, not by finishing an unweighted drain fast.

    ``cost(cfg)`` is a small queueing model of the trace distribution:
    burst arrivals queue ``ceil(position/concurrency)`` service rounds
    deep, priority lets interactive requests requeue ahead of batch
    (shrinking their wait to their own class), prefix sharing deletes
    the shared fraction of prefill ticks.  ``measure(cfg)`` is the real
    thing: :func:`timed_trace_drain` over the identical seeded trace.
    Unlike the other serving tunables this one builds its own reduced
    float32 model from ``arch`` on first ``measure`` — a plan-registry
    job (``serve.scheduler`` in ``fleet_warmup.json``) can therefore
    run ``engine="measure"`` with JSON-only params."""

    arch: str = "smollm-135m"
    context: int = 64
    batch: int = 4
    page_size: int = 8
    kv_pages: int = 0           # 0 -> full per-slot backing
    prefill_chunk: int = 8
    # trace shape (mirrors workload.TraceConfig)
    requests: int = 12
    arrival: str = "bursty"
    rate: float = 1.0
    burst: int = 4
    burst_every: int = 10
    prompt_len: tuple[int, int] = (6, 20)
    max_new: tuple[int, int] = (4, 8)
    interactive_frac: float = 0.5
    shared_frac: float = 0.5
    prefix_len: int = 12
    seed: int = 0
    # lattice bounds
    policies: tuple[str, ...] = ("fcfs", "prefix", "priority")
    age_limits: tuple[int, ...] = (4, 32)
    # lazily-built model handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    last_stats: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.scheduler"

    def __post_init__(self):
        # plan specs deliver JSON lists; the lattice and fingerprint
        # want hashable tuples
        for f in ("prompt_len", "max_new", "policies", "age_limits"):
            object.__setattr__(self, f, tuple(getattr(self, f)))

    def space(self) -> SearchSpace:
        return SearchSpace(params=[Param("policy", self.policies),
                                   Param("age_limit", self.age_limits)])

    def trace_config(self):
        from .workload import TraceConfig
        vocab = 256
        if self.api is not None:
            vocab = self.api.cfg.vocab
        return TraceConfig(
            requests=self.requests, arrival=self.arrival, rate=self.rate,
            burst=self.burst, burst_every=self.burst_every,
            prompt_len=self.prompt_len, max_new=self.max_new,
            interactive_frac=self.interactive_frac,
            shared_frac=self.shared_frac, prefix_len=self.prefix_len,
            vocab=min(vocab, 4096), seed=self.seed)

    # -- modeled objective --------------------------------------------------

    def _trace_moments(self) -> tuple[float, float, float]:
        """(mean prompt, mean new, deadline_interactive) of the trace
        distribution — the shares cost() reasons over."""

        mean_prompt = (sum(self.prompt_len) / 2
                       + self.shared_frac * self.prefix_len)
        mean_new = sum(self.max_new) / 2
        from .workload import TraceConfig
        dl = TraceConfig().deadlines["interactive"]
        return mean_prompt, mean_new, float(dl)

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds per goodput token (same unit as
        ``measure``): service time per request from the prefill/decode
        tick counts, concurrency from the page pool, queueing delay
        from burst position ÷ concurrency — priority requeues
        interactive ahead of batch, prefix deletes shared prefill."""

        policy = str(cfg["policy"])
        mean_prompt, mean_new, dl_int = self._trace_moments()
        from ..configs import get_config
        acfg = get_config(self.arch).reduced()
        layers, d, vocab = acfg.n_layers, acfg.d_model, acfg.vocab
        param_bytes = 2 * (vocab * d + layers * 12 * d * d)
        kv_width = acfg.n_kv_heads * acfg.hd

        prefill_ticks = -(-mean_prompt // self.prefill_chunk)
        if policy == "prefix":
            # the shared fraction's prefix prefills once, then maps in
            prefill_ticks *= max(0.1, 1 - self.shared_frac
                                 * self.prefix_len / mean_prompt)
        service = prefill_ticks + mean_new      # ticks per request

        pool = self.kv_pages * self.page_size if self.kv_pages \
            else self.batch * self.context
        footprint = -(-(mean_prompt + mean_new) // self.page_size) \
            * self.page_size
        if policy == "prefix":
            footprint -= self.shared_frac * self.prefix_len
        conc = max(1.0, min(self.batch, pool / max(1.0, footprint)))

        # queueing: a burst of B arrivals drains conc at a time, so the
        # k-th waits ~ (k / conc) services; priority resequences so
        # interactive requests only wait behind their own class
        burst = self.burst if self.arrival == "bursty" \
            else max(1.0, self.rate * service)
        wait_all = (burst / 2) / conc * service
        if policy == "priority":
            wait_int = (burst * self.interactive_frac / 2) / conc * service
            wait_bat = wait_all * 2 - wait_int
        else:
            wait_int = wait_bat = wait_all
        met_int = 1.0 if wait_int + service <= dl_int else \
            max(0.05, dl_int / (wait_int + service))
        met_bat = 1.0          # batch deadlines are slack by design
        met = (self.interactive_frac * met_int
               + (1 - self.interactive_frac) * met_bat)

        ticks = -(-self.requests // conc) * service
        weight_s = param_bytes / get_platform_spec().hbm_bw
        kv_s = kv_cache_stream_s(conc, layers,
                                 int(mean_prompt + mean_new), kv_width)
        tick_us = (weight_s + kv_s + 50e-6) * 1e6
        goodput = max(1.0, met * self.requests * mean_new)
        return ticks * tick_us / goodput

    # -- measured objective -------------------------------------------------

    def _model(self):
        """Build (and memoize) the reduced float32 model named by
        ``arch`` — deferred so registry-built instances stay cheap until
        a measure engine actually runs them."""

        if self.api is None or self.params is None:
            import jax
            from ..configs import get_config
            from ..models import build_model
            acfg = get_config(self.arch).reduced().replace(
                logits_dtype="float32")
            api = build_model(acfg)
            params = api.init(jax.random.PRNGKey(0))
            object.__setattr__(self, "api", api)
            object.__setattr__(self, "params", params)
        return self.api, self.params

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1) -> float:
        """Wall-clock microseconds per goodput token draining the seeded
        trace through a real paged server under this policy."""

        from .scheduler import make_scheduler
        from .workload import generate_trace
        api, params = self._model()
        policy = str(cfg["policy"])
        sched = make_scheduler(policy, age_limit=int(cfg["age_limit"]))
        trace = generate_trace(self.trace_config())
        stats: dict[str, float] = {}
        wall_us = timed_trace_drain(
            api, params, trace, batch=self.batch, context=self.context,
            prefill_chunk=self.prefill_chunk, paged=True,
            page_size=self.page_size, kv_pages=self.kv_pages or None,
            scheduler=sched, share_prefix=(policy == "prefix"),
            stats_out=stats, warmup=warmup, iters=iters)
        object.__setattr__(self, "last_stats", stats)
        return wall_us / max(1.0, stats.get("goodput_tokens", 0.0))

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        for k in ("prompt_len", "max_new", "policies", "age_limits"):
            fp[k] = list(fp[k])
        return {"tunable": self.name, "unit": "us_per_goodput_token", **fp}


def scheduler_tunable(api=None, *, context: int = 64, batch: int = 4,
                      requests: int = 12, page_size: int = 8,
                      prefill_chunk: int = 8, params=None,
                      **trace_kw) -> SchedulerTunable:
    """The policy tunable for this model + expected traffic — the one
    place the sizing wiring lives (library ``choose_scheduler`` and the
    ``launch/serve --tune-scheduler`` CLI both build through here).
    ``api``/``params`` are optional: omitted, ``measure`` builds the
    reduced model named by ``arch`` itself."""

    arch = trace_kw.pop("arch", api.cfg.name if api is not None
                        else "smollm-135m")
    return SchedulerTunable(arch=arch, context=context, batch=batch,
                            requests=requests, page_size=page_size,
                            prefill_chunk=prefill_chunk, api=api,
                            params=params, **trace_kw)


def choose_scheduler(api=None, *, cache="default", engine: str = "measure",
                     params=None, **tunable_kw):
    """Pick the serving policy via ``repro.tune``; returns
    ``((policy, age_limit), TuneResult)``.  Default engine is
    ``measure`` — policy differences are exactly what the modeled cost
    can only rank, not settle."""

    from ..tune import tune as _tune
    tb = scheduler_tunable(api, params=params, **tunable_kw)
    res = _tune(tb, engine=engine, cache=cache)
    return (str(res.best_config["policy"]),
            int(res.best_config["age_limit"])), res


__all__ = ["KV_CACHE_BYTES", "K_AND_V", "publish_drain_stats",
           "timed_server_drain",
           "timed_trace_drain", "kv_cache_stream_s",
           "DecodeBatchTunable", "PrefillChunkTunable", "KVPageTunable",
           "SchedulerTunable", "decode_batch_tunable",
           "prefill_chunk_tunable", "kv_page_tunable", "scheduler_tunable",
           "choose_batch", "choose_prefill_chunk", "choose_kv_page",
           "choose_scheduler"]
