"""Batched serving runtime: fixed-slot continuous batching with
chunked prefill.

``Server`` keeps ``batch`` decode slots alive; requests are admitted
into free slots, finished requests retire and free their slot.  Each
slot has a *phase*: **prefill** (stream tokens still unconsumed) or
**decode** (generating).  An engine tick advances prefilling slots by
one ``prefill_chunk``-token jitted ``prefill_step`` and decoding slots
by the one-token jitted ``decode_step`` — a long prompt costs
``ceil(len/chunk)`` ticks instead of ``len``, amortizing the per-tick
weight stream chunk-wide.  This is continuous batching in its
TPU-friendly form: static shapes (slot count, chunk size and cache
length fixed), per-slot state packed in the same pytree the dry-run's
serve_step lowers.

**This module is mechanism only.**  Every discretionary decision —
which queued request to admit, which slot to sacrifice when the page
pool runs dry, when to preempt a low-SLO slot for a waiting high-SLO
arrival — is delegated to a :class:`~repro.runtime.scheduler.Scheduler`
(``Server(scheduler="fcfs" | "priority" | "prefix" | instance)``).  The
serving tunables and their measurement harnesses live in
:mod:`repro.runtime.tunables` (re-exported here for compatibility).

Greedy sampling; per-slot absolute positions drive RoPE/ring caches, so
mixed-progress (and mixed-phase) slots coexist in one batch.  Both
steps gate their state writes per slot, so a prefill tick cannot
corrupt a decoding neighbour and vice versa.

``paged=True`` swaps the per-slot KV rings for a shared page pool
(:mod:`repro.runtime.kv`): admission no longer pre-reserves a full
``context`` per slot — a request is admitted when its pages fit the
currently free pool, pages are allocated on demand as prefill chunks
and decode steps advance, and a tick that runs out of pages
**preempts** a policy-chosen victim: its pages are released
(refcounts decremented — shared pages survive for their sharers) and
the request is requeued with prompt AND generated tokens intact, to be
re-prefilled through the chunked path on resume.  Chunked prefill is
tokenwise-exact, so a preempted request's final output is byte-identical
to an undisturbed run.  The page size is a tunable
(:class:`~repro.runtime.tunables.KVPageTunable`, ``serve.kv_page``).

``share_prefix=True`` (paged only) adds **copy-on-write prefix
sharing**: at placement the server looks for a live slot whose written
stream shares a page-aligned-or-longer prefix with the new request and
maps those pages into the new slot's table
(:meth:`~repro.runtime.kv.PagedKVAllocator.share`) — N requests with
one system prompt prefill it once.  The first write into a still-shared
page triggers a device-side page copy
(:meth:`~repro.runtime.kv.PagedKVAllocator.cow_pages`); only the
partial last shared page can ever need this, so sharing costs at most
one page copy per sharer.  Sharing is exact because attention masks
every key position ≥ the query's own validity: a sharer never attends
positions it has not itself written (or inherited below the shared
length), so a mid-prefill source writing beyond the shared length
cannot leak into a sharer's output.  SSM/hybrid and enc-dec state is
per-slot recurrence with no position index — sharing is refused there.

``speculate=`` adds a third per-tick slot population: decoding slots
with a draft from a :class:`~repro.runtime.speculate.Drafter` verify
``depth+1`` candidate tokens in ONE chunk forward
(:meth:`~repro.models.api.ModelAPI.verify_step` — the chunked-prefill
machinery as a verifier), accept the longest greedy-matching prefix
plus the verifier's bonus token, commit exactly the accepted tokens
with a second gated ``prefill_step``, and in paged mode ``rewind`` the
pages grabbed for rejected draft positions — so speculating,
prefilling, and plain-decoding neighbours coexist in one tick and the
page table stays byte-identical to a never-speculated drain.  Output
is token-for-token the baseline greedy stream; only the tick schedule
changes.  (The guarantee is exact up to floating-point argmax ties:
commit chunks reduce in a different order than one-token decodes, so
two logits that quantize to the same value — routine for random
reduced models at bfloat16 — can flip.  The KV cache follows the
params' dtype, so running float32 params restores real logit gaps and
with them stable parity.  Parity also requires comparing through the
same *compiled* steps: every Server for one api shares one set of
jitted steps — see the cache note in ``__init__`` — because XLA:CPU
codegen is not bit-reproducible across separate compiles.  And it
requires that no dispatch ever alias a persistent host buffer the
engine mutates between ticks — see :func:`_snapshot`.)  Depth ×
drafter is the ``serve.spec_depth`` tunable
(:class:`~repro.runtime.speculate.SpecDepthTunable`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import ModelAPI
from .kv import NO_PAGE, PagedKVAllocator, PagedKVSpec
from .scheduler import Scheduler, make_scheduler


def _snapshot(a: np.ndarray) -> jax.Array:
    """Device copy of a host array that is immune to later host writes.

    ``jnp.asarray`` on a small aligned numpy array is ZERO-COPY on the
    CPU backend: the jax Array aliases the numpy buffer.  Engine
    dispatches are asynchronous, so handing a step the live
    ``slot_pos`` / ``page_table`` buffer lets an in-flight executable
    observe increments the host makes a few lines later — e.g. the
    speculation commit (whose logits nothing syncs on) reading
    ``slot_pos`` after ``slot_pos[s] += e`` and scattering the
    committed tokens one chunk too far, leaving the true rows holding
    the slot's previous occupant's KV.  The window only opens when the
    runtime threads are preempted, so the corruption is rare and
    load-dependent.  Every dispatch that takes a persistent,
    host-mutated array must go through this copy; per-tick temporaries
    (``tokens``, ``lengths``, ``commit``, ``mask``) are never written
    after dispatch and may alias freely."""
    return jnp.asarray(np.array(a))


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = field(default_factory=list)
    done: bool = False
    slo: str = "interactive"    # SLO class (scheduler.PriorityScheduler)
    deadline: float | None = None   # absolute driver-clock deadline
    skips: int = 0              # admissions that bypassed this request
    preempted: int = 0          # times evicted mid-flight (progress kept)
    shared_prefix: int = 0      # tokens admitted via COW page sharing
    spec_proposed: int = 0      # draft tokens verified for this request
    spec_accepted: int = 0      # of those, accepted into the output


class Server:
    def __init__(self, api: ModelAPI, params, *, batch: int, context: int,
                 prefill_chunk: int = 32, paged: bool = False,
                 page_size: int = 16, kv_pages: int | None = None,
                 speculate: Any = None, spec_depth: int = 4,
                 scheduler: str | Scheduler | None = None,
                 share_prefix: bool = False, obs: Any = None):
        self.api = api
        self.params = params
        self.batch = batch
        self.context = context
        self.prefill_chunk = max(1, min(prefill_chunk, context))
        self.paged = paged
        self.scheduler = make_scheduler(scheduler)
        self.share_prefix = bool(share_prefix)
        if self.share_prefix and not paged:
            raise ValueError(
                "share_prefix=True needs paged=True: prefix sharing maps "
                "KV pages between slot page tables, contiguous rings have "
                "none")
        if self.share_prefix and api.cfg.is_encdec:
            raise ValueError(
                "share_prefix=True is unsupported for encoder-decoder "
                "models: per-slot cross-K/V is not positionally sharable")
        self.drafter = None
        self.spec_depth = max(1, min(spec_depth, context - 1))
        if speculate is not None:
            from .speculate import make_drafter
            self.drafter = make_drafter(speculate, api=api, params=params)
        self.alloc: PagedKVAllocator | None = None
        if paged:
            spec = PagedKVSpec.for_server(context=context,
                                          page_size=page_size,
                                          n_pages=kv_pages, batch=batch)
            self.alloc = PagedKVAllocator(spec, batch)
        # KV caches follow the params' dtype: a float32 model keeps a
        # float32 cache (greedy parity under speculation needs the real
        # logit gaps, not bfloat16-quantized ties), a bfloat16 model
        # keeps the compact default.
        pdt = next((leaf.dtype for leaf in jax.tree_util.tree_leaves(params)
                    if hasattr(leaf, "dtype")
                    and jnp.issubdtype(leaf.dtype, jnp.floating)), None)
        self.state = api.init_decode_state(
            batch, context, self.alloc.spec if paged else None, dtype=pdt)
        if self.share_prefix and any(
                "ssm" in entry or "enc_kv" in entry
                for entry in self.state["blocks"].values()):
            raise ValueError(
                "share_prefix=True needs pure-attention decode state: "
                "SSM/recurrent state at the share point is per-slot and "
                "has no position index to share through")
        self.slot_req: list[Request | None] = [None] * batch
        self.slot_pos = np.zeros(batch, np.int32)   # per-slot token count
        self._slot_dirty = np.zeros(batch, bool)    # retired -> stale state
        self._slot_seq = np.zeros(batch, np.int64)  # admission order
        self._seq = 0
        self.deferrals = 0          # paged: evictions forced by page OOM
        self.preemptions = 0        # policy-initiated evictions (SLO)
        self.peak_active = 0
        self.peak_used_pages = 0
        # per-drain counters behind stats()
        self.ticks = 0
        self.slot_ticks = 0         # sum of active slots over ticks
        self.tokens_generated = 0
        self.prefill_chunks = 0
        self.spec_ticks = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.share_hits = 0         # placements that mapped a prefix
        self.shared_tokens = 0      # prompt tokens admitted without prefill
        self.cow_copies = 0         # pages copied by write-triggered COW
        self.queue: list[Request] = []
        self.completed: list[Request] = []

        # jitted one-token step over the whole slot batch; positions is
        # the (batch,) per-slot position vector — decode_step threads it
        # through RoPE, the ring-cache slot, and the validity mask, so
        # mixed-progress slots coexist correctly in one batch.  ``active``
        # gates the state merge per slot: slots mid-prefill (or idle)
        # must not have a garbage token scattered into their KV ring or
        # folded into their SSM recurrence.
        def step(params, state, tokens, positions, active):
            logits, new_state = api.decode_step(params, state, tokens,
                                                positions)
            def sel(new, old):
                m = active.reshape((1, active.shape[0])
                                   + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)
            return logits, jax.tree.map(sel, new_state, state)

        # paged sibling: the KV pool is SHARED, so its writes are gated
        # per slot inside the paged attention (``active``); only the
        # per-slot leaves (SSM recurrence, cross K/V) are merge-gated
        # here — a blanket tree-map of ``sel`` would slice the pool on
        # its page dim as if it were a slot dim
        def step_paged(params, state, tokens, positions, active,
                       page_table):
            logits, new_state = api.decode_step(params, state, tokens,
                                                positions, page_table,
                                                active)
            def sel(new, old):
                m = active.reshape((1, active.shape[0])
                                   + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)
            blocks = {}
            for key, entry in new_state["blocks"].items():
                old = state["blocks"][key]
                blocks[key] = {
                    k2: (v2 if k2 == "kv"
                         else jax.tree.map(sel, v2, old[k2]))
                    for k2, v2 in entry.items()}
            return logits, {**new_state, "blocks": blocks}

        # jitted chunked-prefill step: per-slot chunk lengths gate every
        # state write inside the model (KV scatter, SSM scan, paged
        # pool), so one static-shape call serves any mix of
        # prefilling/other slots
        def pstep(params, state, tokens, positions, lengths):
            return api.prefill_step(params, state, tokens, positions,
                                    lengths)

        def pstep_paged(params, state, tokens, positions, lengths,
                        page_table):
            return api.prefill_step(params, state, tokens, positions,
                                    lengths, page_table)

        # speculation verifier: one chunk forward scoring all depth+1
        # candidate positions.  Its returned STATE is always discarded
        # (it holds rejected tokens' cache writes); the accepted prefix
        # is committed by a second, length-gated ``_prefill_step`` call
        # — the only uniform way to keep SSM/hybrid recurrence exact
        # under partial acceptance.
        def vstep(params, state, tokens, positions, lengths):
            return api.verify_step(params, state, tokens, positions,
                                   lengths)

        def vstep_paged(params, state, tokens, positions, lengths,
                        page_table):
            return api.verify_step(params, state, tokens, positions,
                                   lengths, page_table)

        # The jitted steps are built once per (api, paged) and SHARED by
        # every Server in the process (cached on the api object).  This
        # is a correctness requirement, not a compile-time nicety:
        # XLA:CPU native codegen is not bit-reproducible across separate
        # compiles of the same HLO — under CPU contention two jax.jit
        # calls on identical code can yield executables whose float
        # rounding differs enough to flip a near-tie argmax — so a
        # speculative server and its plain-decode baseline must argmax
        # through the SAME compiled step to be token-for-token
        # comparable.  jax.jit retraces per batch/context/dtype, so one
        # cache entry serves all server shapes.
        cache = getattr(api, "_server_steps", None)
        if cache is None:
            cache = {}
            api._server_steps = cache
        if paged not in cache:
            cache[paged] = (
                jax.jit(step_paged if paged else step),
                jax.jit(pstep_paged if paged else pstep),
                jax.jit(vstep_paged if paged else vstep))
        self._step, self._prefill_step, self._verify_step = cache[paged]

        # observability is strictly additive: every hook below is
        # guarded by `if self.obs is not None` and records host state
        # the engine already materialized, so obs=None drains are
        # untouched and obs-attached drains are output-identical.
        self.obs = obs
        if obs is not None:
            obs.attach(self)

    # -- API ----------------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int,
               frames: Any = None, *, slo: str = "interactive",
               deadline: float | None = None) -> Request:
        """``frames``: enc-dec audio frontend output (enc_seq, d_model)
        for this request; the encoder runs at admission and its cross-K/V
        fills the request's slot (serving-side prefill).  ``slo`` names
        the request's service class and ``deadline`` its absolute
        driver-clock deadline — both are policy inputs for the
        scheduler, the engine itself never reads them."""

        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt: a request needs at least one "
                             "prompt token")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        limit = self.context - max_new
        if len(prompt) > limit:
            raise ValueError(
                f"prompt of {len(prompt)} tokens + max_new={max_new} "
                f"exceeds context={self.context}; prompts may be at most "
                f"context - max_new = {limit} tokens")
        req = Request(rid=len(self.completed) + len(self.queue) +
                      sum(r is not None for r in self.slot_req),
                      prompt=prompt, max_new=max_new, slo=slo,
                      deadline=deadline)
        req._frames = frames  # type: ignore[attr-defined]
        self.queue.append(req)
        if self.obs is not None:
            self.obs.on_submit(self, req)
        return req

    # -- scheduler-facing queries (the policy contract) ---------------------

    def live_slots(self) -> list[int]:
        return [s for s in range(self.batch)
                if self.slot_req[s] is not None]

    def has_free_slot(self) -> bool:
        return any(r is None for r in self.slot_req)

    def slot_seq(self, slot: int) -> int:
        """Admission order of the slot's occupant (higher = younger)."""

        return int(self._slot_seq[slot])

    def slot_request(self, slot: int) -> Request | None:
        return self.slot_req[slot]

    def admit_fits(self, req: Request) -> bool:
        """Would ``req``'s pages fit right now?  Contiguous mode always
        fits (a free slot has a full ring reserved); paged mode needs
        the full stream's pages minus any full pages a live shared
        prefix would map in for free."""

        if not self.paged:
            return True
        total = len(req.prompt) + len(req.out)
        need = self.alloc.pages_needed(total)
        if self.share_prefix:
            _, shared = self._find_share_source(req)
            need -= shared // self.alloc.spec.page_size
        return (need <= self.alloc.spec.pages_per_slot
                and need <= self.alloc.free_pages)

    def shared_prefix_len(self, req: Request) -> int:
        """Tokens a placement of ``req`` would map in via COW sharing
        right now (0 when sharing is off or nothing matches)."""

        if not self.share_prefix:
            return 0
        _, shared = self._find_share_source(req)
        return shared

    def is_share_source(self, slot: int) -> bool:
        """Does ``slot`` map at least one refcount>1 page?"""

        if self.alloc is None:
            return False
        return any(int(self.alloc.refcount[p]) > 1
                   for p in self.alloc.slot_pages(slot))

    # -- admission / placement / preemption ---------------------------------

    def _admit(self) -> None:
        # proactive SLO preemption first: the policy may evict live
        # low-class slots so waiting high-class arrivals run this tick
        # (bounded by batch — each eviction frees a slot, and a policy
        # only volunteers strictly-lower-class victims, so this cannot
        # churn)
        for _ in range(self.batch):
            if not self.queue:
                break
            victim = self.scheduler.preempt_for(self)
            if victim is None:
                break
            self._preempt(victim)
            self.preemptions += 1
        for slot in range(self.batch):
            if self.slot_req[slot] is None and self.queue:
                idx = self.scheduler.pick(self)
                if idx is None:
                    return
                self._place(slot, self.queue.pop(idx))

    def _place(self, slot: int, req: Request) -> None:
        """Bind ``req`` to ``slot``: recurrent-state hygiene, the COW
        prefix share (paged + ``share_prefix``), and the prefill target
        — ``len(prompt) + len(out)``, so a preempted request re-prefills
        its generated tokens too and resumes exactly where it left
        off."""

        self.slot_req[slot] = req
        self._slot_seq[slot] = self._seq
        self._seq += 1
        if self._slot_dirty[slot]:
            self._reset_recurrent_state(slot)
            self._slot_dirty[slot] = False
        req._prefill_target = (len(req.prompt)  # type: ignore[attr-defined]
                               + len(req.out))
        start = 0
        if self.share_prefix:
            src, shared = self._find_share_source(req)
            if src is not None and self.alloc.share(src, slot, shared):
                start = shared
                req.shared_prefix = max(req.shared_prefix, shared)
                self.share_hits += 1
                self.shared_tokens += shared
        self.slot_pos[slot] = start
        req._cursor = start  # type: ignore[attr-defined]
        frames = getattr(req, "_frames", None)
        if self.api.cfg.is_encdec and frames is not None:
            kv = self.api.encode_cross_kv(
                # verify: waive(alias-dispatch) -- request audio frames
                # are request-immutable after submit; nothing writes
                # them between here and the dispatch
                self.params, jnp.asarray(frames)[None])
            xk, xv = self.state["xattn"]["k"], self.state["xattn"]["v"]
            self.state["xattn"]["k"] = xk.at[:, slot].set(
                kv["k"][:, 0].astype(xk.dtype))
            self.state["xattn"]["v"] = xv.at[:, slot].set(
                kv["v"][:, 0].astype(xv.dtype))
        if self.obs is not None:
            self.obs.on_admit(self, req, slot, start)

    def _backed_prefix(self, slot: int) -> int:
        """Tokens from position 0 whose pages ``slot`` still maps (SWA
        trim can have freed low pages — those positions cannot be
        shared from)."""

        n = 0
        for p in self.alloc.page_table[slot]:
            if p == NO_PAGE:
                break
            n += 1
        return n * self.alloc.spec.page_size

    def _find_share_source(self, req: Request) -> tuple[int | None, int]:
        """The live slot with the longest written, still-backed common
        prefix against ``req``'s stream, capped one short of the stream
        (at least one token must prefill to emit the next).  Sub-page
        matches return (None, 0): they would save no whole page and
        immediately pay a COW copy."""

        stream = req.prompt + req.out
        cap = len(stream) - 1
        best, best_len = None, 0
        for s in range(self.batch):
            src = self.slot_req[s]
            if src is None:
                continue
            written = (src.prompt + src.out)[:int(self.slot_pos[s])]
            m = min(len(written), cap, self._backed_prefix(s))
            n = 0
            while n < m and stream[n] == written[n]:
                n += 1
            if n > best_len:
                best, best_len = s, n
        if best_len < self.alloc.spec.page_size:
            return None, 0
        return best, best_len

    def _preempt(self, slot: int, reason: str = "slo-preempt") -> None:
        """Evict ``slot`` mid-flight: pages released (refcounts
        decremented — pages shared with other slots survive), request
        requeued at the FRONT with prompt and generated tokens intact.
        On re-admission the whole stream re-prefills through the
        chunked path, which emits the same next token the undisturbed
        slot would have — chunked prefill is tokenwise-exact — so
        preemption never changes a request's output.  ``reason``
        (policy preemption vs page-OOM defer) is observability-only."""

        req = self.slot_req[slot]
        req._cursor = 0  # type: ignore[attr-defined]
        req.preempted += 1
        self.queue.insert(0, req)
        if self.paged:
            self.alloc.release(slot)
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0
        self._slot_dirty[slot] = True
        if self.obs is not None:
            self.obs.on_preempt(self, req, slot, reason)

    def _evict_for(self, slot: int) -> int | None:
        """Page-OOM backpressure: the policy names a victim, the engine
        preempts it.  Returns the victim (None = nothing live)."""

        victim = self.scheduler.victim(self)
        if victim is not None:
            self._preempt(victim, reason="oom-defer")
            self.deferrals += 1
        return victim

    def _ensure_pages(self, slot: int, n_tokens: int) -> bool:
        """Back ``slot`` through ``n_tokens`` positions, evicting
        policy-chosen victims until the allocation fits; False when
        ``slot`` itself was evicted (skip it this tick)."""

        while not self.alloc.ensure(slot, n_tokens):
            victim = self._evict_for(slot)
            if victim is None or victim == slot:
                return False
        return True

    def _cow_range(self, slot: int, start: int,
                   end: int) -> list[tuple[int, int]]:
        """Break page sharing before ``slot`` writes positions
        ``[start, end)``; same eviction backpressure as
        :meth:`_ensure_pages` when the copy needs pages the free list
        lacks.  Returns the (src, dst) pairs for :meth:`_copy_pages`
        (empty when nothing was shared or ``slot`` itself was
        evicted)."""

        while True:
            pairs = self.alloc.cow_pages(slot, start, end)
            if pairs is not None:
                return pairs
            victim = self._evict_for(slot)
            if victim is None or victim == slot:
                return []

    def _copy_pages(self, pairs: list[tuple[int, int]]) -> None:
        """Device half of COW: clone src pages' K/V into the fresh dst
        pages across every block's pool (page dim = axis 1 of the
        stacked kv leaves).  The table already points at dst; positions
        beyond the writer's own validity hold the source's garbage,
        which the position mask keeps unattended until overwritten."""

        src = jnp.asarray(np.array([p[0] for p in pairs], np.int32))
        dst = jnp.asarray(np.array([p[1] for p in pairs], np.int32))
        blocks = dict(self.state["blocks"])
        for key, entry in blocks.items():
            if "kv" not in entry:
                continue
            entry = dict(entry)
            # verify: waive(pool-write) -- 'entry' is a fresh dict copy
            # two lines up; the shared pool only sees it via the
            # blocks[key] swap below, never a mutated shared leaf
            entry["kv"] = jax.tree.map(
                lambda a: a.at[:, dst].set(a[:, src]), entry["kv"])
            blocks[key] = entry
        self.state = {**self.state, "blocks": blocks}
        self.cow_copies += len(pairs)

    def _reset_recurrent_state(self, slot: int) -> None:
        """Zero a reused slot's SSM/conv state: position masking hides
        stale KV-ring entries, but the recurrence has no position — a
        new request must not start from the previous one's hidden
        state.  Only the recurrent leaves are touched (dense archs pay
        nothing; KV rings stay as they are)."""

        blocks = dict(self.state["blocks"])
        for key, entry in blocks.items():
            if "ssm" in entry:
                entry = dict(entry)
                entry["ssm"] = jax.tree.map(
                    lambda a: a.at[:, slot].set(0), entry["ssm"])
                blocks[key] = entry
        self.state = {**self.state, "blocks": blocks}

    def _phase(self, slot: int) -> str:
        req = self.slot_req[slot]
        cur = req._cursor  # type: ignore[attr-defined]
        return "prefill" if cur < req._prefill_target else "decode"

    def _retire_if_done(self, slot: int) -> None:
        req = self.slot_req[slot]
        if len(req.out) >= req.max_new or \
                self.slot_pos[slot] >= self.context - 1:
            req.done = True
            self.completed.append(req)
            self.slot_req[slot] = None
            self._slot_dirty[slot] = True
            if self.paged:
                self.alloc.release(slot)
            if self.obs is not None:
                self.obs.on_retire(self, req, slot)

    def kv_stats(self) -> dict[str, float]:
        """Cache occupancy snapshot: live tokens vs reserved capacity
        (plus allocator fragmentation/sharing and eviction counters in
        paged mode) — the quantity ``bench_paged`` tables."""

        live = sum(int(self.slot_pos[s]) for s in range(self.batch)
                   if self.slot_req[s] is not None)
        if not self.paged:
            cap = self.batch * self.context
            return {"live_tokens": float(live), "capacity_tokens": float(cap),
                    "occupancy": live / cap if cap else 0.0,
                    "deferrals": 0.0, "peak_active": float(self.peak_active)}
        st = self.alloc.stats(live_tokens=live)
        st["capacity_tokens"] = float(self.alloc.spec.pool_tokens)
        st["deferrals"] = float(self.deferrals)
        st["peak_active"] = float(self.peak_active)
        st["peak_used_pages"] = float(self.peak_used_pages)
        return st

    def stats(self) -> dict[str, float]:
        """Per-drain engine-counter snapshot: how many ticks the drain
        took, what they produced, how speculation performed, and what
        the policy did (evictions, COW sharing) — surfaced by
        ``timed_server_drain(stats_out=...)`` /
        ``timed_trace_drain(stats_out=...)`` so tunable ``measure()``
        provenance and the serving benchmarks record real counters next
        to wall-clock."""

        g = self.tokens_generated
        return {
            "ticks": float(self.ticks),
            "tokens_generated": float(g),
            "ticks_per_token": (self.ticks / g) if g else 0.0,
            "mean_active": (self.slot_ticks / self.ticks
                            if self.ticks else 0.0),
            "prefill_chunks": float(self.prefill_chunks),
            "deferrals": float(self.deferrals),
            "preemptions": float(self.preemptions),
            "peak_active": float(self.peak_active),
            "share_hits": float(self.share_hits),
            "shared_tokens": float(self.shared_tokens),
            "cow_copies": float(self.cow_copies),
            "spec_ticks": float(self.spec_ticks),
            "spec_proposed": float(self.spec_proposed),
            "spec_accepted": float(self.spec_accepted),
            "accept_rate": (self.spec_accepted / self.spec_proposed
                            if self.spec_proposed else 0.0),
        }

    def _propose_drafts(self) -> dict[int, list[int]]:
        """Host-side draft proposals for this tick's decoding slots.
        Depth is capped so emission can never overshoot ``max_new`` or
        the context (cap ``d``: up to ``d+1`` tokens emitted, and the
        verify chunk writes positions ``pos..pos+d``), making the spec
        path retire at exactly the baseline stopping point."""

        drafts: dict[int, list[int]] = {}
        if self.drafter is None:
            return drafts
        for s in range(self.batch):
            req = self.slot_req[s]
            if req is None or self._phase(s) != "decode":
                continue
            pos = int(self.slot_pos[s])
            cap = min(self.spec_depth,
                      req.max_new - len(req.out) - 1,
                      self.context - 2 - pos)
            if cap < 1:
                continue
            d = self.drafter.propose(req.prompt + req.out, cap)[:cap]
            if d:
                drafts[s] = [int(t) for t in d]
        return drafts

    def tick(self) -> int:
        """One engine iteration; returns number of active slots.

        Decoding slots advance one token through ``decode_step``;
        prefilling slots advance up to ``prefill_chunk`` stream tokens
        through ``prefill_step`` — the chunk that consumes a stream's
        last token also yields the request's next generated token,
        exactly as the tokenwise tick that fed that token would have.

        Paged mode first backs every slot's positions for this tick
        (admission order) and breaks COW sharing for every position
        about to be written; a slot the allocator cannot cover — even
        after evicting every policy-offered victim — is itself evicted
        and sits the tick out."""

        self._admit()
        drafts = self._propose_drafts()
        if self.paged:
            cow_pairs: list[tuple[int, int]] = []
            order = sorted((s for s in range(self.batch)
                            if self.slot_req[s] is not None),
                           key=lambda s: self._slot_seq[s])
            for s in order:
                req = self.slot_req[s]
                if req is None:          # evicted as an earlier victim
                    continue
                pos = int(self.slot_pos[s])
                if self._phase(s) == "decode":
                    end = pos + 1
                    if s in drafts:
                        # opportunistic draft backing: shrink the draft
                        # to whatever the free list covers WITHOUT
                        # evicting a neighbour — speculation must
                        # never evict a slot a plain decode wouldn't
                        dr = drafts.pop(s)
                        for dd in range(len(dr), 0, -1):
                            if self.alloc.ensure(s, pos + dd + 1):
                                drafts[s] = dr[:dd]
                                end = pos + dd + 1
                                break
                    if s not in drafts and \
                            not self._ensure_pages(s, pos + 1):
                        continue
                else:
                    cur = req._cursor  # type: ignore[attr-defined]
                    n = min(self.prefill_chunk, req._prefill_target - cur)
                    end = pos + n
                    if not self._ensure_pages(s, end):
                        continue
                if self.share_prefix and self.slot_req[s] is req:
                    cow_pairs.extend(self._cow_range(s, pos, end))
            if cow_pairs:
                ob = self.obs
                t0 = (ob.phase_begin("cow_copy", self.ticks)
                      if ob is not None else 0.0)
                self._copy_pages(cow_pairs)
                if ob is not None:
                    ob.phase_end("cow_copy", self.ticks, t0,
                                 sync=self.state, pages=len(cow_pairs))
            self.peak_used_pages = max(self.peak_used_pages,
                                       self.alloc.used_pages)
        active = [s for s in range(self.batch) if self.slot_req[s] is not None]
        self.peak_active = max(self.peak_active, len(active))
        if not active:
            return 0
        self.ticks += 1
        self.slot_ticks += len(active)
        ob = self.obs
        if ob is not None:
            ob.on_tick_begin(self, self.ticks)
        decode = [s for s in active if self._phase(s) == "decode"]
        spec = [s for s in decode if s in drafts]
        decode = [s for s in decode if s not in drafts]
        prefill = [s for s in active if self._phase(s) == "prefill"]
        page_table = (_snapshot(self.alloc.page_table)
                      if self.paged else None)

        if decode:
            if ob is not None:
                t0 = ob.phase_begin("decode", self.ticks)
            tokens = np.zeros((self.batch, 1), np.int32)
            mask = np.zeros(self.batch, bool)
            for s in decode:
                tokens[s, 0] = self.slot_req[s].out[-1]
                mask[s] = True
            extra = (page_table,) if self.paged else ()
            logits, self.state = self._step(self.params, self.state,
                                            jnp.asarray(tokens),
                                            _snapshot(self.slot_pos),
                                            jnp.asarray(mask), *extra)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s in decode:
                req = self.slot_req[s]
                req._cursor += 1  # type: ignore[attr-defined]
                self.slot_pos[s] += 1
                req.out.append(int(nxt[s]))
                self.tokens_generated += 1
                self._retire_if_done(s)
            if ob is not None:
                ob.phase_end("decode", self.ticks, t0, sync=self.state,
                             slots=len(decode))

        if spec:
            if ob is not None:
                t0 = ob.phase_begin("speculate", self.ticks)
            # speculation: verify the chunk [pending token, drafts...]
            # at absolute positions pos..pos+d in one forward, accept
            # the longest prefix of drafts matching the verifier's own
            # greedy picks (plus its bonus token), then COMMIT exactly
            # the accepted tokens with a length-gated prefill_step (the
            # verify state, rejected writes included, is discarded)
            D1 = self.spec_depth + 1
            tokens = np.zeros((self.batch, D1), np.int32)
            lengths = np.zeros(self.batch, np.int32)
            for s in spec:
                dr = drafts[s]
                tokens[s, 0] = self.slot_req[s].out[-1]
                tokens[s, 1:1 + len(dr)] = dr
                lengths[s] = len(dr) + 1
            extra = (page_table,) if self.paged else ()
            logits, _ = self._verify_step(
                self.params, self.state, jnp.asarray(tokens),
                _snapshot(self.slot_pos), jnp.asarray(lengths), *extra)
            greedy = np.asarray(jnp.argmax(logits, axis=-1))  # (B, D1)
            commit = np.zeros(self.batch, np.int32)
            emitted: dict[int, list[int]] = {}
            for s in spec:
                dr = drafts[s]
                k = 0
                while k < len(dr) and int(greedy[s, k]) == dr[k]:
                    k += 1
                emitted[s] = dr[:k] + [int(greedy[s, k])]
                commit[s] = k + 1
                req = self.slot_req[s]
                req.spec_proposed += len(dr)
                req.spec_accepted += k
                self.spec_proposed += len(dr)
                self.spec_accepted += k
            _, self.state = self._prefill_step(
                self.params, self.state, jnp.asarray(tokens),
                _snapshot(self.slot_pos), jnp.asarray(commit), *extra)
            self.spec_ticks += 1
            for s in spec:
                req = self.slot_req[s]
                e = int(commit[s])
                req._cursor += e  # type: ignore[attr-defined]
                self.slot_pos[s] += e
                req.out.extend(emitted[s])
                self.tokens_generated += e
                if self.paged:
                    # hand back pages grabbed for rejected positions;
                    # the table must match a never-speculated drain
                    self.alloc.rewind(s, int(self.slot_pos[s]))
                self._retire_if_done(s)
            if ob is not None:
                ob.phase_end("speculate", self.ticks, t0,
                             sync=self.state, slots=len(spec))

        if prefill:
            if ob is not None:
                t0 = ob.phase_begin("prefill", self.ticks)
            T = self.prefill_chunk
            tokens = np.zeros((self.batch, T), np.int32)
            lengths = np.zeros(self.batch, np.int32)
            for s in prefill:
                req = self.slot_req[s]
                cur = req._cursor  # type: ignore[attr-defined]
                # the stream includes generated tokens: a preempted
                # request re-prefills prompt + out and resumes exactly
                stream = req.prompt + req.out
                n = min(T, req._prefill_target - cur)
                tokens[s, :n] = stream[cur:cur + n]
                lengths[s] = n
            extra = (page_table,) if self.paged else ()
            logits, self.state = self._prefill_step(
                self.params, self.state, jnp.asarray(tokens),
                _snapshot(self.slot_pos), jnp.asarray(lengths), *extra)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            self.prefill_chunks += len(prefill)
            for s in prefill:
                req = self.slot_req[s]
                n = int(lengths[s])
                req._cursor += n  # type: ignore[attr-defined]
                self.slot_pos[s] += n
                if req._cursor >= req._prefill_target:
                    req.out.append(int(nxt[s]))
                    self.tokens_generated += 1
                    self._retire_if_done(s)
            if ob is not None:
                ob.phase_end("prefill", self.ticks, t0, sync=self.state,
                             slots=len(prefill))

        # sliding-window reclamation: pages whose positions all fell out
        # of the window are never attended again — hand them back.  The
        # next tick's earliest attended position is slot_pos - window + 1.
        if self.paged and self.api.cfg.window is not None:
            w = self.api.cfg.window
            for s in range(self.batch):
                if self.slot_req[s] is not None:
                    self.alloc.trim(s, max(0, int(self.slot_pos[s]) - w + 1))
        if ob is not None:
            ob.on_tick_end(self, self.ticks, n_decode=len(decode),
                           n_spec=len(spec), n_prefill=len(prefill))
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if self.tick() == 0 and not self.queue:
                return
        raise RuntimeError("serving did not drain")


# ---------------------------------------------------------------------------
# compatibility re-exports: the serving tunables and their harnesses
# moved to repro.runtime.tunables (and the policies to
# repro.runtime.scheduler) when the scheduler subsystem landed; every
# pre-move import path keeps working through these.
# ---------------------------------------------------------------------------

from .scheduler import SCHEDULER_KINDS  # noqa: E402,F401
from .tunables import (K_AND_V, KV_CACHE_BYTES,  # noqa: E402,F401
                       DecodeBatchTunable, KVPageTunable,
                       PrefillChunkTunable, SchedulerTunable,
                       _require_model, choose_batch, choose_kv_page,
                       choose_prefill_chunk, choose_scheduler,
                       decode_batch_tunable, kv_cache_stream_s,
                       kv_page_tunable, prefill_chunk_tunable,
                       scheduler_tunable, timed_server_drain,
                       timed_trace_drain)

__all__ = ["Server", "Request", "Scheduler", "make_scheduler",
           "SCHEDULER_KINDS",
           "DecodeBatchTunable", "PrefillChunkTunable", "KVPageTunable",
           "SchedulerTunable", "decode_batch_tunable",
           "prefill_chunk_tunable", "kv_page_tunable", "scheduler_tunable",
           "choose_batch", "choose_prefill_chunk", "choose_kv_page",
           "choose_scheduler", "kv_cache_stream_s", "timed_server_drain",
           "timed_trace_drain"]
