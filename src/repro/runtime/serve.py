"""Batched serving runtime: fixed-slot continuous batching with
chunked prefill.

``Server`` keeps ``batch`` decode slots alive; requests are admitted
into free slots, finished requests retire and free their slot.  Each
slot has a *phase*: **prefill** (prompt tokens still unconsumed) or
**decode** (generating).  An engine tick advances prefilling slots by
one ``prefill_chunk``-token jitted ``prefill_step`` and decoding slots
by the one-token jitted ``decode_step`` — a long prompt costs
``ceil(len/chunk)`` ticks instead of ``len``, amortizing the per-tick
weight stream chunk-wide.  This is continuous batching in its
TPU-friendly form: static shapes (slot count, chunk size and cache
length fixed), per-slot state packed in the same pytree the dry-run's
serve_step lowers.

Greedy sampling; per-slot absolute positions drive RoPE/ring caches, so
mixed-progress (and mixed-phase) slots coexist in one batch.  Both
steps gate their state writes per slot, so a prefill tick cannot
corrupt a decoding neighbour and vice versa.

``paged=True`` swaps the per-slot KV rings for a shared page pool
(:mod:`repro.runtime.kv`): admission no longer pre-reserves a full
``context`` per slot — a request is admitted when its prompt fits the
*currently free pages*, pages are allocated on demand as prefill chunks
and decode steps advance, and a tick that runs out of pages defers the
youngest slot (its pages are released and the request requeued for a
fresh start).  Mixed short/long traffic then shares one memory budget
instead of stranding ring capacity.  The page size is a tunable
(:class:`KVPageTunable`, ``serve.kv_page`` in the plan registry).

``speculate=`` adds a third per-tick slot population: decoding slots
with a draft from a :class:`~repro.runtime.speculate.Drafter` verify
``depth+1`` candidate tokens in ONE chunk forward
(:meth:`~repro.models.api.ModelAPI.verify_step` — the chunked-prefill
machinery as a verifier), accept the longest greedy-matching prefix
plus the verifier's bonus token, commit exactly the accepted tokens
with a second gated ``prefill_step``, and in paged mode ``rewind`` the
pages grabbed for rejected draft positions — so speculating,
prefilling, and plain-decoding neighbours coexist in one tick and the
page table stays byte-identical to a never-speculated drain.  Output
is token-for-token the baseline greedy stream; only the tick schedule
changes.  (The guarantee is exact up to floating-point argmax ties:
commit chunks reduce in a different order than one-token decodes, so
two logits that quantize to the same value — routine for random
reduced models at bfloat16 — can flip.  The KV cache follows the
params' dtype, so running float32 params restores real logit gaps and
with them stable parity.  Parity also requires comparing through the
same *compiled* steps: every Server for one api shares one set of
jitted steps — see the cache note in ``__init__`` — because XLA:CPU
codegen is not bit-reproducible across separate compiles.  And it
requires that no dispatch ever alias a persistent host buffer the
engine mutates between ticks — see :func:`_snapshot`.)  Depth ×
drafter is the ``serve.spec_depth`` tunable
(:class:`~repro.runtime.speculate.SpecDepthTunable`).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, ClassVar, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..core.search_space import Param, SearchSpace
from ..core.tpu_machine import HBM_BW, PEAK_FLOPS
from ..models.api import ModelAPI
from .kv import PagedKVAllocator, PagedKVSpec


def _snapshot(a: np.ndarray) -> jax.Array:
    """Device copy of a host array that is immune to later host writes.

    ``jnp.asarray`` on a small aligned numpy array is ZERO-COPY on the
    CPU backend: the jax Array aliases the numpy buffer.  Engine
    dispatches are asynchronous, so handing a step the live
    ``slot_pos`` / ``page_table`` buffer lets an in-flight executable
    observe increments the host makes a few lines later — e.g. the
    speculation commit (whose logits nothing syncs on) reading
    ``slot_pos`` after ``slot_pos[s] += e`` and scattering the
    committed tokens one chunk too far, leaving the true rows holding
    the slot's previous occupant's KV.  The window only opens when the
    runtime threads are preempted, so the corruption is rare and
    load-dependent.  Every dispatch that takes a persistent,
    host-mutated array must go through this copy; per-tick temporaries
    (``tokens``, ``lengths``, ``commit``, ``mask``) are never written
    after dispatch and may alias freely."""
    return jnp.asarray(np.array(a))


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = field(default_factory=list)
    done: bool = False
    spec_proposed: int = 0      # draft tokens verified for this request
    spec_accepted: int = 0      # of those, accepted into the output


class Server:
    def __init__(self, api: ModelAPI, params, *, batch: int, context: int,
                 prefill_chunk: int = 32, paged: bool = False,
                 page_size: int = 16, kv_pages: int | None = None,
                 speculate: Any = None, spec_depth: int = 4):
        self.api = api
        self.params = params
        self.batch = batch
        self.context = context
        self.prefill_chunk = max(1, min(prefill_chunk, context))
        self.paged = paged
        self.drafter = None
        self.spec_depth = max(1, min(spec_depth, context - 1))
        if speculate is not None:
            from .speculate import make_drafter
            self.drafter = make_drafter(speculate, api=api, params=params)
        self.alloc: PagedKVAllocator | None = None
        if paged:
            spec = PagedKVSpec.for_server(context=context,
                                          page_size=page_size,
                                          n_pages=kv_pages, batch=batch)
            self.alloc = PagedKVAllocator(spec, batch)
        # KV caches follow the params' dtype: a float32 model keeps a
        # float32 cache (greedy parity under speculation needs the real
        # logit gaps, not bfloat16-quantized ties), a bfloat16 model
        # keeps the compact default.
        pdt = next((leaf.dtype for leaf in jax.tree_util.tree_leaves(params)
                    if hasattr(leaf, "dtype")
                    and jnp.issubdtype(leaf.dtype, jnp.floating)), None)
        self.state = api.init_decode_state(
            batch, context, self.alloc.spec if paged else None, dtype=pdt)
        self.slot_req: list[Request | None] = [None] * batch
        self.slot_pos = np.zeros(batch, np.int32)   # per-slot token count
        self._slot_dirty = np.zeros(batch, bool)    # retired -> stale state
        self._slot_seq = np.zeros(batch, np.int64)  # admission order
        self._seq = 0
        self.deferrals = 0          # paged: restarts forced by page OOM
        self.peak_active = 0
        self.peak_used_pages = 0
        # per-drain counters behind stats()
        self.ticks = 0
        self.tokens_generated = 0
        self.prefill_chunks = 0
        self.spec_ticks = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.queue: list[Request] = []
        self.completed: list[Request] = []

        # jitted one-token step over the whole slot batch; positions is
        # the (batch,) per-slot position vector — decode_step threads it
        # through RoPE, the ring-cache slot, and the validity mask, so
        # mixed-progress slots coexist correctly in one batch.  ``active``
        # gates the state merge per slot: slots mid-prefill (or idle)
        # must not have a garbage token scattered into their KV ring or
        # folded into their SSM recurrence.
        def step(params, state, tokens, positions, active):
            logits, new_state = api.decode_step(params, state, tokens,
                                                positions)
            def sel(new, old):
                m = active.reshape((1, active.shape[0])
                                   + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)
            return logits, jax.tree.map(sel, new_state, state)

        # paged sibling: the KV pool is SHARED, so its writes are gated
        # per slot inside the paged attention (``active``); only the
        # per-slot leaves (SSM recurrence, cross K/V) are merge-gated
        # here — a blanket tree-map of ``sel`` would slice the pool on
        # its page dim as if it were a slot dim
        def step_paged(params, state, tokens, positions, active,
                       page_table):
            logits, new_state = api.decode_step(params, state, tokens,
                                                positions, page_table,
                                                active)
            def sel(new, old):
                m = active.reshape((1, active.shape[0])
                                   + (1,) * (new.ndim - 2))
                return jnp.where(m, new, old)
            blocks = {}
            for key, entry in new_state["blocks"].items():
                old = state["blocks"][key]
                blocks[key] = {
                    k2: (v2 if k2 == "kv"
                         else jax.tree.map(sel, v2, old[k2]))
                    for k2, v2 in entry.items()}
            return logits, {**new_state, "blocks": blocks}

        # jitted chunked-prefill step: per-slot chunk lengths gate every
        # state write inside the model (KV scatter, SSM scan, paged
        # pool), so one static-shape call serves any mix of
        # prefilling/other slots
        def pstep(params, state, tokens, positions, lengths):
            return api.prefill_step(params, state, tokens, positions,
                                    lengths)

        def pstep_paged(params, state, tokens, positions, lengths,
                        page_table):
            return api.prefill_step(params, state, tokens, positions,
                                    lengths, page_table)

        # speculation verifier: one chunk forward scoring all depth+1
        # candidate positions.  Its returned STATE is always discarded
        # (it holds rejected tokens' cache writes); the accepted prefix
        # is committed by a second, length-gated ``_prefill_step`` call
        # — the only uniform way to keep SSM/hybrid recurrence exact
        # under partial acceptance.
        def vstep(params, state, tokens, positions, lengths):
            return api.verify_step(params, state, tokens, positions,
                                   lengths)

        def vstep_paged(params, state, tokens, positions, lengths,
                        page_table):
            return api.verify_step(params, state, tokens, positions,
                                   lengths, page_table)

        # The jitted steps are built once per (api, paged) and SHARED by
        # every Server in the process (cached on the api object).  This
        # is a correctness requirement, not a compile-time nicety:
        # XLA:CPU native codegen is not bit-reproducible across separate
        # compiles of the same HLO — under CPU contention two jax.jit
        # calls on identical code can yield executables whose float
        # rounding differs enough to flip a near-tie argmax — so a
        # speculative server and its plain-decode baseline must argmax
        # through the SAME compiled step to be token-for-token
        # comparable.  jax.jit retraces per batch/context/dtype, so one
        # cache entry serves all server shapes.
        cache = getattr(api, "_server_steps", None)
        if cache is None:
            cache = {}
            api._server_steps = cache
        if paged not in cache:
            cache[paged] = (
                jax.jit(step_paged if paged else step),
                jax.jit(pstep_paged if paged else pstep),
                jax.jit(vstep_paged if paged else vstep))
        self._step, self._prefill_step, self._verify_step = cache[paged]

    # -- API ----------------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int,
               frames: Any = None) -> Request:
        """``frames``: enc-dec audio frontend output (enc_seq, d_model)
        for this request; the encoder runs at admission and its cross-K/V
        fills the request's slot (serving-side prefill)."""

        prompt = list(prompt)
        if not prompt:
            raise ValueError("empty prompt: a request needs at least one "
                             "prompt token")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        limit = self.context - max_new
        if len(prompt) > limit:
            raise ValueError(
                f"prompt of {len(prompt)} tokens + max_new={max_new} "
                f"exceeds context={self.context}; prompts may be at most "
                f"context - max_new = {limit} tokens")
        req = Request(rid=len(self.completed) + len(self.queue) +
                      sum(r is not None for r in self.slot_req),
                      prompt=prompt, max_new=max_new)
        req._frames = frames  # type: ignore[attr-defined]
        self.queue.append(req)
        return req

    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.slot_req[slot] is None and self.queue:
                req = self._pick_next()
                if req is None:
                    return
                self.slot_req[slot] = req
                self.slot_pos[slot] = 0
                self._slot_seq[slot] = self._seq
                self._seq += 1
                req._cursor = 0  # type: ignore[attr-defined]
                if self._slot_dirty[slot]:
                    self._reset_recurrent_state(slot)
                    self._slot_dirty[slot] = False
                frames = getattr(req, "_frames", None)
                if self.api.cfg.is_encdec and frames is not None:
                    kv = self.api.encode_cross_kv(
                        self.params, jnp.asarray(frames)[None])
                    xk, xv = self.state["xattn"]["k"], self.state["xattn"]["v"]
                    self.state["xattn"]["k"] = xk.at[:, slot].set(
                        kv["k"][:, 0].astype(xk.dtype))
                    self.state["xattn"]["v"] = xv.at[:, slot].set(
                        kv["v"][:, 0].astype(xv.dtype))

    def _pick_next(self) -> Request | None:
        """Next request to admit.  Contiguous mode: strict FIFO (a free
        slot always has a full ring reserved).  Paged mode: first-fit
        over the queue — admit the oldest request whose PROMPT fits the
        currently free pages (decode growth is alloc-on-demand, covered
        by deferral), so a long prompt waiting for pages does not block
        shorter traffic behind it."""

        if not self.paged:
            return self.queue.pop(0)
        for i, req in enumerate(self.queue):
            if self.alloc.fits(len(req.prompt)):
                return self.queue.pop(i)
        return None

    def _defer_youngest(self) -> int | None:
        """Page-OOM backpressure: evict the YOUNGEST active slot — the
        one with the least sunk prefill/decode work — release its pages
        and requeue its request (front of queue) for a fresh start.
        The oldest slot is never deferred before all younger ones, so
        it always progresses and the server cannot livelock."""

        live = [s for s in range(self.batch)
                if self.slot_req[s] is not None]
        if not live:
            return None
        victim = max(live, key=lambda s: self._slot_seq[s])
        req = self.slot_req[victim]
        req._cursor = 0  # type: ignore[attr-defined]
        req.out.clear()
        self.queue.insert(0, req)
        self.alloc.release(victim)
        self.slot_req[victim] = None
        self.slot_pos[victim] = 0
        self._slot_dirty[victim] = True
        self.deferrals += 1
        return victim

    def _ensure_pages(self, slot: int, n_tokens: int) -> bool:
        """Back ``slot`` through ``n_tokens`` positions, deferring
        youngest slots until the allocation fits; False when ``slot``
        itself was deferred (skip it this tick)."""

        while not self.alloc.ensure(slot, n_tokens):
            victim = self._defer_youngest()
            if victim is None or victim == slot:
                return False
        return True

    def _reset_recurrent_state(self, slot: int) -> None:
        """Zero a reused slot's SSM/conv state: position masking hides
        stale KV-ring entries, but the recurrence has no position — a
        new request must not start from the previous one's hidden
        state.  Only the recurrent leaves are touched (dense archs pay
        nothing; KV rings stay as they are)."""

        blocks = dict(self.state["blocks"])
        for key, entry in blocks.items():
            if "ssm" in entry:
                entry = dict(entry)
                entry["ssm"] = jax.tree.map(
                    lambda a: a.at[:, slot].set(0), entry["ssm"])
                blocks[key] = entry
        self.state = {**self.state, "blocks": blocks}

    def _phase(self, slot: int) -> str:
        req = self.slot_req[slot]
        cur = req._cursor  # type: ignore[attr-defined]
        return "prefill" if cur < len(req.prompt) else "decode"

    def _retire_if_done(self, slot: int) -> None:
        req = self.slot_req[slot]
        if len(req.out) >= req.max_new or \
                self.slot_pos[slot] >= self.context - 1:
            req.done = True
            self.completed.append(req)
            self.slot_req[slot] = None
            self._slot_dirty[slot] = True
            if self.paged:
                self.alloc.release(slot)

    def kv_stats(self) -> dict[str, float]:
        """Cache occupancy snapshot: live tokens vs reserved capacity
        (plus allocator fragmentation and deferral counters in paged
        mode) — the quantity ``bench_paged`` tables."""

        live = sum(int(self.slot_pos[s]) for s in range(self.batch)
                   if self.slot_req[s] is not None)
        if not self.paged:
            cap = self.batch * self.context
            return {"live_tokens": float(live), "capacity_tokens": float(cap),
                    "occupancy": live / cap if cap else 0.0,
                    "deferrals": 0.0, "peak_active": float(self.peak_active)}
        st = self.alloc.stats(live_tokens=live)
        st["capacity_tokens"] = float(self.alloc.spec.pool_tokens)
        st["deferrals"] = float(self.deferrals)
        st["peak_active"] = float(self.peak_active)
        st["peak_used_pages"] = float(self.peak_used_pages)
        return st

    def stats(self) -> dict[str, float]:
        """Per-drain engine-counter snapshot: how many ticks the drain
        took, what they produced, and how speculation performed —
        surfaced by ``timed_server_drain(stats_out=...)`` so tunable
        ``measure()`` provenance and the serving benchmarks can record
        real accept rates next to wall-clock."""

        g = self.tokens_generated
        return {
            "ticks": float(self.ticks),
            "tokens_generated": float(g),
            "ticks_per_token": (self.ticks / g) if g else 0.0,
            "prefill_chunks": float(self.prefill_chunks),
            "deferrals": float(self.deferrals),
            "peak_active": float(self.peak_active),
            "spec_ticks": float(self.spec_ticks),
            "spec_proposed": float(self.spec_proposed),
            "spec_accepted": float(self.spec_accepted),
            "accept_rate": (self.spec_accepted / self.spec_proposed
                            if self.spec_proposed else 0.0),
        }

    def _propose_drafts(self) -> dict[int, list[int]]:
        """Host-side draft proposals for this tick's decoding slots.
        Depth is capped so emission can never overshoot ``max_new`` or
        the context (cap ``d``: up to ``d+1`` tokens emitted, and the
        verify chunk writes positions ``pos..pos+d``), making the spec
        path retire at exactly the baseline stopping point."""

        drafts: dict[int, list[int]] = {}
        if self.drafter is None:
            return drafts
        for s in range(self.batch):
            req = self.slot_req[s]
            if req is None or self._phase(s) != "decode":
                continue
            pos = int(self.slot_pos[s])
            cap = min(self.spec_depth,
                      req.max_new - len(req.out) - 1,
                      self.context - 2 - pos)
            if cap < 1:
                continue
            d = self.drafter.propose(req.prompt + req.out, cap)[:cap]
            if d:
                drafts[s] = [int(t) for t in d]
        return drafts

    def tick(self) -> int:
        """One engine iteration; returns number of active slots.

        Decoding slots advance one token through ``decode_step``;
        prefilling slots advance up to ``prefill_chunk`` prompt tokens
        through ``prefill_step`` — the chunk that consumes a prompt's
        last token also yields the request's first generated token,
        exactly as the tokenwise tick that fed the last prompt token
        did.

        Paged mode first backs every slot's positions for this tick
        (oldest slot first); a slot the allocator cannot cover — even
        after deferring every younger one — is itself deferred and sits
        the tick out."""

        self._admit()
        drafts = self._propose_drafts()
        if self.paged:
            order = sorted((s for s in range(self.batch)
                            if self.slot_req[s] is not None),
                           key=lambda s: self._slot_seq[s])
            for s in order:
                req = self.slot_req[s]
                if req is None:          # deferred as a younger victim
                    continue
                if self._phase(s) == "decode":
                    pos = int(self.slot_pos[s])
                    if s in drafts:
                        # opportunistic draft backing: shrink the draft
                        # to whatever the free list covers WITHOUT
                        # deferring a neighbour — speculation must
                        # never evict a slot a plain decode wouldn't
                        dr = drafts.pop(s)
                        for dd in range(len(dr), 0, -1):
                            if self.alloc.ensure(s, pos + dd + 1):
                                drafts[s] = dr[:dd]
                                break
                        if s in drafts:
                            continue
                    need = pos + 1
                else:
                    cur = req._cursor  # type: ignore[attr-defined]
                    n = min(self.prefill_chunk, len(req.prompt) - cur)
                    need = int(self.slot_pos[s]) + n
                self._ensure_pages(s, need)
            self.peak_used_pages = max(self.peak_used_pages,
                                       self.alloc.used_pages)
        active = [s for s in range(self.batch) if self.slot_req[s] is not None]
        self.peak_active = max(self.peak_active, len(active))
        if not active:
            return 0
        self.ticks += 1
        decode = [s for s in active if self._phase(s) == "decode"]
        spec = [s for s in decode if s in drafts]
        decode = [s for s in decode if s not in drafts]
        prefill = [s for s in active if self._phase(s) == "prefill"]
        page_table = (_snapshot(self.alloc.page_table)
                      if self.paged else None)

        if decode:
            tokens = np.zeros((self.batch, 1), np.int32)
            mask = np.zeros(self.batch, bool)
            for s in decode:
                tokens[s, 0] = self.slot_req[s].out[-1]
                mask[s] = True
            extra = (page_table,) if self.paged else ()
            logits, self.state = self._step(self.params, self.state,
                                            jnp.asarray(tokens),
                                            _snapshot(self.slot_pos),
                                            jnp.asarray(mask), *extra)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s in decode:
                req = self.slot_req[s]
                req._cursor += 1  # type: ignore[attr-defined]
                self.slot_pos[s] += 1
                req.out.append(int(nxt[s]))
                self.tokens_generated += 1
                self._retire_if_done(s)

        if spec:
            # speculation: verify the chunk [pending token, drafts...]
            # at absolute positions pos..pos+d in one forward, accept
            # the longest prefix of drafts matching the verifier's own
            # greedy picks (plus its bonus token), then COMMIT exactly
            # the accepted tokens with a length-gated prefill_step (the
            # verify state, rejected writes included, is discarded)
            D1 = self.spec_depth + 1
            tokens = np.zeros((self.batch, D1), np.int32)
            lengths = np.zeros(self.batch, np.int32)
            for s in spec:
                dr = drafts[s]
                tokens[s, 0] = self.slot_req[s].out[-1]
                tokens[s, 1:1 + len(dr)] = dr
                lengths[s] = len(dr) + 1
            extra = (page_table,) if self.paged else ()
            logits, _ = self._verify_step(
                self.params, self.state, jnp.asarray(tokens),
                _snapshot(self.slot_pos), jnp.asarray(lengths), *extra)
            greedy = np.asarray(jnp.argmax(logits, axis=-1))  # (B, D1)
            commit = np.zeros(self.batch, np.int32)
            emitted: dict[int, list[int]] = {}
            for s in spec:
                dr = drafts[s]
                k = 0
                while k < len(dr) and int(greedy[s, k]) == dr[k]:
                    k += 1
                emitted[s] = dr[:k] + [int(greedy[s, k])]
                commit[s] = k + 1
                req = self.slot_req[s]
                req.spec_proposed += len(dr)
                req.spec_accepted += k
                self.spec_proposed += len(dr)
                self.spec_accepted += k
            _, self.state = self._prefill_step(
                self.params, self.state, jnp.asarray(tokens),
                _snapshot(self.slot_pos), jnp.asarray(commit), *extra)
            self.spec_ticks += 1
            for s in spec:
                req = self.slot_req[s]
                e = int(commit[s])
                req._cursor += e  # type: ignore[attr-defined]
                self.slot_pos[s] += e
                req.out.extend(emitted[s])
                self.tokens_generated += e
                if self.paged:
                    # hand back pages grabbed for rejected positions;
                    # the table must match a never-speculated drain
                    self.alloc.rewind(s, int(self.slot_pos[s]))
                self._retire_if_done(s)

        if prefill:
            T = self.prefill_chunk
            tokens = np.zeros((self.batch, T), np.int32)
            lengths = np.zeros(self.batch, np.int32)
            for s in prefill:
                req = self.slot_req[s]
                cur = req._cursor  # type: ignore[attr-defined]
                n = min(T, len(req.prompt) - cur)
                tokens[s, :n] = req.prompt[cur:cur + n]
                lengths[s] = n
            extra = (page_table,) if self.paged else ()
            logits, self.state = self._prefill_step(
                self.params, self.state, jnp.asarray(tokens),
                _snapshot(self.slot_pos), jnp.asarray(lengths), *extra)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            self.prefill_chunks += len(prefill)
            for s in prefill:
                req = self.slot_req[s]
                n = int(lengths[s])
                req._cursor += n  # type: ignore[attr-defined]
                self.slot_pos[s] += n
                if req._cursor >= len(req.prompt):
                    req.out.append(int(nxt[s]))
                    self.tokens_generated += 1
                    self._retire_if_done(s)

        # sliding-window reclamation: pages whose positions all fell out
        # of the window are never attended again — hand them back.  The
        # next tick's earliest attended position is slot_pos - window + 1.
        if self.paged and self.api.cfg.window is not None:
            w = self.api.cfg.window
            for s in range(self.batch):
                if self.slot_req[s] is not None:
                    self.alloc.trim(s, max(0, int(self.slot_pos[s]) - w + 1))
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if self.tick() == 0 and not self.queue:
                return
        raise RuntimeError("serving did not drain")


# ---------------------------------------------------------------------------
# serving-configuration tuning (repro.tune)
# ---------------------------------------------------------------------------


KV_CACHE_BYTES = 2          # bf16 cache entries
K_AND_V = 2                 # two tensors per layer


def timed_server_drain(api: ModelAPI, params, *, batch: int, context: int,
                       prompts, max_new: int, prefill_chunk: int = 32,
                       paged: bool = False, page_size: int = 16,
                       kv_pages: int | None = None, speculate: Any = None,
                       spec_depth: int = 4,
                       stats_out: dict | None = None, warmup: int = 1,
                       iters: int = 1) -> float:
    """Median wall-clock microseconds to drain ``prompts`` (a list of
    token lists) through a fresh :class:`Server` — the one measurement
    harness behind every serving tunable's ``measure(cfg)``
    (:class:`DecodeBatchTunable`, :class:`PrefillChunkTunable`,
    :class:`KVPageTunable`, :class:`~repro.runtime.speculate.\
SpecDepthTunable`).  Warmup drains absorb the step compiles for the
    batch/chunk shape.  ``speculate``/``spec_depth`` pass through to
    :class:`Server` (hand a shared Drafter INSTANCE across calls to
    reuse a draft model's jit cache).  ``stats_out`` (a dict) receives
    the last drain's :meth:`Server.stats` snapshot — real
    proposed/accepted counts for measure() provenance."""

    from ..kernels.common import time_fn
    prompts = [list(p) for p in prompts]

    def drain() -> None:
        srv = Server(api, params, batch=batch, context=context,
                     prefill_chunk=prefill_chunk, paged=paged,
                     page_size=page_size, kv_pages=kv_pages,
                     speculate=speculate, spec_depth=spec_depth)
        for prompt in prompts:
            srv.submit(prompt, max_new=max_new)
        srv.run_until_drained()
        if stats_out is not None:
            stats_out.clear()
            stats_out.update(srv.stats())

    return time_fn(drain, warmup=warmup, iters=iters)


def _require_model(tunable, helper: str) -> None:
    if tunable.api is None or tunable.params is None:
        raise RuntimeError(
            f"{type(tunable).__name__}.measure needs the model attached: "
            f"construct with api=/params= ({helper})")


def kv_cache_stream_s(batch: int, layers: int, cache_len: int,
                      kv_width: int) -> float:
    """Seconds to stream every slot's KV cache once (one engine tick's
    cache traffic).  GQA caches are ``n_kv_heads * hd`` elements wide —
    modeling them as ``d_model`` overestimated KV reads by the
    ``n_heads / n_kv_heads`` grouping ratio and biased slot-count picks
    low.  Shared by :class:`DecodeBatchTunable` and
    :class:`PrefillChunkTunable`."""

    return (batch * layers * cache_len * kv_width
            * K_AND_V * KV_CACHE_BYTES / HBM_BW)


@dataclass(frozen=True)
class DecodeBatchTunable:
    """``repro.tune`` Tunable: the server's slot count.

    Decode is HBM-bound: each engine tick re-streams the weights once
    (amortized over every active slot) and reads each slot's KV cache.
    More slots amortize the weight stream but add KV traffic and admit
    waves of requests; the grid engine picks the drain-time optimum for
    an expected load (request count × mean new tokens).

    With ``api``/``params`` attached (``choose_batch(..., params=...)``)
    the tunable also implements ``measure(cfg)`` — a real :class:`Server`
    drain at that slot count — so ``engine="measure"`` can refine the
    modeled pick against wall-clock."""

    param_bytes: int
    layers: int
    d_model: int
    context: int
    requests: int
    mean_new: int
    max_batch: int = 64
    dispatch_s: float = 50e-6
    # GQA KV-cache width in elements (n_kv_heads * hd); 0 falls back to
    # d_model (the pre-fix overestimate) for old call sites
    kv_width: int = 0
    # hardware-in-the-loop handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.decode_batch"

    def space(self) -> SearchSpace:
        sizes = []
        b = 1
        while b <= self.max_batch:
            sizes.append(b)
            b *= 2
        return SearchSpace(params=[Param("batch", tuple(sizes))])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds to drain the expected load (same unit
        as ``measure`` so modeled/measured entries are comparable)."""

        b = cfg["batch"]
        weight_s = self.param_bytes / HBM_BW
        kv_s = kv_cache_stream_s(b, self.layers, self.context,
                                 self.kv_width or self.d_model)
        tick_s = weight_s + kv_s + self.dispatch_s
        waves = -(-self.requests // b)
        return waves * self.mean_new * tick_s * 1e6

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1, prompt_len: int = 4) -> float:
        """Wall-clock microseconds to drain the expected load through a
        real :class:`Server` at this slot count."""

        _require_model(self, "choose_batch(..., params=...)")
        plen = max(1, min(prompt_len, self.context - self.mean_new - 1))
        return timed_server_drain(
            self.api, self.params, batch=int(cfg["batch"]),
            context=self.context,
            prompts=[range(1, plen + 1)] * self.requests,
            max_new=self.mean_new, warmup=warmup, iters=iters)

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        # "unit" keys out stale entries from before cost() switched from
        # seconds to microseconds (same fields, 1e6-different meaning)
        return {"tunable": self.name, "unit": "us", **fp}


def decode_batch_tunable(api: ModelAPI, *, context: int, requests: int,
                         max_new: int, params=None) -> DecodeBatchTunable:
    """The server-slot tunable for this model + expected load — the one
    place the sizing wiring lives (library ``choose_batch`` and the
    ``launch/serve --tune-batch`` CLI both build through here)."""

    return DecodeBatchTunable(param_bytes=api.param_count() * 2,
                              layers=api.cfg.n_layers,
                              d_model=api.cfg.d_model, context=context,
                              requests=requests, mean_new=max_new,
                              kv_width=api.cfg.n_kv_heads * api.cfg.hd,
                              api=api, params=params)


def choose_batch(api: ModelAPI, *, context: int, requests: int,
                 max_new: int, cache="default", params=None,
                 engine: str = "grid", **tune_kw):
    """Pick the slot count for :class:`Server` via ``repro.tune``;
    returns ``(batch, TuneResult)``.

    ``engine="measure"`` (requires ``params``) shortlists slot counts
    through the drain-time model, then times real server drains and
    returns the wall-clock winner."""

    from ..tune import tune as _tune
    tb = decode_batch_tunable(api, context=context, requests=requests,
                              max_new=max_new, params=params)
    res = _tune(tb, engine=engine, cache=cache, **tune_kw)
    return int(res.best_config["batch"]), res


@dataclass(frozen=True)
class PrefillChunkTunable:
    """``repro.tune`` Tunable: tokens per chunked-prefill tick
    (``Server(prefill_chunk=...)``).

    Chunked prefill amortizes the per-tick weight stream over ``chunk``
    prompt tokens, so a prompt costs ``ceil(len/chunk)`` ticks instead
    of ``len`` — but each tick spends chunk-linear matmul FLOPs and a
    chunk-quadratic attention-score term, so the optimum is a genuine
    tradeoff, not "as big as possible".  ``cost`` models the drain of
    the expected long-prompt load (``requests`` prompts of
    ``prompt_len`` tokens + ``mean_new`` decode steps each) in
    microseconds; with ``api``/``params`` attached, ``measure(cfg)``
    drains a real :class:`Server` at that chunk size so
    ``engine="measure"`` can return the wall-clock winner."""

    param_bytes: int
    layers: int
    d_model: int
    kv_width: int               # GQA cache width, n_kv_heads * hd
    context: int
    prompt_len: int
    requests: int
    mean_new: int
    batch: int = 4
    max_chunk: int = 256
    dispatch_s: float = 50e-6
    # hardware-in-the-loop handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.prefill_chunk"

    def space(self) -> SearchSpace:
        sizes = []
        c = 1
        cap = min(self.max_chunk, self.context)
        while c <= cap:
            sizes.append(c)
            if c >= self.prompt_len:    # larger chunks cannot help
                break
            c *= 2
        return SearchSpace(params=[Param("chunk", tuple(sizes))])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds to drain the load (same unit as
        ``measure``): per prefill tick, one weight stream (amortized
        over the chunk — the term chunking exists to shrink), one KV
        stream (GQA width, shared with :class:`DecodeBatchTunable`),
        chunk-linear matmul FLOPs, and a chunk-quadratic score/HBM term;
        decode ticks follow the decode-batch model."""

        chunk = cfg["chunk"]
        n_params = self.param_bytes / 2            # bf16 weights
        weight_s = self.param_bytes / HBM_BW
        kv_s = kv_cache_stream_s(self.batch, self.layers, self.context,
                                 self.kv_width)
        flops_s = 2 * n_params * chunk * self.batch / PEAK_FLOPS
        score_s = (self.batch * self.layers * chunk
                   * (self.context + chunk) * 4 / HBM_BW)
        prefill_tick_s = (weight_s + kv_s + flops_s + score_s
                          + self.dispatch_s)
        decode_tick_s = (weight_s + kv_s
                         + 2 * n_params * self.batch / PEAK_FLOPS
                         + self.dispatch_s)
        prefill_ticks = -(-self.prompt_len // chunk)
        waves = -(-self.requests // self.batch)
        return waves * (prefill_ticks * prefill_tick_s
                        + self.mean_new * decode_tick_s) * 1e6

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1) -> float:
        """Wall-clock microseconds to drain the long-prompt load through
        a real :class:`Server` at this chunk size."""

        _require_model(self, "choose_prefill_chunk(..., params=...)")
        if self.prompt_len > self.context - self.mean_new:
            # silently clamping here would measure a different load than
            # cost() models and the cache fingerprint claims
            raise ValueError(
                f"prompt_len={self.prompt_len} + mean_new={self.mean_new} "
                f"exceeds context={self.context}; size the tunable to the "
                f"load it will actually serve (prefill_chunk_tunable "
                f"clamps for you)")
        vocab = self.api.cfg.vocab
        prompt = [i % (vocab - 1) + 1 for i in range(self.prompt_len)]
        return timed_server_drain(
            self.api, self.params, batch=self.batch, context=self.context,
            prompts=[prompt] * self.requests, max_new=self.mean_new,
            prefill_chunk=int(cfg["chunk"]), warmup=warmup, iters=iters)

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        return {"tunable": self.name, "unit": "us", **fp}


def prefill_chunk_tunable(api: ModelAPI, *, context: int, prompt_len: int,
                          requests: int, max_new: int, batch: int,
                          max_chunk: int = 256,
                          params=None) -> PrefillChunkTunable:
    """The chunked-prefill tunable for this model + expected load — the
    one place the sizing wiring lives (library ``choose_prefill_chunk``
    and the ``launch/serve --tune-prefill`` CLI both build through
    here)."""

    # clamp UP FRONT so cost(), measure() and the cache fingerprint all
    # describe the same load
    prompt_len = max(1, min(prompt_len, context - max_new))
    return PrefillChunkTunable(param_bytes=api.param_count() * 2,
                               layers=api.cfg.n_layers,
                               d_model=api.cfg.d_model,
                               kv_width=api.cfg.n_kv_heads * api.cfg.hd,
                               context=context, prompt_len=prompt_len,
                               requests=requests, mean_new=max_new,
                               batch=batch, max_chunk=max_chunk,
                               api=api, params=params)


def choose_prefill_chunk(api: ModelAPI, *, context: int, prompt_len: int,
                         requests: int, max_new: int, batch: int,
                         cache="default", params=None,
                         engine: str = "grid", **tune_kw):
    """Pick ``Server``'s ``prefill_chunk`` via ``repro.tune``; returns
    ``(chunk, TuneResult)``.  ``engine="measure"`` (requires ``params``)
    shortlists chunk sizes through the drain-time model, then times real
    long-prompt server drains and returns the wall-clock winner."""

    from ..tune import tune as _tune
    tb = prefill_chunk_tunable(api, context=context, prompt_len=prompt_len,
                               requests=requests, max_new=max_new,
                               batch=batch, params=params)
    res = _tune(tb, engine=engine, cache=cache, **tune_kw)
    return int(res.best_config["chunk"]), res


@dataclass(frozen=True)
class KVPageTunable:
    """``repro.tune`` Tunable: the paged KV-cache page size
    (``Server(paged=True, page_size=...)``).

    The page size trades **internal fragmentation** against **gather
    overhead**: every live request strands the unused tail of its last
    page (~``page/2`` tokens expected), shrinking how many requests a
    fixed pool holds concurrently — so big pages mean more drain waves;
    but every attended token is reached through the page table, and
    smaller pages mean more page descriptors per tick.  ``cost`` models
    the drain of a MIXED-length load (``prompt_lens`` cycled over
    ``requests``, ``mean_new`` decode steps each, ``batch`` slots
    sharing ``pool_tokens`` of page capacity) in microseconds; with
    ``api``/``params`` attached, ``measure(cfg)`` drains the same mixed
    load through a real paged :class:`Server`."""

    param_bytes: int
    layers: int
    d_model: int
    kv_width: int               # GQA cache width, n_kv_heads * hd
    context: int
    prompt_lens: tuple[int, ...]
    requests: int
    mean_new: int
    batch: int = 4
    pool_tokens: int = 0        # 0 -> batch * context (contiguous parity)
    prefill_chunk: int = 32
    max_page: int = 128
    page_gather_s: float = 2e-6  # per page descriptor chased per tick
    dispatch_s: float = 50e-6
    # hardware-in-the-loop handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.kv_page"

    def __post_init__(self):
        # plan specs deliver JSON lists; the fingerprint and lattice
        # want a hashable tuple
        object.__setattr__(self, "prompt_lens", tuple(self.prompt_lens))
        if not self.prompt_lens:
            raise ValueError("prompt_lens must name at least one length")

    def _pool(self) -> int:
        return self.pool_tokens or self.batch * self.context

    def space(self) -> SearchSpace:
        sizes = []
        ps = 4
        cap = min(self.max_page, self.context)
        while ps <= cap:
            sizes.append(ps)
            ps *= 2
        return SearchSpace(params=[Param("page", tuple(sizes))])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds to drain the mixed load (same unit as
        ``measure``): requests occupy ``ceil(total/page)`` pages each —
        the page-rounding waste caps how many run concurrently in the
        pool — and each tick pays the weight stream, the live-KV
        stream, and one page-table chase per live page."""

        page = cfg["page"]
        totals = [min(L, self.context - self.mean_new) + self.mean_new
                  for L in self.prompt_lens]
        mean_total = sum(totals) / len(totals)
        # page-capacity footprint of one request, fragmentation included
        footprint = sum(-(-t // page) * page for t in totals) / len(totals)
        conc = max(1, min(self.batch, int(self._pool() // footprint)))
        waves = -(-self.requests // conc)
        mean_prompt = mean_total - self.mean_new
        ticks = -(-int(mean_prompt) // self.prefill_chunk) + self.mean_new
        weight_s = self.param_bytes / HBM_BW
        kv_s = kv_cache_stream_s(conc, self.layers, int(mean_total),
                                 self.kv_width)
        gather_s = conc * -(-int(mean_total) // page) * self.page_gather_s
        tick_s = weight_s + kv_s + gather_s + self.dispatch_s
        return waves * ticks * tick_s * 1e6

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1) -> float:
        """Wall-clock microseconds to drain the mixed-length load
        through a real paged :class:`Server` at this page size."""

        _require_model(self, "choose_kv_page(..., params=...)")
        page = int(cfg["page"])
        vocab = self.api.cfg.vocab
        prompts = []
        for r in range(self.requests):
            plen = min(self.prompt_lens[r % len(self.prompt_lens)],
                       self.context - self.mean_new)
            prompts.append([(r + i) % (vocab - 1) + 1 for i in range(plen)])
        kv_pages = max(self._pool() // page, -(-self.context // page))
        return timed_server_drain(
            self.api, self.params, batch=self.batch, context=self.context,
            prompts=prompts, max_new=self.mean_new,
            prefill_chunk=self.prefill_chunk, paged=True, page_size=page,
            kv_pages=kv_pages, warmup=warmup, iters=iters)

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        fp["prompt_lens"] = list(self.prompt_lens)
        return {"tunable": self.name, "unit": "us", **fp}


def kv_page_tunable(api: ModelAPI, *, context: int, prompt_lens,
                    requests: int, max_new: int, batch: int,
                    pool_tokens: int | None = None,
                    params=None) -> KVPageTunable:
    """The page-size tunable for this model + expected mixed-length
    load — the one place the sizing wiring lives (library
    ``choose_kv_page`` and the ``launch/serve --tune-page`` CLI both
    build through here)."""

    prompt_lens = tuple(max(1, min(p, context - max_new))
                        for p in prompt_lens)
    return KVPageTunable(param_bytes=api.param_count() * 2,
                         layers=api.cfg.n_layers, d_model=api.cfg.d_model,
                         kv_width=api.cfg.n_kv_heads * api.cfg.hd,
                         context=context, prompt_lens=prompt_lens,
                         requests=requests, mean_new=max_new, batch=batch,
                         pool_tokens=pool_tokens or 0,
                         api=api, params=params)


def choose_kv_page(api: ModelAPI, *, context: int, prompt_lens,
                   requests: int, max_new: int, batch: int,
                   pool_tokens: int | None = None, cache="default",
                   params=None, engine: str = "grid", **tune_kw):
    """Pick ``Server(paged=True)``'s page size via ``repro.tune``;
    returns ``(page, TuneResult)``.  ``engine="measure"`` (requires
    ``params``) shortlists page sizes through the fragmentation/gather
    model, then times real mixed-length paged drains and returns the
    wall-clock winner."""

    from ..tune import tune as _tune
    tb = kv_page_tunable(api, context=context, prompt_lens=prompt_lens,
                         requests=requests, max_new=max_new, batch=batch,
                         pool_tokens=pool_tokens, params=params)
    res = _tune(tb, engine=engine, cache=cache, **tune_kw)
    return int(res.best_config["page"]), res


__all__ = ["Server", "Request", "DecodeBatchTunable", "PrefillChunkTunable",
           "KVPageTunable", "decode_batch_tunable", "prefill_chunk_tunable",
           "kv_page_tunable", "choose_batch", "choose_prefill_chunk",
           "choose_kv_page", "kv_cache_stream_s", "timed_server_drain"]
