"""Batched serving runtime: fixed-slot continuous batching.

``Server`` keeps ``batch`` decode slots alive; requests are admitted
into free slots, every engine tick advances *all* active slots by one
token through the (jitted) ``decode_step``, finished requests retire and
free their slot.  This is continuous batching in its TPU-friendly form:
static shapes (slot count and cache length fixed), per-slot state packed
in the same pytree the dry-run's serve_step lowers.

Greedy sampling; per-slot absolute positions drive RoPE/ring caches, so
mixed-progress slots coexist in one batch.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, ClassVar, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..core.search_space import Param, SearchSpace
from ..core.tpu_machine import HBM_BW
from ..models.api import ModelAPI


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = field(default_factory=list)
    done: bool = False


class Server:
    def __init__(self, api: ModelAPI, params, *, batch: int, context: int):
        self.api = api
        self.params = params
        self.batch = batch
        self.context = context
        self.state = api.init_decode_state(batch, context)
        self.slot_req: list[Request | None] = [None] * batch
        self.slot_pos = np.zeros(batch, np.int32)   # per-slot token count
        self.queue: list[Request] = []
        self.completed: list[Request] = []

        # jitted one-token step over the whole slot batch; positions is
        # the (batch,) per-slot position vector — decode_step threads it
        # through RoPE, the ring-cache slot, and the validity mask, so
        # mixed-progress slots coexist correctly in one batch
        def step(params, state, tokens, positions):
            return api.decode_step(params, state, tokens, positions)

        self._step = jax.jit(step)

    # -- API ----------------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int,
               frames: Any = None) -> Request:
        """``frames``: enc-dec audio frontend output (enc_seq, d_model)
        for this request; the encoder runs at admission and its cross-K/V
        fills the request's slot (serving-side prefill)."""

        req = Request(rid=len(self.completed) + len(self.queue) +
                      sum(r is not None for r in self.slot_req),
                      prompt=list(prompt), max_new=max_new)
        req._frames = frames  # type: ignore[attr-defined]
        self.queue.append(req)
        return req

    def _admit(self) -> None:
        for slot in range(self.batch):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[slot] = req
                self.slot_pos[slot] = 0
                req._cursor = 0  # type: ignore[attr-defined]
                frames = getattr(req, "_frames", None)
                if self.api.cfg.is_encdec and frames is not None:
                    kv = self.api.encode_cross_kv(
                        self.params, jnp.asarray(frames)[None])
                    xk, xv = self.state["xattn"]["k"], self.state["xattn"]["v"]
                    self.state["xattn"]["k"] = xk.at[:, slot].set(
                        kv["k"][:, 0].astype(xk.dtype))
                    self.state["xattn"]["v"] = xv.at[:, slot].set(
                        kv["v"][:, 0].astype(xv.dtype))

    def tick(self) -> int:
        """One engine iteration; returns number of active slots."""

        self._admit()
        active = [s for s in range(self.batch) if self.slot_req[s] is not None]
        if not active:
            return 0
        tokens = np.zeros((self.batch, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            cur = req._cursor  # type: ignore[attr-defined]
            if cur < len(req.prompt):
                tokens[s, 0] = req.prompt[cur]       # prompt consumption
            else:
                tokens[s, 0] = req.out[-1] if req.out else 0
        logits, self.state = self._step(self.params, self.state,
                                        jnp.asarray(tokens),
                                        jnp.asarray(self.slot_pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for s in active:
            req = self.slot_req[s]
            req._cursor += 1  # type: ignore[attr-defined]
            self.slot_pos[s] += 1
            if req._cursor >= len(req.prompt):  # type: ignore[attr-defined]
                req.out.append(int(nxt[s]))
                if len(req.out) >= req.max_new or \
                        self.slot_pos[s] >= self.context - 1:
                    req.done = True
                    self.completed.append(req)
                    self.slot_req[s] = None
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if self.tick() == 0 and not self.queue:
                return
        raise RuntimeError("serving did not drain")


# ---------------------------------------------------------------------------
# serving-configuration tuning (repro.tune)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DecodeBatchTunable:
    """``repro.tune`` Tunable: the server's slot count.

    Decode is HBM-bound: each engine tick re-streams the weights once
    (amortized over every active slot) and reads each slot's KV cache.
    More slots amortize the weight stream but add KV traffic and admit
    waves of requests; the grid engine picks the drain-time optimum for
    an expected load (request count × mean new tokens).

    With ``api``/``params`` attached (``choose_batch(..., params=...)``)
    the tunable also implements ``measure(cfg)`` — a real :class:`Server`
    drain at that slot count — so ``engine="measure"`` can refine the
    modeled pick against wall-clock."""

    param_bytes: int
    layers: int
    d_model: int
    context: int
    requests: int
    mean_new: int
    max_batch: int = 64
    dispatch_s: float = 50e-6
    # hardware-in-the-loop handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.decode_batch"

    def space(self) -> SearchSpace:
        sizes = []
        b = 1
        while b <= self.max_batch:
            sizes.append(b)
            b *= 2
        return SearchSpace(params=[Param("batch", tuple(sizes))])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds to drain the expected load (same unit
        as ``measure`` so modeled/measured entries are comparable)."""

        b = cfg["batch"]
        weight_s = self.param_bytes / HBM_BW
        kv_s = b * self.layers * self.context * self.d_model * 2 * 2 / HBM_BW
        tick_s = weight_s + kv_s + self.dispatch_s
        waves = -(-self.requests // b)
        return waves * self.mean_new * tick_s * 1e6

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1, prompt_len: int = 4) -> float:
        """Wall-clock microseconds to drain the expected load through a
        real :class:`Server` at this slot count (warmup drains absorb
        the decode-step compile for the batch shape)."""

        if self.api is None or self.params is None:
            raise RuntimeError(
                "DecodeBatchTunable.measure needs the model attached: "
                "construct with api=/params= (choose_batch(..., params=...))")
        from ..kernels.common import time_fn
        plen = max(1, min(prompt_len, self.context - self.mean_new - 1))

        def drain() -> None:
            srv = Server(self.api, self.params,
                         batch=int(cfg["batch"]), context=self.context)
            for _ in range(self.requests):
                srv.submit(list(range(1, plen + 1)), max_new=self.mean_new)
            srv.run_until_drained()

        return time_fn(drain, warmup=warmup, iters=iters)

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        # "unit" keys out stale entries from before cost() switched from
        # seconds to microseconds (same fields, 1e6-different meaning)
        return {"tunable": self.name, "unit": "us", **fp}


def decode_batch_tunable(api: ModelAPI, *, context: int, requests: int,
                         max_new: int, params=None) -> DecodeBatchTunable:
    """The server-slot tunable for this model + expected load — the one
    place the sizing wiring lives (library ``choose_batch`` and the
    ``launch/serve --tune-batch`` CLI both build through here)."""

    return DecodeBatchTunable(param_bytes=api.param_count() * 2,
                              layers=api.cfg.n_layers,
                              d_model=api.cfg.d_model, context=context,
                              requests=requests, mean_new=max_new,
                              api=api, params=params)


def choose_batch(api: ModelAPI, *, context: int, requests: int,
                 max_new: int, cache="default", params=None,
                 engine: str = "grid", **tune_kw):
    """Pick the slot count for :class:`Server` via ``repro.tune``;
    returns ``(batch, TuneResult)``.

    ``engine="measure"`` (requires ``params``) shortlists slot counts
    through the drain-time model, then times real server drains and
    returns the wall-clock winner."""

    from ..tune import tune as _tune
    tb = decode_batch_tunable(api, context=context, requests=requests,
                              max_new=max_new, params=params)
    res = _tune(tb, engine=engine, cache=cache, **tune_kw)
    return int(res.best_config["batch"]), res


__all__ = ["Server", "Request", "DecodeBatchTunable",
           "decode_batch_tunable", "choose_batch"]
