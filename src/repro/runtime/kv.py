"""Paged KV-cache bookkeeping: the block-table allocator.

The contiguous serving cache reserves a full ``context``-length ring per
slot, so memory scales with ``batch * context`` whether a request uses
three tokens or three thousand — mixed short/long traffic strands most
of it.  Paged mode replaces the per-slot ring with a shared **pool** of
fixed-size pages; each slot holds a *page table* mapping logical page
``pos // page_size`` to a physical page id, pages are allocated on
demand as prefill chunks and decode steps advance, and a retired slot's
pages return to a free list for immediate reuse.

This module is the host-side bookkeeping half (pure numpy — the page
table crosses into jit as a plain ``(slots, pages_per_slot)`` int32
array); the device-side gather/scatter lives in
:func:`repro.models.attention.decode_attention_paged` and its chunked
sibling, and :class:`repro.runtime.serve.Server` threads the two
together (``paged=True``).

**Copy-on-write prefix sharing** extends the table with per-page
refcounts: :meth:`PagedKVAllocator.share` maps the pages backing a
common prompt prefix into a second slot's page table (the K/V is
prefilled once, then referenced N times), and
:meth:`PagedKVAllocator.cow_pages` breaks the sharing page-by-page the
moment a slot is about to *write* into a shared page — the caller gets
``(src_page, dst_page)`` pairs to copy device-side, and the writer
proceeds against its private copy.  ``release``/``rewind``/``trim``
decrement refcounts and only return a page to the free list when its
last holder lets go.

Invariants the allocator maintains (tested in ``tests/test_kv.py``):

* a physical page is EXCLUSIVELY owned unless explicitly shared via
  ``share`` (refcount == number of page tables mapping it),
* ``ensure`` is all-or-nothing — a partial allocation never leaks,
* ``release``/``rewind``/``trim`` decrement refcounts; a page returns
  to the free list (LIFO, so reuse is immediate and cache-friendly)
  exactly when its refcount reaches zero,
* the page table never points at a freed page,
* ``cow_pages`` is all-or-nothing: a write range either gets every
  shared page it touches copied, or (free list too short) nothing
  changes.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

NO_PAGE = -1


def _traced(fn):
    """Record ``(method, args, ret)`` on ``self.trace`` when tracing is
    on — the narrow op-trace hook :mod:`repro.verify.conformance`
    replays against the abstract allocator model.  List returns are
    frozen to tuples so traces are hashable/JSON-friendly."""

    name = fn.__name__

    @functools.wraps(fn)
    def wrapper(self, *args):
        ret = fn(self, *args)
        if self.trace is not None:
            rec = tuple(tuple(p) if isinstance(p, tuple) else p
                        for p in ret) if isinstance(ret, list) else ret
            self.trace.append((name, tuple(int(a) for a in args), rec))
        return ret
    return wrapper


@dataclass(frozen=True)
class PagedKVSpec:
    """Static shape of a paged KV pool: ``n_pages`` physical pages of
    ``page_size`` tokens each, addressed through per-slot page tables of
    ``pages_per_slot`` logical entries (= ``ceil(context / page_size)``,
    the per-request position bound)."""

    n_pages: int
    page_size: int
    pages_per_slot: int

    def __post_init__(self):
        if self.page_size < 1 or self.n_pages < 1 or self.pages_per_slot < 1:
            raise ValueError(f"degenerate paged spec: {self}")

    @property
    def pool_tokens(self) -> int:
        return self.n_pages * self.page_size

    @classmethod
    def for_server(cls, *, context: int, page_size: int,
                   n_pages: int | None = None,
                   batch: int = 1) -> "PagedKVSpec":
        """The spec a :class:`~repro.runtime.serve.Server` needs:
        ``pages_per_slot`` covers ``context`` positions; ``n_pages``
        defaults to full per-slot backing (equal memory to the
        contiguous layout) and must cover at least one full slot so a
        lone request can always make progress (deferral has nobody else
        to evict)."""

        pps = -(-context // page_size)
        if n_pages is None:
            n_pages = batch * pps
        if n_pages < pps:
            raise ValueError(
                f"kv_pages={n_pages} cannot back even one full slot "
                f"({pps} pages for context={context} at "
                f"page_size={page_size}); a single request could deadlock")
        return cls(n_pages=n_pages, page_size=page_size, pages_per_slot=pps)


class PagedKVAllocator:
    """Free-list page allocator + per-slot page tables (host side)."""

    def __init__(self, spec: PagedKVSpec, n_slots: int):
        self.spec = spec
        self.n_slots = n_slots
        self.page_table = np.full((n_slots, spec.pages_per_slot), NO_PAGE,
                                  np.int32)
        self.owner = np.full(spec.n_pages, NO_PAGE, np.int32)
        # live page-table references per page: 1 = exclusive, >1 =
        # prefix-shared (writes must go through cow_pages first)
        self.refcount = np.zeros(spec.n_pages, np.int32)
        # LIFO free list: a just-released page is handed out first
        self._free: list[int] = list(range(spec.n_pages - 1, -1, -1))
        # highest logical page ever backed per slot: ensure() only
        # allocates ABOVE it, so pages trimmed away (SWA) or still held
        # are never re-backed for positions already written
        self._top = np.full(n_slots, -1, np.int64)
        # op-trace hook for repro.verify: set to a list to record every
        # mutating call as (method, args, ret)
        self.trace: list[tuple] | None = None

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.spec.n_pages - len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        """Physical pages backing positions ``[0, n_tokens)``."""

        return -(-max(0, n_tokens) // self.spec.page_size)

    def fits(self, n_tokens: int) -> bool:
        """Could a fresh slot hold ``n_tokens`` right now?  (Admission
        check: positions bound by the page table, pages by the free
        list.)"""

        need = self.pages_needed(n_tokens)
        return need <= self.spec.pages_per_slot and need <= self.free_pages

    def slot_pages(self, slot: int) -> list[int]:
        row = self.page_table[slot]
        return [int(p) for p in row if p != NO_PAGE]

    @property
    def shared_pages(self) -> int:
        """Physical pages currently mapped by more than one slot."""

        return int(np.sum(self.refcount > 1))

    def is_shared(self, slot: int, logical_page: int) -> bool:
        page = int(self.page_table[slot, logical_page])
        return page != NO_PAGE and int(self.refcount[page]) > 1

    def project(self) -> tuple:
        """Canonical hashable projection of the allocator's mutable
        state — the shared vocabulary between this class and the
        abstract model in :mod:`repro.verify.models` (state agreement
        along a replayed trail is projection equality)."""

        return (
            tuple(tuple(int(p) for p in row) for row in self.page_table),
            tuple(int(r) for r in self.refcount),
            tuple(int(o) for o in self.owner),
            tuple(self._free),
            tuple(int(t) for t in self._top),
        )

    # -- mutation -----------------------------------------------------------

    def _deref(self, page: int) -> bool:
        """Drop one reference to ``page`` (its table entry must already
        be cleared); frees it when the last holder lets go.  Returns
        True when the page actually hit the free list."""

        self.refcount[page] -= 1
        if self.refcount[page] <= 0:
            self.refcount[page] = 0
            self.owner[page] = NO_PAGE
            self._free.append(page)
            return True
        if not np.any(self.page_table[int(self.owner[page])] == page):
            # the nominal owner let go first: hand ownership to any
            # remaining holder so owner never names a slot without the
            # page in its table
            holders = np.argwhere(self.page_table == page)
            self.owner[page] = int(holders[0][0]) if len(holders) else NO_PAGE
        return False

    @_traced
    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Back positions ``[0, n_tokens)`` of ``slot``; allocates only
        logical pages above the slot's high-water mark.  All-or-nothing:
        returns False (and allocates nothing) when the free list cannot
        cover the growth."""

        if n_tokens <= 0:
            return True
        top_needed = (n_tokens - 1) // self.spec.page_size
        if top_needed >= self.spec.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed the page table "
                f"({self.spec.pages_per_slot} pages of "
                f"{self.spec.page_size})")
        grow = top_needed - int(self._top[slot])
        if grow <= 0:
            return True
        if grow > len(self._free):
            return False
        for lp in range(int(self._top[slot]) + 1, top_needed + 1):
            page = self._free.pop()
            self.page_table[slot, lp] = page
            self.owner[page] = slot
            self.refcount[page] = 1
        self._top[slot] = top_needed
        return True

    @_traced
    def share(self, src_slot: int, dst_slot: int, n_tokens: int) -> int:
        """Map the pages backing positions ``[0, n_tokens)`` of
        ``src_slot`` into ``dst_slot``'s page table (refcounts bumped,
        no K/V moved — both slots now read the same physical pages).
        ``dst_slot`` must be empty and the source range fully backed.
        Returns the number of pages shared."""

        if n_tokens <= 0:
            return 0
        if int(self._top[dst_slot]) != -1 or self.slot_pages(dst_slot):
            raise ValueError(f"share: dst slot {dst_slot} is not empty")
        need = self.pages_needed(n_tokens)
        row = self.page_table[src_slot, :need]
        if np.any(row == NO_PAGE):
            raise ValueError(
                f"share: src slot {src_slot} does not back {n_tokens} "
                f"tokens ({need} pages)")
        for lp in range(need):
            page = int(row[lp])
            self.page_table[dst_slot, lp] = page
            self.refcount[page] += 1
        self._top[dst_slot] = need - 1
        return need

    @_traced
    def cow_pages(self, slot: int, start_pos: int,
                  end_pos: int) -> list[tuple[int, int]] | None:
        """Break sharing before ``slot`` writes positions
        ``[start_pos, end_pos)``: every SHARED page in that range is
        remapped to a fresh private page (refcount 1, old refcount
        dropped).  Returns ``(old_page, new_page)`` pairs for the caller
        to copy device-side, or None when the free list cannot cover
        them (all-or-nothing — no table entry changes on failure)."""

        if end_pos <= start_pos:
            return []
        ps = self.spec.page_size
        lo = start_pos // ps
        hi = min((end_pos - 1) // ps, self.spec.pages_per_slot - 1)
        todo = [lp for lp in range(lo, hi + 1)
                if self.is_shared(slot, lp)]
        if len(todo) > len(self._free):
            return None
        pairs: list[tuple[int, int]] = []
        for lp in todo:
            old = int(self.page_table[slot, lp])
            new = self._free.pop()
            self.page_table[slot, lp] = new
            self.owner[new] = slot
            self.refcount[new] = 1
            self._deref(old)
            pairs.append((old, new))
        return pairs

    @_traced
    def release(self, slot: int) -> int:
        """Drop ``slot``'s reference to every page it maps (retire /
        deferral / preemption); a page returns to the free list only
        when no other slot still maps it.  Returns the number of pages
        the slot let go of."""

        pages = self.slot_pages(slot)
        self.page_table[slot] = NO_PAGE
        self._top[slot] = -1
        for page in pages:
            self._deref(page)
        return len(pages)

    @_traced
    def rewind(self, slot: int, n_tokens: int) -> int:
        """Roll ``slot`` back so it backs exactly positions
        ``[0, n_tokens)`` again: free every page above
        ``pages_needed(n_tokens)`` and LOWER the high-water mark so a
        later ``ensure`` re-backs those logical pages with fresh
        physical ones.  This is the speculation-rejection path — pages
        grabbed for draft tokens the verifier refused must come back
        immediately, leaving the table byte-identical to a slot that
        never speculated.  Returns the number freed."""

        keep = self.pages_needed(n_tokens)
        freed = 0
        for lp in range(keep, int(self._top[slot]) + 1):
            page = int(self.page_table[slot, lp])
            if page != NO_PAGE:
                self.page_table[slot, lp] = NO_PAGE
                if self._deref(page):
                    freed += 1
        self._top[slot] = min(int(self._top[slot]), keep - 1)
        return freed

    @_traced
    def trim(self, slot: int, keep_from_pos: int) -> int:
        """Free pages of ``slot`` holding only positions strictly below
        ``keep_from_pos`` (sliding-window reclamation: positions that
        fell out of the window are never attended again).  Whole pages
        only; returns the number freed."""

        ps = self.spec.page_size
        full_below = keep_from_pos // ps      # pages [0, full_below) dead
        freed = 0
        for lp in range(min(full_below, self.spec.pages_per_slot)):
            page = int(self.page_table[slot, lp])
            if page != NO_PAGE:
                self.page_table[slot, lp] = NO_PAGE
                if self._deref(page):
                    freed += 1
        return freed

    # -- stats --------------------------------------------------------------

    def stats(self, live_tokens: int = 0) -> dict[str, float]:
        """Occupancy/fragmentation snapshot.  ``live_tokens`` is the
        caller's count of positions actually holding K/V (the allocator
        tracks pages, not tokens); internal fragmentation is the share
        of allocated page capacity those tokens do not fill."""

        used = self.used_pages
        cap = used * self.spec.page_size
        return {
            "n_pages": float(self.spec.n_pages),
            "page_size": float(self.spec.page_size),
            "used_pages": float(used),
            "free_pages": float(self.free_pages),
            "occupancy": used / self.spec.n_pages,
            "live_tokens": float(live_tokens),
            "fragmentation": (1.0 - live_tokens / cap) if cap else 0.0,
            "shared_pages": float(self.shared_pages),
        }


__all__ = ["NO_PAGE", "PagedKVSpec", "PagedKVAllocator"]
