"""Paged KV-cache bookkeeping: the block-table allocator.

The contiguous serving cache reserves a full ``context``-length ring per
slot, so memory scales with ``batch * context`` whether a request uses
three tokens or three thousand — mixed short/long traffic strands most
of it.  Paged mode replaces the per-slot ring with a shared **pool** of
fixed-size pages; each slot holds a *page table* mapping logical page
``pos // page_size`` to a physical page id, pages are allocated on
demand as prefill chunks and decode steps advance, and a retired slot's
pages return to a free list for immediate reuse.

This module is the host-side bookkeeping half (pure numpy — the page
table crosses into jit as a plain ``(slots, pages_per_slot)`` int32
array); the device-side gather/scatter lives in
:func:`repro.models.attention.decode_attention_paged` and its chunked
sibling, and :class:`repro.runtime.serve.Server` threads the two
together (``paged=True``).

Invariants the allocator maintains (tested in ``tests/test_kv.py``):

* a physical page is owned by at most one live slot,
* ``ensure`` is all-or-nothing — a partial allocation never leaks,
* ``release``/``trim`` return pages to the free list (LIFO, so reuse is
  immediate and cache-friendly),
* the page table never points at a freed page.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

NO_PAGE = -1


@dataclass(frozen=True)
class PagedKVSpec:
    """Static shape of a paged KV pool: ``n_pages`` physical pages of
    ``page_size`` tokens each, addressed through per-slot page tables of
    ``pages_per_slot`` logical entries (= ``ceil(context / page_size)``,
    the per-request position bound)."""

    n_pages: int
    page_size: int
    pages_per_slot: int

    def __post_init__(self):
        if self.page_size < 1 or self.n_pages < 1 or self.pages_per_slot < 1:
            raise ValueError(f"degenerate paged spec: {self}")

    @property
    def pool_tokens(self) -> int:
        return self.n_pages * self.page_size

    @classmethod
    def for_server(cls, *, context: int, page_size: int,
                   n_pages: int | None = None,
                   batch: int = 1) -> "PagedKVSpec":
        """The spec a :class:`~repro.runtime.serve.Server` needs:
        ``pages_per_slot`` covers ``context`` positions; ``n_pages``
        defaults to full per-slot backing (equal memory to the
        contiguous layout) and must cover at least one full slot so a
        lone request can always make progress (deferral has nobody else
        to evict)."""

        pps = -(-context // page_size)
        if n_pages is None:
            n_pages = batch * pps
        if n_pages < pps:
            raise ValueError(
                f"kv_pages={n_pages} cannot back even one full slot "
                f"({pps} pages for context={context} at "
                f"page_size={page_size}); a single request could deadlock")
        return cls(n_pages=n_pages, page_size=page_size, pages_per_slot=pps)


class PagedKVAllocator:
    """Free-list page allocator + per-slot page tables (host side)."""

    def __init__(self, spec: PagedKVSpec, n_slots: int):
        self.spec = spec
        self.n_slots = n_slots
        self.page_table = np.full((n_slots, spec.pages_per_slot), NO_PAGE,
                                  np.int32)
        self.owner = np.full(spec.n_pages, NO_PAGE, np.int32)
        # LIFO free list: a just-released page is handed out first
        self._free: list[int] = list(range(spec.n_pages - 1, -1, -1))
        # highest logical page ever backed per slot: ensure() only
        # allocates ABOVE it, so pages trimmed away (SWA) or still held
        # are never re-backed for positions already written
        self._top = np.full(n_slots, -1, np.int64)

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.spec.n_pages - len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        """Physical pages backing positions ``[0, n_tokens)``."""

        return -(-max(0, n_tokens) // self.spec.page_size)

    def fits(self, n_tokens: int) -> bool:
        """Could a fresh slot hold ``n_tokens`` right now?  (Admission
        check: positions bound by the page table, pages by the free
        list.)"""

        need = self.pages_needed(n_tokens)
        return need <= self.spec.pages_per_slot and need <= self.free_pages

    def slot_pages(self, slot: int) -> list[int]:
        row = self.page_table[slot]
        return [int(p) for p in row if p != NO_PAGE]

    # -- mutation -----------------------------------------------------------

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Back positions ``[0, n_tokens)`` of ``slot``; allocates only
        logical pages above the slot's high-water mark.  All-or-nothing:
        returns False (and allocates nothing) when the free list cannot
        cover the growth."""

        if n_tokens <= 0:
            return True
        top_needed = (n_tokens - 1) // self.spec.page_size
        if top_needed >= self.spec.pages_per_slot:
            raise ValueError(
                f"slot {slot}: {n_tokens} tokens exceed the page table "
                f"({self.spec.pages_per_slot} pages of "
                f"{self.spec.page_size})")
        grow = top_needed - int(self._top[slot])
        if grow <= 0:
            return True
        if grow > len(self._free):
            return False
        for lp in range(int(self._top[slot]) + 1, top_needed + 1):
            page = self._free.pop()
            self.page_table[slot, lp] = page
            self.owner[page] = slot
        self._top[slot] = top_needed
        return True

    def release(self, slot: int) -> int:
        """Free every page of ``slot`` (retire / deferral); returns the
        number released."""

        pages = self.slot_pages(slot)
        for page in pages:
            self.owner[page] = NO_PAGE
            self._free.append(page)
        self.page_table[slot] = NO_PAGE
        self._top[slot] = -1
        return len(pages)

    def rewind(self, slot: int, n_tokens: int) -> int:
        """Roll ``slot`` back so it backs exactly positions
        ``[0, n_tokens)`` again: free every page above
        ``pages_needed(n_tokens)`` and LOWER the high-water mark so a
        later ``ensure`` re-backs those logical pages with fresh
        physical ones.  This is the speculation-rejection path — pages
        grabbed for draft tokens the verifier refused must come back
        immediately, leaving the table byte-identical to a slot that
        never speculated.  Returns the number freed."""

        keep = self.pages_needed(n_tokens)
        freed = 0
        for lp in range(keep, int(self._top[slot]) + 1):
            page = int(self.page_table[slot, lp])
            if page != NO_PAGE:
                self.owner[page] = NO_PAGE
                self._free.append(page)
                self.page_table[slot, lp] = NO_PAGE
                freed += 1
        self._top[slot] = min(int(self._top[slot]), keep - 1)
        return freed

    def trim(self, slot: int, keep_from_pos: int) -> int:
        """Free pages of ``slot`` holding only positions strictly below
        ``keep_from_pos`` (sliding-window reclamation: positions that
        fell out of the window are never attended again).  Whole pages
        only; returns the number freed."""

        ps = self.spec.page_size
        full_below = keep_from_pos // ps      # pages [0, full_below) dead
        freed = 0
        for lp in range(min(full_below, self.spec.pages_per_slot)):
            page = int(self.page_table[slot, lp])
            if page != NO_PAGE:
                self.owner[page] = NO_PAGE
                self._free.append(page)
                self.page_table[slot, lp] = NO_PAGE
                freed += 1
        return freed

    # -- stats --------------------------------------------------------------

    def stats(self, live_tokens: int = 0) -> dict[str, float]:
        """Occupancy/fragmentation snapshot.  ``live_tokens`` is the
        caller's count of positions actually holding K/V (the allocator
        tracks pages, not tokens); internal fragmentation is the share
        of allocated page capacity those tokens do not fill."""

        used = self.used_pages
        cap = used * self.spec.page_size
        return {
            "n_pages": float(self.spec.n_pages),
            "page_size": float(self.spec.page_size),
            "used_pages": float(used),
            "free_pages": float(self.free_pages),
            "occupancy": used / self.spec.n_pages,
            "live_tokens": float(live_tokens),
            "fragmentation": (1.0 - live_tokens / cap) if cap else 0.0,
        }


__all__ = ["NO_PAGE", "PagedKVSpec", "PagedKVAllocator"]
