"""Speculative multi-token decoding: drafters + the speculation-policy
tunable.

Baseline decode advances one greedy token per engine tick per slot:
every generated token pays a full weight stream.  Speculative decoding
drafts ``depth`` candidate tokens cheaply, then scores all ``depth+1``
positions in ONE batched verify forward against the paged/contiguous KV
cache (:meth:`repro.models.api.ModelAPI.verify_step` — the chunked
prefill machinery reused as a verifier) and accepts the longest prefix
of drafts that matches the model's own greedy choices, plus the bonus
token the verifier produces after it.  Greedy accept-longest-prefix
keeps the output token-for-token identical to tick-by-tick decode —
speculation changes the *schedule*, never the text.

Two drafters ship:

* :class:`NGramDrafter` — self-speculative prompt-lookup: match the
  longest recent n-gram suffix of the slot's prompt+generated tokens
  against an earlier occurrence and propose its continuation.  Zero
  model cost; wins on repetitive traffic (code, templated text, the
  repetition loops greedy decoding itself falls into).
* :class:`DraftModelDrafter` — greedy rollout through a (smaller) draft
  model's full forward.  With the target model as its own drafter
  ("self-draft") acceptance is exact — the degenerate upper bound the
  benchmarks and parity tests use.

The policy — how deep to speculate, and with which drafter — is exactly
the shape of knob this repo tunes: :class:`SpecDepthTunable`
(``serve.spec_depth``) prices the depth × drafter lattice with a modeled
acceptance-rate curve against verify FLOPs/KV traffic, and defends the
pick with real mixed-workload :class:`~repro.runtime.serve.Server`
drains via ``timed_server_drain`` under ``engine="measure"``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, ClassVar, Mapping, Protocol, Sequence, runtime_checkable

import numpy as np

from ..calibrate.spec import get_platform_spec
from ..core.search_space import Param, SearchSpace

DRAFTER_KINDS = ("ngram", "draft")


@runtime_checkable
class Drafter(Protocol):
    """Anything that proposes draft tokens for a slot.

    ``propose(tokens, depth)`` receives the slot's full known history
    (prompt + generated so far, INCLUDING the pending last token) and
    returns up to ``depth`` candidate continuations.  Returning fewer —
    or none — is fine: the server verifies whatever arrives and a
    zero-draft slot degrades to plain one-token decode through the same
    verify step."""

    name: str

    def propose(self, tokens: Sequence[int], depth: int) -> list[int]:
        ...


@dataclass
class NGramDrafter:
    """Self-speculative prompt-lookup drafting.

    Match the longest suffix n-gram (``ngram_max`` down to
    ``ngram_min`` tokens) of the history against its most recent
    earlier occurrence and propose the tokens that followed it.  Pure
    host-side list scanning — no model, no device work."""

    ngram_max: int = 3
    ngram_min: int = 1
    name: str = "ngram"

    def propose(self, tokens: Sequence[int], depth: int) -> list[int]:
        toks = list(tokens)
        L = len(toks)
        if depth <= 0 or L < 2:
            return []
        for n in range(min(self.ngram_max, L - 1), self.ngram_min - 1, -1):
            key = toks[L - n:]
            for i in range(L - n - 1, -1, -1):     # most recent match wins
                if toks[i:i + n] == key:
                    return toks[i + n:i + n + depth]
        return []


class DraftModelDrafter:
    """Greedy rollout through a draft model's full forward pass.

    ``api``/``params`` name the DRAFT model (its vocab must match the
    target's); passing the target model itself gives self-draft —
    acceptance is then exact, which makes it the tick-floor reference
    for benchmarks and the deterministic workhorse of the parity tests.
    Sequences are padded up to ``bucket`` multiples so the jitted
    forward compiles once per bucket, not once per length (causal
    masking makes tail padding inert).  Decoder-only LMs only — enc-dec
    drafting would need the request's frames."""

    def __init__(self, api, params, *, bucket: int = 32,
                 name: str = "draft"):
        if api.cfg.is_encdec:
            raise ValueError("DraftModelDrafter needs a decoder-only LM "
                             "draft model (enc-dec forwards need frames)")
        import jax
        self.api = api
        self.params = params
        self.bucket = max(1, bucket)
        self.name = name
        self._fwd = jax.jit(
            lambda p, toks: api.forward(p, {"tokens": toks}))

    def propose(self, tokens: Sequence[int], depth: int) -> list[int]:
        import jax.numpy as jnp
        toks = list(tokens)
        out: list[int] = []
        for _ in range(max(0, depth)):
            L = len(toks)
            S = -(-L // self.bucket) * self.bucket
            buf = np.zeros((1, S), np.int32)
            buf[0, :L] = toks
            logits = self._fwd(self.params, jnp.asarray(buf))
            nxt = int(jnp.argmax(logits[0, L - 1]))
            out.append(nxt)
            toks.append(nxt)
        return out


def make_drafter(kind: "str | Drafter", *, api=None, params=None,
                 **kw) -> Drafter:
    """Resolve a drafter spec: an existing :class:`Drafter` passes
    through (share one instance across servers to share its jit cache);
    ``"ngram"`` builds the prompt-lookup drafter; ``"draft"`` builds a
    :class:`DraftModelDrafter` from ``api``/``params`` (the target model
    itself by default — self-draft)."""

    if not isinstance(kind, str):
        if not hasattr(kind, "propose"):
            raise TypeError(f"not a Drafter: {kind!r}")
        return kind
    if kind == "ngram":
        return NGramDrafter(**kw)
    if kind == "draft":
        if api is None or params is None:
            raise ValueError("speculate='draft' needs api=/params= for "
                             "the draft model")
        return DraftModelDrafter(api, params, **kw)
    raise ValueError(f"unknown drafter {kind!r}; known: "
                     f"{', '.join(DRAFTER_KINDS)} or a Drafter instance")


# ---------------------------------------------------------------------------
# speculation-policy tuning (repro.tune)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SpecDepthTunable:
    """``repro.tune`` Tunable: the speculation policy —
    ``Server(speculate=<drafter>, spec_depth=<depth>)``.

    Depth trades **expected tokens per tick** against **verify cost**:
    with per-token acceptance probability ``a``, a depth-``d`` draft
    yields ``1 + a + a² + ... + a^d`` expected tokens per tick (the
    bonus token is free), saturating at ``1 + a/(1-a)`` — while the
    verify+commit forward pays FLOPs and KV-scatter traffic linear in
    ``d+1`` every tick, and a draft-model drafter adds ``d`` draft
    forwards on top.  The optimum is interior and depends on the
    drafter's acceptance curve, which only a real drain can settle:
    ``cost()`` models the drain in microseconds from ``accept_ngram`` /
    ``accept_draft``; with ``api``/``params`` attached, ``measure(cfg)``
    drains a real mixed workload through ``timed_server_drain`` and the
    measure engine returns the wall-clock winner.  The last measured
    drain's :meth:`Server.stats` snapshot (real proposed/accepted
    counts) lands in :attr:`last_stats` for provenance."""

    param_bytes: int
    layers: int
    d_model: int
    kv_width: int               # GQA cache width, n_kv_heads * hd
    context: int
    prompt_len: int
    requests: int
    mean_new: int
    batch: int = 4
    max_depth: int = 8
    drafters: tuple[str, ...] = DRAFTER_KINDS
    accept_ngram: float = 0.4   # modeled per-token acceptance rates
    accept_draft: float = 0.8
    draft_cost_ratio: float = 0.15  # draft forward cost vs target forward
    dispatch_s: float = 50e-6
    # hardware-in-the-loop handles: excluded from identity/caching
    api: Any = field(default=None, repr=False, compare=False)
    params: Any = field(default=None, repr=False, compare=False)
    draft_api: Any = field(default=None, repr=False, compare=False)
    draft_params: Any = field(default=None, repr=False, compare=False)
    name: ClassVar[str] = "serve.spec_depth"

    def __post_init__(self):
        # plan specs deliver JSON lists; the fingerprint and lattice
        # want a hashable tuple
        object.__setattr__(self, "drafters", tuple(self.drafters))
        unknown = [d for d in self.drafters if d not in DRAFTER_KINDS]
        if unknown or not self.drafters:
            raise ValueError(f"drafters must be drawn from "
                             f"{DRAFTER_KINDS}, got {self.drafters}")

    def space(self) -> SearchSpace:
        depths = []
        d = 1
        while d <= self.max_depth:
            depths.append(d)
            d *= 2
        return SearchSpace(params=[Param("depth", tuple(depths)),
                                   Param("drafter", tuple(self.drafters))])

    def _accept(self, drafter: str) -> float:
        return {"ngram": self.accept_ngram,
                "draft": self.accept_draft}[drafter]

    def tokens_per_tick(self, cfg: Mapping[str, Any]) -> float:
        """Modeled expected tokens per decode tick: the accepted-prefix
        geometric series plus the verifier's bonus token."""

        a = self._accept(str(cfg["drafter"]))
        d = int(cfg["depth"])
        return 1.0 + sum(a ** i for i in range(1, d + 1))

    def cost(self, cfg: Mapping[str, Any]) -> float:
        """Modeled microseconds to drain the load (same unit as
        ``measure``): decode ticks shrink by the expected tokens/tick,
        but each tick now runs TWO chunk forwards (score + commit, each
        streaming the weights once) over ``depth+1`` tokens, plus the
        drafter's own cost — ``d`` scaled-down forwards for a draft
        model, ~nothing for n-gram lookup."""

        d = int(cfg["depth"])
        drafter = str(cfg["drafter"])
        platform = get_platform_spec()
        n_params = self.param_bytes / 2            # bf16 weights
        weight_s = self.param_bytes / platform.hbm_bw
        from .tunables import kv_cache_stream_s
        kv_s = kv_cache_stream_s(self.batch, self.layers, self.context,
                                 self.kv_width)
        flops_s = (2 * n_params * (d + 1) * self.batch
                   / platform.peak_flops)
        spec_tick_s = 2 * (weight_s + flops_s) + kv_s + self.dispatch_s
        if drafter == "draft":
            draft_fwd_s = self.draft_cost_ratio * (
                weight_s
                + 2 * n_params * self.batch / platform.peak_flops)
            spec_tick_s += d * draft_fwd_s
        prefill_tick_s = (weight_s + kv_s + self.dispatch_s
                          + 2 * n_params * self.batch
                          / platform.peak_flops)
        decode_ticks = self.mean_new / self.tokens_per_tick(cfg)
        prefill_ticks = -(-self.prompt_len // 32)
        waves = -(-self.requests // self.batch)
        return waves * (prefill_ticks * prefill_tick_s
                        + decode_ticks * spec_tick_s) * 1e6

    def _build_drafter(self, drafter: str):
        if drafter == "draft":
            return make_drafter("draft", api=self.draft_api or self.api,
                                params=(self.draft_params
                                        if self.draft_api is not None
                                        else self.params))
        return make_drafter(drafter)

    def measure(self, cfg: Mapping[str, Any], *, warmup: int = 1,
                iters: int = 1) -> float:
        """Wall-clock microseconds to drain the mixed workload through a
        real speculating :class:`~repro.runtime.serve.Server` at this
        depth/drafter.  Prompts cycle a short pattern so the n-gram
        drafter sees the lookup structure real repetitive traffic has."""

        from .tunables import _require_model, timed_server_drain
        _require_model(self, "choose_spec_depth(..., params=...)")
        vocab = self.api.cfg.vocab
        period = 4
        prompts = [[(r + i % period) % (vocab - 1) + 1
                    for i in range(self.prompt_len)]
                   for r in range(self.requests)]
        stats: dict[str, float] = {}
        t = timed_server_drain(
            self.api, self.params, batch=self.batch, context=self.context,
            prompts=prompts, max_new=self.mean_new,
            speculate=self._build_drafter(str(cfg["drafter"])),
            spec_depth=int(cfg["depth"]), warmup=warmup, iters=iters,
            stats_out=stats)
        object.__setattr__(self, "last_stats", stats)
        return t

    def fingerprint(self) -> dict[str, Any]:
        fp = {f.name: getattr(self, f.name)
              for f in dataclasses.fields(self) if f.compare}
        fp["drafters"] = list(self.drafters)
        return {"tunable": self.name, "unit": "us", **fp}


def spec_depth_tunable(api, *, context: int, prompt_len: int,
                       requests: int, max_new: int, batch: int,
                       max_depth: int = 8, drafters=DRAFTER_KINDS,
                       params=None, draft_api=None,
                       draft_params=None) -> SpecDepthTunable:
    """The speculation-policy tunable for this model + expected load —
    the one place the sizing wiring lives (library ``choose_spec_depth``
    and the ``launch/serve --tune-spec`` CLI both build through
    here)."""

    prompt_len = max(1, min(prompt_len, context - max_new))
    return SpecDepthTunable(param_bytes=api.param_count() * 2,
                            layers=api.cfg.n_layers,
                            d_model=api.cfg.d_model,
                            kv_width=api.cfg.n_kv_heads * api.cfg.hd,
                            context=context, prompt_len=prompt_len,
                            requests=requests, mean_new=max_new,
                            batch=batch, max_depth=max_depth,
                            drafters=tuple(drafters), api=api,
                            params=params, draft_api=draft_api,
                            draft_params=draft_params)


def choose_spec_depth(api, *, context: int, prompt_len: int, requests: int,
                      max_new: int, batch: int, max_depth: int = 8,
                      drafters=DRAFTER_KINDS, cache="default", params=None,
                      draft_api=None, draft_params=None,
                      engine: str = "grid", **tune_kw):
    """Pick ``Server``'s speculation policy via ``repro.tune``; returns
    ``((depth, drafter), TuneResult)``.  ``engine="measure"`` (requires
    ``params``) shortlists policy points through the acceptance-curve
    model, then times real speculating drains and returns the
    wall-clock winner."""

    from ..tune import tune as _tune
    tb = spec_depth_tunable(api, context=context, prompt_len=prompt_len,
                            requests=requests, max_new=max_new, batch=batch,
                            max_depth=max_depth, drafters=drafters,
                            params=params, draft_api=draft_api,
                            draft_params=draft_params)
    res = _tune(tb, engine=engine, cache=cache, **tune_kw)
    return ((int(res.best_config["depth"]),
             str(res.best_config["drafter"])), res)


__all__ = ["Drafter", "NGramDrafter", "DraftModelDrafter", "make_drafter",
           "SpecDepthTunable", "spec_depth_tunable", "choose_spec_depth",
           "DRAFTER_KINDS"]
