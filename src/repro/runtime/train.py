"""Train-step builder: grad accumulation (microbatching), AdamW,
optional int8 error-feedback compression for pod-crossing gradients.

The returned ``train_step(state, batch)`` is a pure function suitable for
``jax.jit``/pjit; all distribution comes from shardings on its inputs +
the logical constraints inside the model.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..models.api import ModelAPI
from ..optim import (OptState, adamw_init, adamw_update, cosine_schedule,
                     ef_compress_grads)


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1          # gradient accumulation (tuning parameter)
    compress_pod_grads: bool = False


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    ef_residual: Any               # None unless compress_pod_grads


def init_train_state(api: ModelAPI, rng: jax.Array, tcfg: TrainConfig
                     ) -> TrainState:
    params = api.init(rng)
    residual = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                if tcfg.compress_pod_grads else None)
    return TrainState(params, adamw_init(params), residual)


def abstract_train_state(api: ModelAPI, tcfg: TrainConfig) -> TrainState:
    """ShapeDtypeStruct train state for dry-run lowering."""

    params = api.abstract()
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                   m=jax.tree.map(f32, params), v=jax.tree.map(f32, params))
    residual = jax.tree.map(f32, params) if tcfg.compress_pod_grads else None
    return TrainState(params, opt, residual)


def _split_microbatches(batch: dict, m: int) -> dict:
    def split(x):
        B = x.shape[0]
        assert B % m == 0, (B, m)
        return x.reshape(m, B // m, *x.shape[1:])
    return jax.tree.map(split, batch)


def build_train_step(api: ModelAPI, tcfg: TrainConfig
                     ) -> Callable[[TrainState, dict], tuple[TrainState, dict]]:
    lr = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.total_steps)
    grad_fn = jax.value_and_grad(api.loss)

    def train_step(state: TrainState, batch: dict):
        if tcfg.microbatches > 1:
            mb = _split_microbatches(batch, tcfg.microbatches)

            def acc(carry, one):
                loss_sum, g_sum = carry
                loss, g = grad_fn(state.params, one)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (loss_sum + loss, g_sum), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (loss_sum, gsum), _ = jax.lax.scan(acc, (jnp.zeros(()), zeros), mb)
            loss = loss_sum / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, gsum)
        else:
            loss, grads = grad_fn(state.params, batch)

        residual = state.ef_residual
        if tcfg.compress_pod_grads:
            grads, residual = ef_compress_grads(grads, residual)

        params, opt, metrics = adamw_update(
            state.params, grads, state.opt, lr=lr,
            weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm)
        metrics = dict(metrics, loss=loss)
        return TrainState(params, opt, residual), metrics

    return train_step


__all__ = ["TrainConfig", "TrainState", "init_train_state",
           "abstract_train_state", "build_train_step"]
