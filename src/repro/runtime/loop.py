"""Fault-tolerant training supervisor.

The loop is a supervised state machine designed for preemptible fleets:

* **checkpoint/restart** — resumes from the latest committed checkpoint
  (params, optimizer, step cursor); the data pipeline is deterministic in
  the step index, so a restart replays no data and skips none;
* **failure handling** — any exception from a step (including injected
  :class:`SimulatedFailure` — our stand-in for a lost pod) triggers
  restore-from-checkpoint and replay; ``max_restarts`` bounds crash
  loops;
* **straggler mitigation** — per-step wall time is tracked with an EWMA;
  a step slower than ``straggler_factor ×`` EWMA is flagged and
  *re-dispatched* (the step function is pure, so re-execution is safe —
  the single-host analogue of backup-task re-execution à la MapReduce /
  TPU hot spares).  Mitigation events are recorded in the history;
* **elastic rescale** — checkpoints are mesh-agnostic (full arrays), so
  a restart may pass a different ``place_fn`` (new mesh/sharding) and
  continue seamlessly; tested by reshaping an 8-device mesh between
  phases.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from ..checkpoint import CheckpointManager


class SimulatedFailure(RuntimeError):
    """Injected node/pod failure."""


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    redispatch_stragglers: bool = True


@dataclass
class History:
    losses: list[float] = field(default_factory=list)
    step_times: list[float] = field(default_factory=list)
    restarts: int = 0
    straggler_events: list[int] = field(default_factory=list)
    redispatched: int = 0
    resumed_from: list[int] = field(default_factory=list)


def run_training(
    *,
    step_fn: Callable[[Any, dict], tuple[Any, dict]],
    init_state: Any,
    batch_fn: Callable[[int], dict],
    cfg: LoopConfig,
    ckpt_dir: str | None = None,
    place_fn: Callable[[Any], Any] | None = None,
    inject: Callable[[int], None] | None = None,
) -> tuple[Any, History]:
    """Run ``total_steps`` of ``step_fn`` under supervision.

    ``inject(step)`` may raise SimulatedFailure or sleep (straggler) —
    the test hook for fault drills.  ``place_fn`` re-places a restored
    host-memory state onto the current mesh (elastic restarts)."""

    hist = History()
    mgr = (CheckpointManager(ckpt_dir, keep=cfg.keep,
                             save_interval=cfg.ckpt_every)
           if ckpt_dir else None)

    state = init_state
    start = 0
    if mgr is not None and mgr.latest_step() is not None:
        restored, manifest = mgr.restore(jax.tree.map(lambda x: x, state))
        state = place_fn(restored) if place_fn else restored
        start = manifest["step"]
        hist.resumed_from.append(start)

    step = start
    ewma = None
    warmed = False
    restarts = 0
    while step < cfg.total_steps:
        try:
            t0 = time.perf_counter()
            if inject is not None:
                inject(step)      # failures/stalls manifest inside the step
            batch = batch_fn(step)
            new_state, metrics = step_fn(state, batch)
            jax.block_until_ready(jax.tree.leaves(new_state)[0])
            dt = time.perf_counter() - t0

            # straggler detection + re-dispatch (the first measured step
            # includes jit compilation and must not seed the EWMA)
            if ewma is not None and dt > cfg.straggler_factor * ewma:
                hist.straggler_events.append(step)
                if cfg.redispatch_stragglers:
                    t1 = time.perf_counter()
                    new_state, metrics = step_fn(state, batch)
                    jax.block_until_ready(jax.tree.leaves(new_state)[0])
                    dt2 = time.perf_counter() - t1
                    hist.redispatched += 1
                    dt = min(dt, dt2)
            if warmed:
                ewma = dt if ewma is None else \
                    (1 - cfg.ewma_alpha) * ewma + cfg.ewma_alpha * dt
            warmed = True

            state = new_state
            step += 1
            hist.losses.append(float(metrics.get("loss", np.nan)))
            hist.step_times.append(dt)

            if mgr is not None and mgr.should_save(step):
                mgr.save(step, state)     # async
        except SimulatedFailure:
            restarts += 1
            hist.restarts = restarts
            if restarts > cfg.max_restarts:
                raise
            if mgr is None:
                # no checkpointing: restart from the initial state
                state, step = init_state, 0
                continue
            mgr.wait()
            latest = mgr.latest_step()
            if latest is None:
                state, step = init_state, 0
                continue
            restored, manifest = mgr.restore(jax.tree.map(lambda x: x, state))
            state = place_fn(restored) if place_fn else restored
            step = manifest["step"]
            hist.resumed_from.append(step)

    if mgr is not None:
        mgr.save(cfg.total_steps, state, blocking=True)
    return state, hist


__all__ = ["run_training", "LoopConfig", "History", "SimulatedFailure"]
