"""repro — model-checking-based auto-tuning for a multi-pod JAX/TPU
framework (reproduction + TPU-native extension of Garanina, Staroletov &
Gorlatch, "Auto-Tuning High-Performance Programs Using Model Checking in
Promela", 2023).

Subpackages: core (the paper's contribution), models, configs, kernels,
data, optim, checkpoint, runtime, distribute, launch.
"""

__version__ = "1.0.0"
