"""Cell lowering: one (architecture × shape × mesh) dry-run unit.

Used by launch/dryrun.py (which sets the 512-device XLA flag first) and
by the roofline/benchmark tooling.  Produces, per cell:

* lower + compile success (the multi-pod dry-run deliverable),
* ``memory_analysis`` (per-device bytes: argument/output/temp/peak),
* ``cost_analysis``   (per-device HLO FLOPs + bytes accessed),
* collective-bytes breakdown parsed from the compiled HLO,
* the roofline terms (see repro/launch/roofline.py).
"""

from __future__ import annotations

import dataclasses
import json
import re
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from ..configs import SHAPES, get_config, supports
from ..configs.base import ArchConfig, ShapeSpec
from ..data.pipeline import make_batch_specs
from ..distribute.sharding import (Rules, default_rules, shard_like,
                                   tree_shardings, use_mesh)
from ..models.api import build_model
from ..models.common import abstract_params, axes_tree
from ..runtime.train import (TrainConfig, abstract_train_state,
                             build_train_step)


def rules_for_arch(cfg: ArchConfig, *, multi_pod: bool = False,
                   overrides: dict | None = None) -> Rules:
    """Arch-aware default rules (DESIGN.md §4): MoE archs whose expert
    count does not divide the model axis shard each expert's d_ff
    instead (mixtral: 8 experts, 16-way model -> expert_mlp)."""

    rules = default_rules(multi_pod)
    if cfg.moe is not None and cfg.moe.num_experts % 16 != 0:
        rules = rules.replace(experts=None, expert_mlp="model")
    if overrides:
        rules = rules.replace(**overrides)
    return rules


def batch_axes(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    out = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.family == "vlm":
        out["img_embeds"] = ("batch", None, None)
    if cfg.is_encdec:
        out["frames"] = ("batch", None, None)
    return out


@dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str                   # ok | skipped | failed
    reason: str = ""
    n_devices: int = 0
    lower_s: float = 0.0
    compile_s: float = 0.0
    memory: dict = field(default_factory=dict)
    cost: dict = field(default_factory=dict)
    collectives: dict = field(default_factory=dict)
    param_count: int = 0
    settings: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-type (count, result bytes) of collective ops in an HLO module.

    Result-shape bytes approximate the payload: exact for all-reduce and
    collective-permute, the gathered size for all-gather, N× the output
    for reduce-scatter (documented in EXPERIMENTS.md §Roofline)."""

    out: dict[str, dict[str, int]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, op = m.group(1), m.group(2)
        b = _type_bytes(type_str)
        rec = out.setdefault(op, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        out["peak_hbm_bytes"] = (out["argument_size_in_bytes"]
                                 + out["output_size_in_bytes"]
                                 + out["temp_size_in_bytes"]
                                 - out.get("alias_size_in_bytes", 0))
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out = {}
    for k in ("flops", "bytes accessed", "optimal_seconds"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    # per-operand byte accesses if present
    extra = {k: float(v) for k, v in ca.items()
             if k.startswith("bytes accessed")}
    if extra:
        out["bytes_accessed_detail"] = {k: v for k, v in sorted(extra.items())}
    return out


def auto_microbatches(cfg: ArchConfig, shape: ShapeSpec, n_dp: int,
                      budget_bytes: float = 4e9) -> int:
    """Machine-model-driven default gradient-accumulation factor: the
    remat'd scan saves one carry per block; pick the smallest power of
    two keeping the per-device saved-activation stack under budget.
    (This is the auto-tuner's memory-term lever applied as a default —
    the §Perf loop refines it per cell.)"""

    from ..models.transformer import _block_plan
    _, n_blocks = _block_plan(cfg)
    b_loc = max(1, shape.global_batch // n_dp)
    carry = n_blocks * b_loc * shape.seq_len * cfg.d_model * 2
    if cfg.ssm is not None:   # SSD intra-chunk tensors are heavier
        carry *= 2
    if cfg.is_encdec:         # decoder+cross stacks and encoder residency
        carry *= 6
    mb = 1
    while carry / mb > budget_bytes and mb < b_loc:
        mb *= 2
    return mb


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               rules_overrides: dict | None = None,
               tcfg: TrainConfig | None = None,
               remat: str | None = None,
               logits_dtype: str | None = None,
               cfg_overrides: dict | None = None,
               capture_hlo: bool = False,
               mesh=None) -> CellResult:
    from .mesh import make_production_mesh

    cfg = get_config(arch)
    if remat:
        cfg = cfg.replace(remat=remat)
    if logits_dtype:
        cfg = cfg.replace(logits_dtype=logits_dtype)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name, status="ok",
                     settings={"remat": cfg.remat,
                               "logits_dtype": cfg.logits_dtype,
                               "rules_overrides": rules_overrides or {},
                               "microbatches": tcfg.microbatches if tcfg else 1})

    ok, why = supports(cfg, shape)
    if not ok:
        res.status, res.reason = "skipped", why
        return res

    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    res.n_devices = mesh.devices.size
    rules = rules_for_arch(cfg, multi_pod=multi_pod,
                           overrides=rules_overrides)
    api = build_model(cfg)
    res.param_count = api.param_count()

    try:
        with use_mesh(mesh, rules):
            if shape.kind == "train":
                tc = tcfg or TrainConfig(microbatches=0)
                if tc.microbatches == 0:
                    n_dp = 32 if multi_pod else 16
                    tc = dataclasses.replace(
                        tc, microbatches=auto_microbatches(cfg, shape, n_dp))
                res.settings["microbatches"] = tc.microbatches
                state = abstract_train_state(api, tc)
                step = build_train_step(api, tc)
                state_axes = api.axes()
                from ..runtime.train import TrainState
                from ..optim.adamw import OptState
                st_ax = TrainState(
                    params=state_axes,
                    opt=OptState(step=(), m=state_axes, v=state_axes),
                    ef_residual=state_axes if tc.compress_pod_grads else None)
                st_sh = shard_like(state, st_ax, mesh, rules)
                batch = make_batch_specs(cfg, shape)
                b_sh = shard_like(batch, batch_axes(cfg, shape), mesh, rules)
                fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None),
                             donate_argnums=(0,))
                t0 = time.perf_counter()
                lowered = fn.lower(state, batch)
                res.lower_s = time.perf_counter() - t0
            elif shape.kind == "prefill":
                params = api.abstract()
                p_sh = shard_like(params, api.axes(), mesh, rules)
                batch = make_batch_specs(cfg, shape)
                batch.pop("labels")
                b_sh = shard_like(batch, {k: v for k, v in batch_axes(
                    cfg, shape).items() if k in batch}, mesh, rules)

                def prefill(params, batch):
                    logits = api.forward(params, batch)
                    return logits[:, -1]   # serving prefill emits last token

                fn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
                t0 = time.perf_counter()
                lowered = fn.lower(params, batch)
                res.lower_s = time.perf_counter() - t0
            else:  # decode
                params = api.abstract()
                p_sh = shard_like(params, api.axes(), mesh, rules)
                B = shape.global_batch
                dspecs = api.decode_state_specs(B, shape.seq_len)
                dstate = abstract_params(dspecs)
                d_sh = shard_like(dstate, axes_tree(dspecs), mesh, rules)
                from ..distribute.sharding import arg_sharding
                tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
                tok_sh = arg_sharding((B, 1), ("batch", None), mesh, rules)
                # (B,) per-slot position vector — the continuous-batching
                # server's actual feed; a scalar spec lowered a different
                # decode_step than serving runs
                cur = jax.ShapeDtypeStruct((B,), jnp.int32)
                cur_sh = arg_sharding((B,), ("batch",), mesh, rules)

                def serve_step(params, state, tokens, cur_len):
                    return api.decode_step(params, state, tokens, cur_len)

                fn = jax.jit(serve_step,
                             in_shardings=(p_sh, d_sh, tok_sh, cur_sh),
                             out_shardings=(None, d_sh),
                             donate_argnums=(1,))
                t0 = time.perf_counter()
                lowered = fn.lower(params, dstate, tok, cur)
                res.lower_s = time.perf_counter() - t0

            t0 = time.perf_counter()
            compiled = lowered.compile()
            res.compile_s = time.perf_counter() - t0
            res.memory = _memory_dict(compiled)
            res.cost = _cost_dict(compiled)
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
            res.collectives = collective_bytes(hlo)
            if capture_hlo:
                res.settings["hlo_len"] = len(hlo)
    except Exception as e:
        res.status = "failed"
        res.reason = f"{type(e).__name__}: {e}"
    return res


def lower_block_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                     part: str = "decoder",
                     rules_overrides: dict | None = None,
                     remat: str | None = None,
                     cfg_overrides: dict | None = None,
                     mesh=None) -> CellResult:
    """Lower ONE scan block (fwd, or fwd+bwd for train shapes) under the
    same mesh/shardings as the full module.

    XLA's HloCostAnalysis counts a while-loop body once regardless of the
    trip count (verified by tests/test_dryrun), so per-cell roofline
    totals are composed as  module + (trips - 1) x block  -- see
    repro/launch/roofline.py.  ``settings["trips"]`` holds the trip
    count."""

    from .mesh import make_production_mesh
    from ..distribute.sharding import arg_sharding
    from ..models import attention as attn_mod
    from ..models import transformer as tfm
    from ..models.api import make_decode_body
    from ..models.common import PSpec, rms_norm
    from ..models.transformer import _block_plan, _remat, layer_forward

    cfg = get_config(arch)
    if remat:
        cfg = cfg.replace(remat=remat)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    res = CellResult(arch=arch, shape=shape_name, mesh=mesh_name,
                     status="ok", settings={"part": part,
                                            "remat": cfg.remat})
    ok, why = supports(cfg, shape)
    if not ok:
        res.status, res.reason = "skipped", why
        return res

    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    res.n_devices = mesh.devices.size
    rules = rules_for_arch(cfg, multi_pod=multi_pod,
                           overrides=rules_overrides)
    api = build_model(cfg)

    if part == "encoder":
        kinds, trips = ["encoder"], cfg.encoder_layers
        seq = shape.seq_len if shape.kind == "train" else cfg.enc_seq
        seq = cfg.enc_seq
    else:
        kinds, trips = _block_plan(cfg)
        seq = shape.seq_len
    res.settings["trips"] = trips

    B = shape.global_batch
    d = cfg.d_model
    block_specs: Any = {f"{i}_{kind}": tfm.layer_specs(cfg, kind)
                        for i, kind in enumerate(kinds)}
    encdec_dec = cfg.is_encdec and part == "decoder"
    if encdec_dec:
        block_specs = (block_specs,
                       {"x": attn_mod.attn_specs(cfg, cross=True),
                        "ln_x": tfm._norm_spec(cfg)})

    x_axes = ("batch", None, None)

    def block_fwd(bp, x, extras):
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32), (B, x.shape[1]))
        enc_out = extras.get("enc_out")
        if encdec_dec:
            dp, xp = bp
            h = layer_forward(dp["0_dense"], cfg, "dense", x, positions)
            a = attn_mod.attention(xp["x"], cfg, rms_norm(h, xp["ln_x"]),
                                   positions, x_kv=enc_out)
            return h + a
        h = x
        for i, kind in enumerate(kinds):
            h = layer_forward(bp[f"{i}_{kind}"], cfg, kind, h, positions,
                              enc_out=enc_out, causal=(part != "encoder"))
        return h

    try:
        with use_mesh(mesh, rules):
            if shape.kind in ("train", "prefill"):
                bp = abstract_params(block_specs)
                bp_sh = shard_like(bp, axes_tree(block_specs), mesh, rules)
                x = jax.ShapeDtypeStruct((B, seq, d), jnp.bfloat16)
                x_sh = arg_sharding((B, seq, d), x_axes, mesh, rules)
                extras, extra_sh = {}, {}
                if cfg.family == "vlm" and part == "decoder":
                    n = cfg.n_img_tokens
                    extras["enc_out"] = jax.ShapeDtypeStruct(
                        (B, n, d), jnp.bfloat16)
                    extra_sh["enc_out"] = arg_sharding((B, n, d), x_axes,
                                                       mesh, rules)
                if encdec_dec:
                    n = cfg.enc_seq
                    extras["enc_out"] = jax.ShapeDtypeStruct(
                        (B, n, d), jnp.bfloat16)
                    extra_sh["enc_out"] = arg_sharding((B, n, d), x_axes,
                                                       mesh, rules)

                if shape.kind == "train":
                    def train_block(bp, x, ct, extras):
                        f = _remat(cfg, lambda b, y: block_fwd(b, y, extras))
                        out, vjp = jax.vjp(f, bp, x)
                        dbp, dx = vjp(ct)
                        return out, dbp, dx

                    fn = jax.jit(train_block,
                                 in_shardings=(bp_sh, x_sh, x_sh, extra_sh))
                    args = (bp, x, x, extras)
                else:
                    fn = jax.jit(block_fwd,
                                 in_shardings=(bp_sh, x_sh, extra_sh))
                    args = (bp, x, extras)
            else:  # decode block
                bp = abstract_params(block_specs)
                bp_sh = shard_like(bp, axes_tree(block_specs), mesh, rules)
                cspecs = api.decode_block_specs(B, shape.seq_len)
                cache = abstract_params(cspecs)
                c_sh = shard_like(cache, axes_tree(cspecs), mesh, rules)
                x = jax.ShapeDtypeStruct((B, 1, d), jnp.bfloat16)
                x_sh = arg_sharding((B, 1, d), x_axes, mesh, rules)

                if encdec_dec:
                    Hkv, hd = cfg.n_kv_heads, cfg.hd
                    xkv_specs = {
                        "k": PSpec((B, Hkv, cfg.enc_seq, hd),
                                   ("cache_batch", "kv_heads", None, None),
                                   init="zeros"),
                        "v": PSpec((B, Hkv, cfg.enc_seq, hd),
                                   ("cache_batch", "kv_heads", None, None),
                                   init="zeros")}
                    xkv = abstract_params(xkv_specs)
                    xkv_sh = shard_like(xkv, axes_tree(xkv_specs), mesh,
                                        rules)

                    def decode_block(bp, cache, xkv, x):
                        dp, xp = bp
                        body = make_decode_body(cfg, kinds, jnp.int32(7))
                        return body(x, (dp, cache, xp, xkv))

                    fn = jax.jit(decode_block,
                                 in_shardings=(bp_sh, c_sh, xkv_sh, x_sh))
                    args = (bp, cache, xkv, x)
                else:
                    def decode_block(bp, cache, x):
                        body = make_decode_body(cfg, kinds, jnp.int32(7))
                        return body(x, (bp, cache))

                    fn = jax.jit(decode_block,
                                 in_shardings=(bp_sh, c_sh, x_sh))
                    args = (bp, cache, x)

            t0 = time.perf_counter()
            lowered = fn.lower(*args)
            res.lower_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            compiled = lowered.compile()
            res.compile_s = time.perf_counter() - t0
            res.memory = _memory_dict(compiled)
            res.cost = _cost_dict(compiled)
            try:
                hlo = compiled.as_text()
            except Exception:
                hlo = lowered.as_text()
            res.collectives = collective_bytes(hlo)
    except Exception as e:
        res.status = "failed"
        res.reason = f"{type(e).__name__}: {e}"
    return res


__all__ = ["lower_cell", "lower_block_cell", "CellResult",
           "collective_bytes", "rules_for_arch", "batch_axes"]
