import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lower a cell under a named variant and report
the roofline terms (module + block composition).

  PYTHONPATH=src python -m repro.launch.perf --arch mamba2-2.7b \
      --shape train_4k --variant baseline
  PYTHONPATH=src python -m repro.launch.perf --arch mamba2-2.7b \
      --shape train_4k --cfg '{"ssd_dtype": "bfloat16"}' --name ssd_bf16

Each run appends a JSON line to results/perf_log.jsonl so the iteration
history is machine-readable."""

import argparse
import json
import time

from repro.launch.cells import lower_block_cell, lower_cell
from repro.launch.roofline import analyze
from repro.runtime.train import TrainConfig


def run_variant(arch: str, shape: str, name: str, *,
                cfg_overrides: dict | None = None,
                rules_overrides: dict | None = None,
                remat: str | None = None,
                logits_dtype: str | None = None,
                microbatches: int = 0,
                out_path: str = "results/perf_log.jsonl") -> dict:
    tcfg = TrainConfig(microbatches=microbatches)
    t0 = time.perf_counter()
    res = lower_cell(arch, shape, tcfg=tcfg, remat=remat,
                     logits_dtype=logits_dtype, cfg_overrides=cfg_overrides,
                     rules_overrides=rules_overrides)
    rec = res.to_json()
    if res.status == "ok":
        blk = lower_block_cell(arch, shape, remat=remat,
                               cfg_overrides=cfg_overrides,
                               rules_overrides=rules_overrides)
        rec["block"] = blk.to_json()
        from repro.configs import get_config
        if get_config(arch).is_encdec:
            rec["enc_block"] = lower_block_cell(
                arch, shape, part="encoder", remat=remat,
                cfg_overrides=cfg_overrides,
                rules_overrides=rules_overrides).to_json()
    r = analyze(rec)
    out = {
        "variant": name, "arch": arch, "shape": shape,
        "status": res.status, "reason": res.reason[:200],
        "compute_ms": r.compute_s * 1e3, "memory_ms": r.memory_s * 1e3,
        "collective_ms": r.collective_s * 1e3, "dominant": r.dominant,
        "useful": r.useful_ratio, "mfu": r.mfu,
        "peak_hbm_gib": r.peak_hbm_gib,
        "temp_gib": res.memory.get("temp_size_in_bytes", 0) / 2**30,
        "settings": res.settings, "wall_s": time.perf_counter() - t0,
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "a") as f:
        f.write(json.dumps(out) + "\n")
    print(json.dumps(out, indent=1))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--name", default="baseline")
    ap.add_argument("--cfg", default="", help="JSON ArchConfig overrides")
    ap.add_argument("--rules", default="", help="JSON rules overrides")
    ap.add_argument("--remat", default="")
    ap.add_argument("--logits-dtype", default="")
    ap.add_argument("--microbatches", type=int, default=0)
    args = ap.parse_args()
    run_variant(args.arch, args.shape, args.name,
                cfg_overrides=json.loads(args.cfg) if args.cfg else None,
                rules_overrides=json.loads(args.rules) if args.rules else None,
                remat=args.remat or None,
                logits_dtype=args.logits_dtype or None,
                microbatches=args.microbatches)


if __name__ == "__main__":
    main()
