import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles every (architecture × input shape) cell on the
production meshes — (16, 16) single pod and (2, 16, 16) multi-pod — and
records memory_analysis / cost_analysis / collective schedule per cell.

The XLA flag above MUST precede every other import (jax locks the device
count at first init); smoke tests and benches never import this module.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
      --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""

import argparse
import json
import sys
import time

from repro.configs import ARCHS, SHAPES
from repro.launch.cells import lower_cell
from repro.runtime.train import TrainConfig


def run(args) -> int:
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ([True] if args.multi_pod else
              [False] if args.single_pod else [False, True])

    from repro.configs import get_config
    from repro.launch.cells import lower_block_cell

    results = []
    failed = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                t0 = time.perf_counter()
                tcfg = TrainConfig(microbatches=args.microbatches)
                overrides = json.loads(args.rules) if args.rules else None
                res = lower_cell(
                    arch, shape, multi_pod=multi_pod, tcfg=tcfg,
                    remat=args.remat or None,
                    logits_dtype=args.logits_dtype or None,
                    rules_overrides=overrides)
                rec = res.to_json()
                # block-level cost lowering for scan-aware composition
                if res.status == "ok" and not args.no_blocks:
                    blk = lower_block_cell(
                        arch, shape, multi_pod=multi_pod,
                        remat=args.remat or None, rules_overrides=overrides)
                    rec["block"] = blk.to_json()
                    if get_config(arch).is_encdec:
                        enc = lower_block_cell(
                            arch, shape, multi_pod=multi_pod, part="encoder",
                            remat=args.remat or None,
                            rules_overrides=overrides)
                        rec["enc_block"] = enc.to_json()
                rec["wall_s"] = time.perf_counter() - t0
                results.append(rec)
                ok = res.status
                mem = res.memory.get("temp_size_in_bytes", 0) / 2**30
                flops = res.cost.get("flops", 0)
                coll = res.collectives.get("total_bytes", 0) / 2**30
                print(f"[{res.mesh}] {arch:26s} {shape:12s} {ok:8s} "
                      f"lower={res.lower_s:6.1f}s compile={res.compile_s:6.1f}s "
                      f"temp={mem:7.2f}GiB flops/dev={flops:.3e} "
                      f"coll={coll:7.2f}GiB {res.reason[:90]}",
                      flush=True)
                if res.status == "failed":
                    failed += 1
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    print(f"\n{len(results)} cells, {failed} failed", flush=True)
    return 1 if failed else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = auto (machine-model default)")
    ap.add_argument("--remat", default="")
    ap.add_argument("--logits-dtype", default="")
    ap.add_argument("--rules", default="", help="JSON rules overrides")
    ap.add_argument("--no-blocks", action="store_true",
                    help="skip block-level cost lowering")
    ap.add_argument("--out", default="")
    sys.exit(run(ap.parse_args()))


if __name__ == "__main__":
    main()
