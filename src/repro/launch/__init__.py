"""Launch layer: production meshes, dry-run driver, train/serve CLIs.

NOTE: do not import repro.launch.dryrun from tests — it forces the
512-device XLA flag at import time (by design)."""
