"""Roofline analysis (deliverable g) over dry-run records.

Per (arch × shape × mesh) cell:

    compute_term    = flops_per_device / PEAK_FLOPS
    memory_term     = hbm_bytes_per_device / HBM_BW
    collective_term = collective_bytes_per_device / (LINKS × LINK_BW)

Hardware constants (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI with 4 links usable per chip in a 2-D torus (we charge
the *sum over collective payloads traversing the chip's links*, i.e.
bytes / (links × bw) — a deliberately simple model, same spirit as the
paper's GMT ratio).

Scan-aware composition: XLA cost analysis counts a while body once, so
totals are ``module + (trips − 1) × block`` using the block-level
lowering shipped alongside every cell record (and ``enc_block`` with its
own trip count for the enc-dec arch).

MODEL_FLOPS = 6·N·D for dense training (N params, D tokens), 6·N_active·D
for MoE, 2·N·D for pure forward (prefill), 2·N_active·B for one decode
step.  The ratio MODEL_FLOPS / HLO_FLOPS measures how much compiled
compute is "useful" — remat recompute, attention (excluded from 6ND by
convention), MoE dispatch and padding all show up here.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Any

from ..calibrate.spec import DEFAULT_SPEC, get_platform_spec
from ..configs import SHAPES, get_config

# Datasheet aliases — the single definition lives in
# calibrate.spec.DEFAULT_SPEC (previously re-declared here verbatim).
# analyze() resolves LIVE constants via get_platform_spec() so a
# calibration artifact reprices the roofline terms.
PEAK_FLOPS = DEFAULT_SPEC.peak_flops   # bf16 FLOP/s per chip
HBM_BW = DEFAULT_SPEC.hbm_bw           # bytes/s per chip
LINK_BW = DEFAULT_SPEC.link_bw         # bytes/s per ICI link
LINKS = DEFAULT_SPEC.links             # usable links per chip (2-D torus)


def active_params(arch: str) -> int:
    """Parameters touched per token (MoE: top-k experts + shared)."""

    cfg = get_config(arch)
    from ..models.api import build_model
    total = build_model(cfg).param_count()
    if cfg.moe is None:
        return total
    m = cfg.moe
    # expert params per MoE layer
    per_expert = 3 * cfg.d_model * cfg.d_ff
    n_moe_layers = cfg.n_layers // m.every
    expert_total = n_moe_layers * m.num_experts * per_expert
    expert_active = n_moe_layers * m.top_k * per_expert
    return total - expert_total + expert_active


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = active_params(arch)
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * n_act * D
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * n_act * D
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def _scaled(rec: dict, key_path: tuple[str, ...], trips_minus_1_blocks:
            list[tuple[dict, int]]) -> float:
    def get(d, path):
        for k in path:
            d = d.get(k, {}) if isinstance(d, dict) else {}
        return d if isinstance(d, (int, float)) else 0.0

    total = get(rec, key_path)
    for blk, extra_trips in trips_minus_1_blocks:
        total += extra_trips * get(blk, key_path)
    return total


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    hlo_flops_per_dev: float = 0.0
    hbm_bytes_per_dev: float = 0.0
    coll_bytes_per_dev: float = 0.0
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    peak_hbm_gib: float = 0.0
    step_time_s: float = 0.0          # max of the three terms
    mfu: float = 0.0                  # model_flops/(devices*peak*step_time)
    note: str = ""

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} | "
                f"{self.collective_s*1e3:.2f} | {self.dominant} | "
                f"{self.useful_ratio:.2f} | {self.mfu*100:.1f}% |")


def analyze(rec: dict, *, spec=None) -> Roofline:
    r = Roofline(arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
                 status=rec["status"])
    if rec["status"] != "ok":
        r.note = rec.get("reason", "")
        return r
    if spec is None:
        spec = get_platform_spec()

    comp = []
    blk = rec.get("block")
    if blk and blk.get("status") == "ok":
        comp.append((blk, max(0, blk["settings"]["trips"] - 1)))
    enc = rec.get("enc_block")
    if enc and enc.get("status") == "ok":
        comp.append((enc, max(0, enc["settings"]["trips"] - 1)))

    n_dev = rec.get("n_devices", 256)
    r.hlo_flops_per_dev = _scaled(rec, ("cost", "flops"), comp)
    r.hbm_bytes_per_dev = _scaled(rec, ("cost", "bytes_accessed"), comp)
    coll_total = _scaled(rec, ("collectives", "total_bytes"), comp)
    # HLO shapes inside an SPMD module are per-device shards already.
    r.coll_bytes_per_dev = coll_total

    r.compute_s = r.hlo_flops_per_dev / spec.peak_flops
    r.memory_s = r.hbm_bytes_per_dev / spec.hbm_bw
    r.collective_s = r.coll_bytes_per_dev / spec.ici_bw
    terms = {"compute": r.compute_s, "memory": r.memory_s,
             "collective": r.collective_s}
    r.dominant = max(terms, key=terms.get)
    r.step_time_s = max(terms.values())

    r.model_flops = model_flops(rec["arch"], rec["shape"])
    total_hlo = r.hlo_flops_per_dev * n_dev
    r.useful_ratio = r.model_flops / total_hlo if total_hlo else 0.0
    if r.step_time_s > 0:
        r.mfu = r.model_flops / (n_dev * spec.peak_flops * r.step_time_s)
    r.peak_hbm_gib = rec.get("memory", {}).get("peak_hbm_bytes", 0) / 2**30
    return r


def analyze_file(path: str) -> list[Roofline]:
    with open(path) as f:
        records = json.load(f)
    return [analyze(rec) for rec in records]


def what_moves_it(r: Roofline) -> str:
    """One-sentence lever on the dominant term (per-cell heuristic)."""

    if r.dominant == "compute":
        if r.useful_ratio < 0.5:
            return ("compute is mostly non-model work (remat/attention/"
                    "dispatch): relax remat policy or cut dispatch/"
                    "mask overheads")
        return "compute-bound at high useful ratio: already near roofline"
    if r.dominant == "memory":
        return ("HBM-bound: raise arithmetic intensity — bigger per-device "
                "batch, fuse CE/softmax, drop f32 intermediates")
    return ("collective-bound: reshard to cut the dominant collective "
            "(FSDP vs TP trade, gradient compression on the pod axis, "
            "overlap via microbatching)")


__all__ = ["analyze", "analyze_file", "Roofline", "model_flops",
           "active_params", "what_moves_it", "PEAK_FLOPS", "HBM_BW",
           "LINK_BW", "LINKS"]
