"""Production mesh builders.

Functions (not module constants) so importing never touches jax device
state.  The production target is TPU v5e pods: 16×16 = 256 chips per
pod; the multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips,
pod axis crossing DCI).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices=None):
    """Tiny mesh over whatever devices exist (tests)."""

    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    dp = max(1, n // 2)
    tp = n // dp
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:dp * tp]).reshape(dp, tp),
                ("data", "model"))


__all__ = ["make_production_mesh", "make_smoke_mesh"]
