"""End-to-end training driver.

Runs real training on the available devices (CPU here; the same code
path pjit-shards on a TPU fleet).  ``--preset smoke`` uses the reduced
config; ``--tune`` asks the model-checking auto-tuner for the
distributed configuration (microbatches/remat/FSDP/compression) before
building the step function — the paper's method as a first-class
framework feature.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --preset smoke --steps 200 --batch 32 --seq 64
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --preset smoke --steps 50 --tune --inject-failure 20 \
      --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import SHAPES, get_config
from ..configs.base import ShapeSpec
from ..core.tpu_machine import TPUWorkload
from ..data import DataConfig, SyntheticLM
from ..models import build_model
from ..runtime import (LoopConfig, SimulatedFailure, TrainConfig,
                       build_train_step, init_train_state, run_training)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--tune", action="store_true",
                    help="pick distributed config via the auto-tuner")
    ap.add_argument("--use-flash", action="store_true",
                    help="route full-seq self-attention through the "
                         "@autotune'd Pallas flash kernel")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="simulate a pod failure at this step")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    if args.use_flash:
        cfg = cfg.replace(use_flash=True)
    api = build_model(cfg)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")

    microbatches = args.microbatches
    remat = cfg.remat
    if args.tune:
        import math

        from ..tune import TuningPlan
        w = TPUWorkload(params=api.param_count(),
                        active_params=api.param_count(),
                        layers=cfg.n_layers, d_model=cfg.d_model,
                        seq=args.seq, global_batch=args.batch,
                        vocab=cfg.vocab)
        plan = TuningPlan(name=f"train.{args.arch}")
        plan.add(w.tunable(chips_per_pod=max(len(jax.devices()), 1)),
                 engine="grid", label="distributed-config")
        report = plan.run(progress=lambda s: print(f"[tune] {s}"))
        job = report.results[0]
        if job.status == "failed":
            raise RuntimeError(f"tuning failed: {job.error}")
        res = job.result
        if not math.isfinite(res.t_min):
            raise RuntimeError("no feasible configuration fits HBM")
        best = res.best_config
        microbatches = min(best["microbatches"], args.batch)
        remat = best["remat"]
        cfg = cfg.replace(remat=remat)
        api = build_model(cfg)
        print(f"[tune] config: microbatches={microbatches} remat={remat} "
              f"fsdp={best['fsdp']} modeled step={res.t_min*1e3:.2f} ms "
              f"(engine={res.engine}, cache {job.status})")

    tcfg = TrainConfig(lr=args.lr, warmup=max(2, args.steps // 20),
                       total_steps=args.steps, microbatches=microbatches)
    state = init_train_state(api, jax.random.PRNGKey(args.seed), tcfg)
    step = jax.jit(build_train_step(api, tcfg))
    data = SyntheticLM(cfg, shape, DataConfig(seed=args.seed))

    inject = None
    if args.inject_failure >= 0:
        fail_at = {args.inject_failure}

        def inject(s):
            if s in fail_at:
                fail_at.clear()
                print(f"[inject] simulated pod failure at step {s}")
                raise SimulatedFailure(f"injected at {s}")

    t0 = time.perf_counter()
    state, hist = run_training(
        step_fn=step, init_state=state, batch_fn=data.batch,
        cfg=LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every),
        ckpt_dir=args.ckpt_dir or None, inject=inject)
    wall = time.perf_counter() - t0

    print(f"steps={len(hist.losses)} wall={wall:.1f}s "
          f"mean_step={np.mean(hist.step_times)*1e3:.1f}ms "
          f"restarts={hist.restarts} stragglers={len(hist.straggler_events)}")
    print(f"loss: first={hist.losses[0]:.4f} last={hist.losses[-1]:.4f} "
          f"min={min(hist.losses):.4f}")


if __name__ == "__main__":
    main()
