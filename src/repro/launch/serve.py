"""Batched serving driver: continuous batching over decode slots.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --preset smoke --requests 12 --batch 4 --context 64 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import build_model
from ..runtime.scheduler import SCHEDULER_KINDS
from ..runtime.serve import Server
from ..runtime.speculate import DRAFTER_KINDS, spec_depth_tunable
from ..runtime.tunables import (decode_batch_tunable, kv_page_tunable,
                                prefill_chunk_tunable, scheduler_tunable)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--context", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per chunked-prefill tick")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: slots share a page pool instead "
                         "of reserving a full context-length ring each")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="pool size in pages (default: full per-slot "
                         "backing, batch * ceil(context/page))")
    ap.add_argument("--scheduler", choices=list(SCHEDULER_KINDS),
                    default=None,
                    help="serving policy: fcfs (default), priority "
                         "(SLO classes, preemptive), or prefix "
                         "(prefix-affinity admission)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="copy-on-write KV prefix sharing across slots "
                         "(implies --paged)")
    ap.add_argument("--speculate", choices=list(DRAFTER_KINDS), default=None,
                    help="speculative decoding drafter: 'ngram' "
                         "(prompt-lookup, free) or 'draft' (self-draft "
                         "model rollout)")
    ap.add_argument("--spec-depth", type=int, default=4,
                    help="draft tokens verified per speculative tick")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="record lifecycle/tick spans and write the "
                         "repro.obs trace artifact (Perfetto-loadable) "
                         "here; with --paged the online conformance "
                         "monitor validates the allocator op stream")
    ap.add_argument("--metrics", action="store_true",
                    help="print the drain's metrics registry as "
                         "Prometheus text exposition")
    ap.add_argument("--profile", action="store_true",
                    help="per-tick phase breakdown (prefill vs decode "
                         "vs speculate vs COW vs host); syncs the "
                         "device per phase, so the drain itself runs "
                         "slower")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tune-batch", action="store_true",
                    help="pick the slot count via repro.tune")
    ap.add_argument("--tune-prefill", action="store_true",
                    help="pick the prefill chunk size via repro.tune")
    ap.add_argument("--tune-page", action="store_true",
                    help="pick the KV page size via repro.tune "
                         "(implies --paged)")
    ap.add_argument("--tune-spec", action="store_true",
                    help="pick the speculation policy (depth x drafter) "
                         "via repro.tune (implies speculation)")
    ap.add_argument("--tune-scheduler", action="store_true",
                    help="pick the scheduling policy (policy x age_limit) "
                         "via repro.tune over a seeded traffic trace "
                         "(implies --paged; measured drains)")
    ap.add_argument("--tune-engine", default="grid",
                    help="tuning engine for --tune-batch/--tune-prefill/"
                         "--tune-page/--tune-spec; 'measure' refines the "
                         "modeled pick with real server drains "
                         "(wall-clock)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(args.seed))

    def run_job(tunable, label, key):
        from ..tune import TuningPlan
        plan = TuningPlan(name=f"serve.{args.arch}")
        plan.add(tunable, engine=args.tune_engine, label=label)
        job = plan.run(progress=None).results[0]
        if job.status == "failed":
            raise RuntimeError(f"--tune-{label} failed: {job.error}")
        picked = dict(job.best_config)
        shown = ",".join(f"{k}={v}" for k, v in sorted(picked.items()))
        print(f"[tune] {shown} "
              f"{job.provenance or 'modeled'} drain="
              f"{job.t_min / 1e3:.1f} ms (engine={job.engine}, "
              f"cache {job.status})")
        return picked if key is None else int(picked[key])

    batch = args.batch
    prefill_chunk = args.prefill_chunk
    page_size = args.page_size
    paged = (args.paged or args.tune_page or args.share_prefix
             or args.tune_scheduler)
    if args.tune_batch:
        tb = decode_batch_tunable(api, context=args.context,
                                  requests=args.requests,
                                  max_new=args.max_new, params=params)
        batch = run_job(tb, "batch", "batch")
    if args.tune_prefill:
        # after --tune-batch so the chunk is tuned (and cached) for the
        # slot count the server will actually run
        tp = prefill_chunk_tunable(api, context=args.context,
                                   prompt_len=args.prompt_len,
                                   requests=args.requests,
                                   max_new=args.max_new,
                                   batch=batch, params=params)
        prefill_chunk = run_job(tp, "prefill", "chunk")
    if args.tune_page:
        tk = kv_page_tunable(api, context=args.context,
                             prompt_lens=[args.prompt_len],
                             requests=args.requests, max_new=args.max_new,
                             batch=batch, params=params)
        page_size = run_job(tk, "page", "page")
    speculate = args.speculate
    spec_depth = args.spec_depth
    if args.tune_spec:
        ts = spec_depth_tunable(api, context=args.context,
                                prompt_len=args.prompt_len,
                                requests=args.requests,
                                max_new=args.max_new, batch=batch,
                                params=params)
        picked = run_job(ts, "spec", None)
        spec_depth = int(picked["depth"])
        speculate = str(picked["drafter"])
    scheduler = args.scheduler
    share_prefix = args.share_prefix
    if args.tune_scheduler:
        # policy differences are what the modeled cost can only rank,
        # not settle — this tunable measures real trace drains
        tsc = scheduler_tunable(api, context=args.context, batch=batch,
                                requests=args.requests,
                                page_size=page_size,
                                prefill_chunk=prefill_chunk,
                                prompt_len=(max(2, args.prompt_len // 2),
                                            args.prompt_len),
                                max_new=(max(1, args.max_new // 2),
                                         args.max_new), params=params)
        picked = run_job(tsc, "scheduler", None)
        scheduler = str(picked["policy"])
        share_prefix = share_prefix or scheduler == "prefix"

    obs = None
    if args.trace or args.metrics or args.profile:
        from ..obs import Observability
        obs = Observability(trace=args.trace is not None or args.profile,
                            metrics=True, profile=args.profile,
                            monitor=paged)
    server = Server(api, params, batch=batch, context=args.context,
                    prefill_chunk=prefill_chunk, paged=paged,
                    page_size=page_size, kv_pages=args.kv_pages,
                    speculate=speculate, spec_depth=spec_depth,
                    scheduler=scheduler, share_prefix=share_prefix,
                    obs=obs)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, args.prompt_len).tolist()
        server.submit(prompt, max_new=args.max_new)

    t0 = time.perf_counter()
    ticks = 0
    while server.queue or any(r is not None for r in server.slot_req):
        server.tick()
        ticks += 1
        if ticks > 100_000:
            raise RuntimeError("did not drain")
    wall = time.perf_counter() - t0

    done = server.completed
    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in "
          f"{ticks} engine ticks, {wall:.2f}s "
          f"({total_tokens / max(wall, 1e-9):.1f} tok/s)")
    if paged:
        st = server.kv_stats()
        print(f"  paged kv: page={page_size} pool={st['n_pages']:.0f} pages "
              f"peak_used={st['peak_used_pages']:.0f} "
              f"peak_active={st['peak_active']:.0f} "
              f"deferrals={st['deferrals']:.0f}")
    if scheduler is not None or share_prefix:
        st = server.stats()
        print(f"  scheduler: policy={scheduler or 'fcfs'} "
              f"preemptions={st['preemptions']:.0f} "
              f"share_hits={st['share_hits']:.0f} "
              f"shared_tokens={st['shared_tokens']:.0f} "
              f"cow_copies={st['cow_copies']:.0f}")
    if speculate is not None:
        st = server.stats()
        print(f"  speculation: drafter={speculate} depth={spec_depth} "
              f"proposed={st['spec_proposed']:.0f} "
              f"accepted={st['spec_accepted']:.0f} "
              f"(accept_rate={st['accept_rate']:.2f}) "
              f"ticks/token={st['ticks_per_token']:.2f}")
    for r in done[:3]:
        print(f"  req{r.rid}: prompt={r.prompt[:4]}... out={r.out}")
    if obs is not None:
        doc = obs.export(args.trace)
        if args.trace:
            print(f"  trace: {len(doc['traceEvents'])} events -> "
                  f"{args.trace} (open in https://ui.perfetto.dev)")
        if obs.monitor is not None:
            mon = doc["monitor"]
            print(f"  conformance monitor: {mon['status']} "
                  f"({mon['ops_checked']} allocator ops checked)")
        if args.profile and obs.profiler is not None:
            print(obs.profiler.format())
        if args.metrics and obs.registry is not None:
            print(obs.registry.to_prometheus(), end="")


if __name__ == "__main__":
    main()
