"""Deterministic synthetic token pipeline with per-host sharding and
background prefetch.

Real deployments swap :class:`SyntheticLM` for a tokenized corpus reader
with the same interface; everything downstream (sharded device_put,
prefetch, restart cursor) is production-shaped:

* determinism: batch ``i`` depends only on (seed, i) — a restart resumes
  from the checkpointed step with identical data (required for
  fault-tolerant exactly-once training semantics),
* per-host sharding: each host materializes only its slice of the global
  batch (``host_slice``),
* prefetch: a daemon thread keeps ``prefetch`` batches ahead.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, ShapeSpec


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 50_000
    # markov-ish synthetic stream so the loss actually decreases
    structure: float = 0.7


class SyntheticLM:
    """Deterministic synthetic LM batches: batch(i) is a pure function."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, data: DataConfig
                 = DataConfig(), host_index: int = 0, host_count: int = 1):
        assert shape.global_batch % host_count == 0
        self.cfg, self.shape, self.data = cfg, shape, data
        self.host_index, self.host_count = host_index, host_count
        self.local_batch = shape.global_batch // host_count

    def batch(self, i: int) -> dict:
        rng = np.random.default_rng(
            (self.data.seed, i, self.host_index))
        B, S = self.local_batch, self.shape.seq_len
        V = self.cfg.vocab
        # learnable stream: a per-sequence cyclic pattern of distinct
        # tokens (next-token is a function of the previous one), with
        # (1-structure) random corruptions
        k = min(32, V)
        # the cycle is fixed per dataset (seed only) so it is learnable
        # across batches; corruption positions vary per batch
        pat = np.random.default_rng(self.data.seed).permutation(V)[:k]
        phase = rng.integers(0, k, (B, 1))
        base = pat[(phase + np.arange(S)) % k]           # (B, S)
        mask = rng.random((B, S)) < self.data.structure
        noise = rng.integers(0, V, (B, S))
        toks = np.where(mask, base, noise).astype(np.int32)
        out = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            out["img_embeds"] = jnp.asarray(
                rng.standard_normal((B, self.cfg.n_img_tokens,
                                     self.cfg.d_model)) * 0.1, jnp.bfloat16)
        if self.cfg.is_encdec:
            out["frames"] = jnp.asarray(
                rng.standard_normal((B, self.cfg.enc_seq, self.cfg.d_model))
                * 0.1, jnp.bfloat16)
        return out

    def iterate(self, start: int = 0, prefetch: int = 2) -> Iterator[dict]:
        """Prefetching iterator starting at batch ``start`` (the restart
        cursor)."""

        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            i = start
            while not stop.is_set():
                q.put(self.batch(i))
                i += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def make_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs of a global batch (the dry-run input contract)."""

    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
           "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        out["img_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return out


__all__ = ["DataConfig", "SyntheticLM", "make_batch_specs"]
