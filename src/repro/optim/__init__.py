"""Optimizer substrate."""

from .adamw import (OptState, adamw_init, adamw_update, cosine_schedule,
                    global_norm)
from .compression import compress_int8, decompress_int8, ef_compress_grads

__all__ = ["OptState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm", "compress_int8", "decompress_int8",
           "ef_compress_grads"]
