"""AdamW with decoupled weight decay, cosine schedule, global-norm clip.

Written against plain pytrees (no optax available offline).  Optimizer
moments are f32 regardless of param dtype (bf16 params, f32 m/v —
the memory layout the roofline accounts for: 2 + 4 + 4 bytes/param)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array          # ()
    m: Any                   # f32 pytree
    v: Any                   # f32 pytree


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_update(params, grads, state: OptState, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float | None = 1.0):
    """Returns (new_params, new_state, metrics)."""

    step = state.step + 1
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state.v, grads)

    def upd(p, m, v):
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr_t}


__all__ = ["OptState", "adamw_init", "adamw_update", "cosine_schedule",
           "global_norm"]
