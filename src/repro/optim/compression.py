"""Gradient compression for cross-pod reduction (distributed-optimization
trick for 1000+ node scale).

int8 block-quantization with error feedback: gradients are quantized to
int8 with per-block f32 scales before the (slow, DCI-crossing) "pod"-axis
all-reduce, and the quantization residual is carried to the next step
(error feedback keeps SGD-style convergence).  The intra-pod ("data")
reduction stays full precision.

In the pjit train step this is expressed as quantize -> psum over 'pod'
-> dequantize inside a shard_map over the pod axis; at dry-run level the
win shows up as a 4x drop in pod-axis all-reduce bytes (bf16 -> int8
payload accounting, §Perf).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """-> (int8 values, f32 per-block scales). Works on any shape."""

    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress_int8(q: jax.Array, scale: jax.Array, shape: tuple[int, ...]
                    ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def ef_compress_grads(grads, residual):
    """Error-feedback compression of a gradient pytree.

    Returns (compressed-then-decompressed grads, new residual).  The
    round-trip models exactly what the receiving pods see; the residual
    (g + r) - Q(g + r) is added to the next step's gradient."""

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                grads)

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = compress_int8(target)
        deq = decompress_int8(q, s, g.shape)
        return deq.astype(g.dtype), target - deq

    pairs = jax.tree.map(one, grads, residual)
    new_g = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_r = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_r


__all__ = ["compress_int8", "decompress_int8", "ef_compress_grads", "BLOCK"]
