"""Tuning-parameter search spaces (configuration lattices).

The paper's ``main`` selects each tuning parameter from powers of two
bounded by the input size (Listing 3).  :class:`SearchSpace` generalizes
this: named parameters with finite value lists, cartesian product,
constraint predicates, and export as flat numpy arrays for the vectorized
sweep engine.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Mapping, Sequence

import numpy as np


def powers_of_two(lo: int, hi: int) -> tuple[int, ...]:
    """Inclusive powers of two between lo and hi."""

    out = []
    v = 1
    while v <= hi:
        if v >= lo:
            out.append(v)
        v *= 2
    return tuple(out)


@dataclass(frozen=True)
class Param:
    name: str
    values: tuple[Any, ...]


@dataclass
class SearchSpace:
    params: list[Param]
    constraints: list[Callable[[Mapping[str, Any]], bool]] = field(default_factory=list)

    def names(self) -> list[str]:
        return [p.name for p in self.params]

    def __iter__(self) -> Iterator[dict[str, Any]]:
        for combo in itertools.product(*[p.values for p in self.params]):
            cfg = dict(zip(self.names(), combo))
            if all(c(cfg) for c in self.constraints):
                yield cfg

    def __len__(self) -> int:
        return sum(1 for _ in self)

    def size_unconstrained(self) -> int:
        n = 1
        for p in self.params:
            n *= len(p.values)
        return n

    def to_arrays(self) -> dict[str, np.ndarray]:
        """Flat arrays over all constraint-satisfying lattice points."""

        cols: dict[str, list] = {n: [] for n in self.names()}
        for cfg in self:
            for k, v in cfg.items():
                cols[k].append(v)
        return {k: np.asarray(v) for k, v in cols.items()}


def wg_ts_space(size: int, np_elems: int | None = None) -> SearchSpace:
    """The paper's (WG, TS) lattice for input ``size`` (powers of two,
    at least one work item)."""

    space = SearchSpace(params=[
        Param("WG", powers_of_two(1, size)),
        Param("TS", powers_of_two(1, size)),
    ])
    space.constraints.append(lambda c: size // c["TS"] >= 1)
    return space


__all__ = ["Param", "SearchSpace", "powers_of_two", "wg_ts_space"]
