"""Vectorized configuration sweep — the beyond-paper engine.

SPIN enumerates the configuration lattice one interleaving at a time
(Table 1: hours for size=1024).  Because the platform model's time is a
*pure function of the configuration* (interleaving-invariance, tested),
the whole lattice collapses to one data-parallel evaluation:

* exact integer path (numpy int64) — the default oracle; bit-identical
  to the explicit-state simulator,
* jitted JAX path (``jax.jit`` over the same formulas) — demonstrates
  on-device evaluation; this is the TPU-native shortcut, trading SPIN's
  per-state search for an MXU/VPU-friendly dense sweep.

The sweep still *speaks the paper's protocol*: :func:`cex_oracle` answers
"is there a counterexample to Φ_o(T)?" so Fig. 1's bisection loop runs
unchanged on top of it, and the returned witness is validated against the
explicit-state model by tests.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .counterexample import Counterexample
from .search_space import SearchSpace, wg_ts_space
from .wave_model import WaveParams, model_time, model_time_jnp


@dataclass
class SweepResult:
    best_config: dict
    t_min: int
    times: np.ndarray
    configs: dict[str, np.ndarray]
    evaluated: int


def sweep_times(p: WaveParams, space: SearchSpace | None = None) -> SweepResult:
    """Evaluate the exact model time for every lattice point (numpy)."""

    space = space or wg_ts_space(p.size)
    arrs = space.to_arrays()
    WG, TS = arrs["WG"].astype(np.int64), arrs["TS"].astype(np.int64)
    items = p.size // TS
    valid = items >= 1
    times = np.full(WG.shape, np.int64(2**62))
    # vectorized closed form (identical to wave_model.model_time)
    full = np.where(valid, items // np.maximum(WG, 1), 0)
    rem = np.where(valid, items % np.maximum(WG, 1), 0)
    short = full == 0
    full = np.where(short, 0, full)
    rem = np.where(short, items, rem)
    g_total = full + (rem > 0)
    cnt_full = np.minimum(WG, items)

    def gmt_eff(resident):
        if p.warp is None:
            return p.GMT
        n_warps = np.maximum(1, -(-resident // p.warp))
        return np.maximum(1, -(-p.GMT // n_warps))

    def wave_time(its, resident):
        g = gmt_eff(resident)
        if p.kind == "abstract":
            return its * (g * TS + TS) + g
        return g * TS

    def group_time(cnt):
        waves = -(-cnt // p.NP)
        resident = np.minimum(cnt, p.NP)
        t = waves * wave_time(items, resident)
        if p.kind == "minimum":
            t = t + (resident - 1) + gmt_eff(resident)
        return t + p.L

    U = p.ND * p.NU
    t_full = group_time(cnt_full)
    t_rem = np.where(rem > 0, group_time(np.maximum(rem, 1)), 0)
    count0 = -(-g_total // U)
    r = (g_total - 1) % U
    count_r = -(-(g_total - r) // U)
    t0 = count0 * t_full - np.where(r == 0, t_full - t_rem, 0)
    tr = count_r * t_full - (t_full - t_rem)
    device_t = np.where(rem > 0, np.maximum(t0, tr), count0 * t_full)
    host_t = g_total if p.kind == "minimum" else 0
    times = np.where(valid, device_t + host_t, times)

    i = int(np.argmin(times))
    best = {k: int(v[i]) for k, v in arrs.items()}
    return SweepResult(best_config=best, t_min=int(times[i]), times=times,
                       configs=arrs, evaluated=len(WG))


@partial(jax.jit, static_argnames=("p",))
def sweep_times_jit(p: WaveParams, WG: jax.Array, TS: jax.Array) -> jax.Array:
    """Jitted on-device sweep (same formulas via wave_model.model_time_jnp)."""

    return model_time_jnp(p, WG, TS)


def cex_oracle(p: WaveParams, space: SearchSpace | None = None
               ) -> Callable[[int], Counterexample | None]:
    """Adapt the sweep to the paper's C_ex(T) protocol: return a
    counterexample to Φ_o(T) (a config terminating with time ≤ T), or
    None if Φ_o(T) holds over the whole lattice."""

    res = sweep_times(p, space)

    def oracle(T: int) -> Counterexample | None:
        mask = res.times <= T
        if not mask.any():
            return None
        # pick the best admissible witness (any would do; SPIN returns the
        # first trail found — we return the strongest, which only speeds
        # the bisection up)
        idx = int(np.argmin(np.where(mask, res.times, np.int64(2**62))))
        cfg = {k: int(v[idx]) for k, v in res.configs.items()}
        return Counterexample(time=int(res.times[idx]), config=cfg,
                              trail=(), depth=0)

    return oracle


__all__ = ["sweep_times", "sweep_times_jit", "cex_oracle", "SweepResult"]
