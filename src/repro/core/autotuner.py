"""``TuneResult`` — the result dataclass every tuning layer shares.

The seed's two front doors (``AutoTuner``/``FunctionTuner``) lived here;
both were replaced by the unified :mod:`repro.tune` API —

    from repro.tune import tune, PlatformTunable, FunctionTunable
    tune(PlatformTunable(spec), engine="sweep")
    tune(FunctionTunable(cost_fn, space), engine="grid")

— and the deprecated shims have since been removed (no callers remain).
``TuneResult`` stays defined in ``core`` because it is the leaf type both
the paper-faithful search code and the ``repro.tune`` engine/cache/plan
layers depend on, without either importing the other.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from .counterexample import Counterexample


@dataclass
class TuneResult:
    best_config: dict[str, Any]
    t_min: int
    engine: str
    oracle_calls: int = 0
    elapsed_s: float = 0.0
    stats: dict[str, Any] = field(default_factory=dict)
    witness: Counterexample | None = None
    log: Any = None


__all__ = ["TuneResult"]
