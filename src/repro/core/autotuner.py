"""Top-level auto-tuning API (the paper's four-step method, packaged).

``AutoTuner`` runs the paper's loop end to end:

1. *model* — an abstract platform model (`PlatformSpec` → Promela-like
   process system) or any pure evaluation function over a search space;
2. *property* — Φ_o(T) over-time;
3. *search* — bisection on T (Fig. 1) against a counterexample oracle:
   ``engine="explorer"`` (explicit-state DFS — SPIN-faithful),
   ``engine="swarm"``   (Fig. 5 randomized bounded search),
   ``engine="sweep"``   (vectorized lattice evaluation — beyond-paper);
4. *extract* — the final counterexample's tuning configuration.

This is also the integration point for the rest of the framework: the
launcher tunes Pallas kernel block sizes and distributed-training
parameters through this interface (see `repro.core.tpu_machine` and
`repro.launch.train --tune`).
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Any, Callable

from . import bisect_search, explorer, platform, properties, swarm, sweep
from .counterexample import Counterexample
from .search_space import SearchSpace
from .wave_model import WaveParams, model_time


@dataclass
class TuneResult:
    best_config: dict[str, Any]
    t_min: int
    engine: str
    oracle_calls: int = 0
    elapsed_s: float = 0.0
    stats: dict[str, Any] = field(default_factory=dict)
    witness: Counterexample | None = None
    log: Any = None


def _explorer_oracle(model, config_vars, *, schedule="por", max_states=2_000_000):
    def oracle(T: int) -> Counterexample | None:
        prop = properties.OverTime(T)
        r = explorer.explore(model, prop.violates, schedule=schedule,
                             max_states=max_states)
        if r.counterexample is None:
            return None
        return Counterexample.from_terminal(r.counterexample, config_vars)
    return oracle


def _simulate_t_ini(model) -> int:
    """The paper obtains T_ini from a SPIN simulation run: one random
    walk to FIN reads off a feasible termination time."""

    for seed in range(16):
        r = explorer.explore(model, properties.NonTermination().violates,
                             schedule="random", seed=seed, depth_limit=2_000_000)
        if r.counterexample is not None:
            return int(r.counterexample.globals["time"])
    raise RuntimeError("simulation never reached FIN")


class AutoTuner:
    """Tunes a :class:`~repro.core.platform.PlatformSpec` workload."""

    def __init__(self, spec: platform.PlatformSpec,
                 space: SearchSpace | None = None,
                 config_vars: tuple[str, ...] = ("WG", "TS")):
        self.spec = spec
        self.space = space
        self.config_vars = config_vars
        self.wave = WaveParams(size=spec.size, NP=spec.NP, GMT=spec.GMT,
                               L=spec.L, kind=spec.kind)

    # -- engines -------------------------------------------------------------

    def tune(self, engine: str = "sweep", **kw) -> TuneResult:
        t0 = _time.perf_counter()
        if engine == "sweep":
            res = self._tune_sweep(**kw)
        elif engine == "explorer":
            res = self._tune_explorer(**kw)
        elif engine == "swarm":
            res = self._tune_swarm(**kw)
        elif engine == "bnb":
            res = self._tune_bnb(**kw)
        else:
            raise ValueError(f"unknown engine {engine!r}")
        res.elapsed_s = _time.perf_counter() - t0
        return res

    def _tune_sweep(self, use_bisection: bool = False) -> TuneResult:
        if use_bisection:
            # run the paper's Fig.1 loop with the sweep as C_ex oracle
            oracle = sweep.cex_oracle(self.wave, self.space)
            t_ini = model_time(self.wave, WG=1, TS=1)  # trivially feasible config
            br = bisect_search.find_minimal_time(oracle, t_ini=t_ini)
            return TuneResult(best_config=br.witness.config, t_min=br.t_min,
                              engine="sweep+bisection",
                              oracle_calls=br.oracle_calls,
                              witness=br.witness, log=br.log)
        r = sweep.sweep_times(self.wave, self.space)
        return TuneResult(best_config=r.best_config, t_min=r.t_min,
                          engine="sweep", oracle_calls=1,
                          stats={"evaluated": r.evaluated})

    def _tune_explorer(self, schedule: str = "por", mode: str = "collect",
                       max_states: int = 2_000_000) -> TuneResult:
        model = platform.build_model(self.spec)
        if mode == "collect":
            # The paper's own optimization (§6): run SPIN once with -e
            # (trails for ALL errors) against Φ_t, then post-process the
            # collected counterexamples — every terminating execution is
            # a counterexample to non-termination, so one exploration
            # yields the whole (config -> time) table and the bisection
            # answers from it.
            r = explorer.explore(model, properties.NonTermination().violates,
                                 schedule=schedule, max_states=max_states,
                                 stop_on_first=False, collect_terminals=True)
            if not r.terminals:
                raise RuntimeError("no terminating executions found")
            table = [Counterexample.from_terminal(t, self.config_vars)
                     for t in r.terminals]

            def oracle(T: int) -> Counterexample | None:
                ok = [c for c in table if c.time <= T]
                return min(ok, key=lambda c: c.time) if ok else None

            t_ini = max(c.time for c in table)
            br = bisect_search.find_minimal_time(oracle, t_ini=t_ini)
            return TuneResult(best_config=br.witness.config, t_min=br.t_min,
                              engine=f"explorer/{schedule}+collect",
                              oracle_calls=br.oracle_calls,
                              witness=br.witness, log=br.log,
                              stats={"states": r.states,
                                     "terminals": len(table)})
        oracle = _explorer_oracle(model, self.config_vars,
                                  schedule=schedule, max_states=max_states)
        t_ini = _simulate_t_ini(model)
        br = bisect_search.find_minimal_time(oracle, t_ini=t_ini)
        return TuneResult(best_config=br.witness.config, t_min=br.t_min,
                          engine=f"explorer/{schedule}",
                          oracle_calls=br.oracle_calls, witness=br.witness,
                          log=br.log)

    def _tune_bnb(self, schedule: str = "por",
                  max_states: int = 5_000_000) -> TuneResult:
        """Ruys-style branch-and-bound (paper §8 future work [11]): the
        minimal time from ONE verification run — no bisection."""

        model = platform.build_model(self.spec)
        r = explorer.explore(model, lambda G: False, schedule=schedule,
                             branch_and_bound="time", stop_on_first=False,
                             max_states=max_states)
        if r.counterexample is None:
            raise RuntimeError("no terminating execution found")
        cex = Counterexample.from_terminal(r.counterexample,
                                           self.config_vars)
        return TuneResult(best_config=cex.config, t_min=cex.time,
                          engine=f"bnb/{schedule}", oracle_calls=1,
                          witness=cex, stats={"states": r.states})

    def _tune_swarm(self, n_walks: int = 16, depth_limit: int = 500_000,
                    seed: int = 0, n_workers: int = 1) -> TuneResult:
        model = platform.build_model(self.spec)
        sr = swarm.swarm_search(model, n_walks=n_walks,
                                depth_limit=depth_limit, seed=seed,
                                n_workers=n_workers,
                                config_vars=self.config_vars)
        return TuneResult(best_config=sr.best.config, t_min=sr.t_min,
                          engine="swarm", oracle_calls=sr.stats.rounds,
                          witness=sr.best,
                          stats={"walks": sr.stats.walks,
                                 "counterexamples": sr.stats.counterexamples})


class FunctionTuner:
    """Generic tuner over an arbitrary cost function (used for Pallas
    kernel block sizes and TPU distributed configs): same Fig. 1 protocol,
    with the cost function as the machine model."""

    def __init__(self, cost_fn: Callable[[dict], float], space: SearchSpace):
        self.cost_fn = cost_fn
        self.space = space

    def tune(self) -> TuneResult:
        t0 = _time.perf_counter()
        best_cfg, best_t = None, None
        n = 0
        for cfg in self.space:
            t = self.cost_fn(cfg)
            n += 1
            if best_t is None or t < best_t:
                best_cfg, best_t = dict(cfg), t
        if best_cfg is None:
            raise RuntimeError("empty search space")
        return TuneResult(best_config=best_cfg, t_min=best_t, engine="function",
                          oracle_calls=n, elapsed_s=_time.perf_counter() - t0)


__all__ = ["AutoTuner", "FunctionTuner", "TuneResult"]
