"""DEPRECATED legacy tuning entry points (thin shims over ``repro.tune``).

``AutoTuner`` and ``FunctionTuner`` were the seed's two front doors; all
tuning now goes through the unified :mod:`repro.tune` API —

    from repro.tune import tune, PlatformTunable, FunctionTunable
    tune(PlatformTunable(spec), engine="sweep")     # was AutoTuner(spec).tune("sweep")
    tune(FunctionTunable(cost_fn, space), "grid")   # was FunctionTuner(cost_fn, space).tune()

— which adds the engine registry and the persistent
:class:`~repro.tune.TuningCache`.  The shims delegate verbatim (with
caching disabled, matching the old behavior) and are kept only so
existing callers and the parity tests keep working; new code should not
use them.  ``TuneResult`` remains defined here as the leaf dataclass both
layers share.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any, Callable

from .counterexample import Counterexample
from .search_space import SearchSpace
from .wave_model import WaveParams


@dataclass
class TuneResult:
    best_config: dict[str, Any]
    t_min: int
    engine: str
    oracle_calls: int = 0
    elapsed_s: float = 0.0
    stats: dict[str, Any] = field(default_factory=dict)
    witness: Counterexample | None = None
    log: Any = None


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


class AutoTuner:
    """DEPRECATED: use ``repro.tune.tune(PlatformTunable(spec), ...)``."""

    def __init__(self, spec, space: SearchSpace | None = None,
                 config_vars: tuple[str, ...] = ("WG", "TS")):
        self.spec = spec
        self.space = space
        self.config_vars = config_vars
        self.wave = WaveParams(size=spec.size, NP=spec.NP, GMT=spec.GMT,
                               L=spec.L, kind=spec.kind)

    def tune(self, engine: str = "sweep", **kw) -> TuneResult:
        _deprecated("repro.core.AutoTuner",
                    "repro.tune.tune(repro.tune.PlatformTunable(spec), ...)")
        from ..tune import PlatformTunable, tune
        tunable = PlatformTunable(self.spec, space=self.space,
                                  config_vars=self.config_vars)
        return tune(tunable, engine=engine, cache=None, **kw)


class FunctionTuner:
    """DEPRECATED: use ``repro.tune.tune(FunctionTunable(cost_fn, space),
    engine="grid")``."""

    def __init__(self, cost_fn: Callable[[dict], float], space: SearchSpace):
        self.cost_fn = cost_fn
        self.space = space

    def tune(self) -> TuneResult:
        _deprecated("repro.core.FunctionTuner",
                    "repro.tune.tune(repro.tune.FunctionTunable(...), "
                    "engine='grid')")
        from ..tune import FunctionTunable, tune
        return tune(FunctionTunable(self.cost_fn, self.space),
                    engine="function", cache=None)


__all__ = ["AutoTuner", "FunctionTuner", "TuneResult"]
