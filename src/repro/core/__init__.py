"""Core: model-checking-based auto-tuning (the paper's contribution).

Public API:

* :class:`~repro.core.platform.PlatformSpec` + :func:`~repro.core.platform.build_model`
  — the abstract OpenCL/TPU platform as a Promela-like process system,
* :func:`~repro.core.explorer.explore` — explicit-state verification,
* :class:`~repro.core.properties.OverTime` / ``NonTermination`` — Φ_o / Φ_t,
* :func:`~repro.core.bisect_search.find_minimal_time` — Fig. 1,
* :func:`~repro.core.swarm.swarm_search` — Fig. 5,
* :func:`~repro.core.sweep.sweep_times` — beyond-paper vectorized engine,
* :class:`~repro.core.autotuner.TuneResult` — the shared result type
  (the four-step method itself is packaged as :func:`repro.tune.tune`).
"""

from .autotuner import TuneResult
from .bisect_search import find_minimal_time
from .counterexample import Counterexample
from .explorer import ExploreResult, explore, replay
from .platform import PlatformSpec, build_model
from .properties import NonTermination, OverTime, trace_satisfies
from .search_space import Param, SearchSpace, powers_of_two, wg_ts_space
from .swarm import swarm_search
from .sweep import cex_oracle, sweep_times
from .wave_model import WaveParams, model_time, model_time_jnp

__all__ = [
    "TuneResult", "find_minimal_time",
    "Counterexample", "ExploreResult", "explore", "replay", "PlatformSpec",
    "build_model", "NonTermination", "OverTime", "trace_satisfies", "Param",
    "SearchSpace", "powers_of_two", "wg_ts_space", "swarm_search",
    "cex_oracle", "sweep_times", "WaveParams", "model_time", "model_time_jnp",
]
