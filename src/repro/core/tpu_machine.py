"""Abstract TPU-pod machine model for distributed-configuration tuning.

This is the paper's "Abstract Platform" (§3.1) re-instantiated for the
512-chip target: instead of devices/units/PEs with a GMT memory ratio,
the platform is pods × chips with three resources per chip — MXU
(197 TFLOP/s bf16), HBM (819 GB/s) and ICI links (4 × 50 GB/s) — plus a
slow inter-pod DCI (default 25 GB/s/chip-pair share).

``TPUWorkload`` captures one training step analytically; the modeled
step time plays the role of the paper's model ``time`` variable, and
the search over :class:`TPUConfig` lattices runs through the same
engines (bisection over Φ_o with the vectorized sweep as C_ex oracle —
``repro.tune.tune`` on a :class:`DistributedTunable`, or
``tune_distributed`` below).

Calibration: the analytic terms are aligned against the dry-run's
compiled artifact for the baseline config (same quantities the roofline
reports); the tuner then extrapolates across the lattice without
recompiling every point — the paper's core benefit (no hardware, and
here: not even 80 compiles) — and the chosen config is verified by ONE
recompile (§Perf loop).
"""

from __future__ import annotations

import math
from dataclasses import asdict, dataclass
from typing import Any, ClassVar, Mapping

from ..calibrate.spec import DEFAULT_SPEC, get_platform_spec
from .search_space import Param, SearchSpace

# Datasheet aliases (TPU v5e), stated once in calibrate.spec.DEFAULT_SPEC.
# The models below resolve LIVE constants via get_platform_spec() so a
# calibration artifact (python -m repro.calibrate run) reprices them;
# these names remain for callers that want the uncalibrated numbers.
PEAK_FLOPS = DEFAULT_SPEC.peak_flops
HBM_BW = DEFAULT_SPEC.hbm_bw
ICI_BW = DEFAULT_SPEC.ici_bw
DCI_BW = DEFAULT_SPEC.dci_bw


@dataclass(frozen=True)
class TPUWorkload:
    """One training step of a stacked-layer LM (analytic)."""

    params: int                      # total parameter count
    active_params: int               # per-token touched params (MoE)
    layers: int
    d_model: int
    seq: int
    global_batch: int
    vocab: int
    dtype_bytes: int = 2
    flops_const: float = 6.0         # 6 = fwd+bwd

    # -- repro.tune Tunable protocol (default 256-chip single-pod target;
    # use .tunable() to pin a different platform) --------------------------

    name: ClassVar[str] = "tpu.workload"

    def tunable(self, *, chips_per_pod: int = 256, pods: int = 1,
                hbm_bytes: float = 16e9) -> "DistributedTunable":
        return DistributedTunable(self, chips_per_pod=chips_per_pod,
                                  pods=pods, hbm_bytes=hbm_bytes)

    def space(self) -> SearchSpace:
        return self.tunable().space()

    def cost(self, cfg: Mapping[str, Any]) -> float:
        return self.tunable().cost(cfg)

    def fingerprint(self) -> dict[str, Any]:
        return self.tunable().fingerprint()


@dataclass(frozen=True)
class TPUConfig:
    """A tuning-parameter configuration (the WG/TS analogue)."""

    dp: int                          # data-parallel ways (per pod)
    tp: int                          # tensor-parallel ways
    pods: int = 1
    microbatches: int = 1
    remat: str = "full"              # none | dots | full
    compress_pod_grads: bool = False
    fsdp: bool = False               # shard params over dp (ZeRO-3-ish)


def step_time(w: TPUWorkload, c: TPUConfig, *, overlap: float = 0.7,
              spec=None) -> dict[str, float]:
    """Modeled per-step time decomposition (seconds).

    overlap: fraction of collective time hidden under compute (TPU async
    collectives + microbatch pipelining).  ``spec`` pins the platform
    constants (a :class:`repro.calibrate.PlatformSpec`); ``None``
    resolves the active one (calibrated when an artifact exists)."""

    if spec is None:
        spec = get_platform_spec()
    chips = c.dp * c.tp * c.pods
    tokens = w.seq * w.global_batch

    # -- compute ------------------------------------------------------------
    remat_mult = {"none": 1.0, "dots": 1.15, "full": 4.0 / 3.0}[c.remat]
    flops = w.flops_const * w.active_params * tokens * remat_mult
    compute = flops / (chips * spec.peak_flops)

    # -- memory -------------------------------------------------------------
    # weights re-streamed once per microbatch (fwd) + once (bwd);
    # activations in/out once; optimizer state touched once.
    w_bytes = w.params * w.dtype_bytes / (c.tp * (c.dp if c.fsdp else 1))
    act_bytes = tokens // (c.dp * c.pods) * w.d_model * w.dtype_bytes \
        * w.layers * (4 if c.remat == "none" else 2)
    opt_bytes = w.params * 12 / (c.tp * (c.dp if c.fsdp else 1))
    hbm = (w_bytes * (c.microbatches + 1) + act_bytes + opt_bytes) \
        / spec.hbm_bw

    # -- collectives ----------------------------------------------------------
    # DP gradient all-reduce (ring): 2*(n-1)/n * bytes; FSDP swaps it for
    # reduce-scatter + all-gather (same volume, half latency exposure).
    grad_bytes = w.params * w.dtype_bytes / c.tp
    dp_ways = c.dp
    dp_ar = 2 * (dp_ways - 1) / max(dp_ways, 1) * grad_bytes / spec.ici_bw
    # TP per-layer activation collectives (2 all-reduces/layer fwd+bwd)
    tp_bytes = (tokens // (c.dp * c.pods)) * w.d_model * w.dtype_bytes
    tp_ar = (4 * (c.tp - 1) / max(c.tp, 1) * tp_bytes * w.layers /
             max(c.microbatches, 1) * c.microbatches) / spec.ici_bw \
        if c.tp > 1 else 0.0
    # pod-axis gradient reduction over DCI (compressible)
    pod_bytes = grad_bytes * (0.25 if c.compress_pod_grads else 1.0)
    pod_ar = 2 * (c.pods - 1) / max(c.pods, 1) * pod_bytes / spec.dci_bw \
        if c.pods > 1 else 0.0

    collective = dp_ar + tp_ar + pod_ar
    exposed = collective * (1.0 - overlap * min(1.0, c.microbatches / 2))
    total = max(compute, hbm) + exposed
    return {"compute": compute, "memory": hbm, "collective": collective,
            "exposed_collective": exposed, "total": total,
            "chips": chips}


def hbm_fits(w: TPUWorkload, c: TPUConfig, *, hbm_bytes: float = 16e9
             ) -> bool:
    # FSDP shards parameters/optimizer over the dp axes of every pod
    chips = c.tp * ((c.dp * c.pods) if c.fsdp else 1)
    resident = w.params * (w.dtype_bytes + 8 + 4) / chips
    act = (w.seq * w.global_batch // (c.dp * c.pods)) * w.d_model * \
        w.dtype_bytes * (w.layers if c.remat == "none" else 2)
    return resident + act < hbm_bytes * 0.9


def config_space(chips_per_pod: int = 256, pods: int = 1) -> SearchSpace:
    tps = [t for t in (1, 2, 4, 8, 16, 32) if chips_per_pod % t == 0]
    space = SearchSpace(params=[
        Param("tp", tuple(tps)),
        Param("microbatches", (1, 2, 4, 8)),
        Param("remat", ("none", "dots", "full")),
        Param("fsdp", (False, True)),
        Param("compress_pod_grads", ((False, True) if pods > 1
                                     else (False,))),
    ])
    return space


@dataclass(frozen=True)
class DistributedTunable:
    """``repro.tune`` Tunable: the distributed-training configuration
    lattice for one workload on a pods × chips platform.  Infeasible
    (HBM-overflowing) points cost ``inf``."""

    workload: TPUWorkload
    chips_per_pod: int = 256
    pods: int = 1
    hbm_bytes: float = 16e9
    name: ClassVar[str] = "tpu.distributed"

    def __post_init__(self):
        # step-time decompositions computed during the search, so callers
        # (tune_distributed's ranked list) don't price the lattice twice
        object.__setattr__(self, "_decompositions", {})

    def space(self) -> SearchSpace:
        return config_space(self.chips_per_pod, self.pods)

    def to_config(self, cfg: Mapping[str, Any]) -> TPUConfig:
        return TPUConfig(dp=self.chips_per_pod // cfg["tp"], tp=cfg["tp"],
                         pods=self.pods, microbatches=cfg["microbatches"],
                         remat=cfg["remat"], fsdp=cfg["fsdp"],
                         compress_pod_grads=cfg["compress_pod_grads"])

    def cost(self, cfg: Mapping[str, Any]) -> float:
        c = self.to_config(cfg)
        if not hbm_fits(self.workload, c, hbm_bytes=self.hbm_bytes):
            return float("inf")
        t = step_time(self.workload, c)
        self._decompositions[c] = t
        return t["total"]

    def decomposition(self, c: TPUConfig) -> dict[str, float]:
        t = self._decompositions.get(c)
        return t if t is not None else step_time(self.workload, c)

    def fingerprint(self) -> dict[str, Any]:
        return {"tunable": self.name, "workload": asdict(self.workload),
                "chips_per_pod": self.chips_per_pod, "pods": self.pods,
                "hbm_bytes": self.hbm_bytes}


def tune_distributed(w: TPUWorkload, *, chips_per_pod: int = 256,
                     pods: int = 1, hbm_bytes: float = 16e9):
    """Sweep the config lattice through the machine model (via the
    ``repro.tune`` grid engine); returns (best TPUConfig, best step
    decomposition, ranked list)."""

    from ..tune import tune as _tune
    tb = DistributedTunable(w, chips_per_pod=chips_per_pod, pods=pods,
                            hbm_bytes=hbm_bytes)
    res = _tune(tb, engine="grid", cache=None, keep_trace=True)
    ranked = []
    for total, cfg in res.stats["trace"]:
        if math.isfinite(total):
            c = tb.to_config(cfg)
            ranked.append((total, c, tb.decomposition(c)))
    if not ranked:
        raise RuntimeError("no feasible configuration fits HBM")
    ranked.sort(key=lambda r: r[0])
    return ranked[0][1], ranked[0][2], ranked


def workload_from_arch(arch: str, shape_name: str) -> TPUWorkload:
    from ..configs import SHAPES, get_config
    from ..launch.roofline import active_params
    from ..models.api import build_model
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    api = build_model(cfg)
    return TPUWorkload(params=api.param_count(),
                       active_params=active_params(arch),
                       layers=cfg.n_layers, d_model=cfg.d_model,
                       seq=shape.seq_len, global_batch=shape.global_batch,
                       vocab=cfg.vocab)


__all__ = ["TPUWorkload", "TPUConfig", "DistributedTunable", "step_time",
           "hbm_fits", "config_space", "tune_distributed",
           "workload_from_arch"]
