"""Closed-form wave-timing model of the abstract platform.

The process model's time is deterministic per configuration (lock-step
clock, interleaving-invariant — tested).  This module is the closed form
of that time, derived from the scheduling semantics in
:mod:`repro.core.platform`:

* ``items = size // TS`` work items, grouped into workgroups of ``WG``
  (last group may be short),
* a unit executes its groups sequentially; a group of ``cnt`` items runs
  in ``ceil(cnt / NP)`` waves of at most NP resident elements,
* abstract kernel wave time  C = items·(GMT·TS + TS) + GMT,
* minimum kernel wave time   GMT·TS, plus a per-group epilogue
  ``(min(cnt, NP) − 1) + GMT`` and a host-side final reduction of one
  unit per group,
* optional per-group launch overhead ``L``,
* ND·NU units take groups round-robin; total time is the max over units
  (exact for the round-robin assignment).

``model_time`` is the exact integer scalar form (tests assert equality
with the explicit-state simulator); ``model_time_jnp`` is the
vectorized/jittable form used by the sweep engine — identical formulas
over arrays.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


def _cdiv(a, b):
    return -(-a // b)


@dataclass(frozen=True)
class WaveParams:
    size: int
    NP: int = 4
    GMT: int = 4
    L: int = 0
    kind: str = "abstract"   # "abstract" | "minimum"
    ND: int = 1
    NU: int = 1
    # Warp-based scheduling (the paper's §8 planned extension): resident
    # elements execute in warps of this size; multiple resident warps
    # hide global-memory latency, dividing the effective GMT (down to 1).
    warp: int | None = None

    def gmt_eff(self, resident: int) -> int:
        if self.warp is None:
            return self.GMT
        n_warps = max(1, -(-resident // self.warp))
        return max(1, -(-self.GMT // n_warps))

    @classmethod
    def from_platform(cls, size: int, *, spec=None, el_bytes: int = 4,
                      **kw) -> "WaveParams":
        """Wave parameters whose GMT ratio is derived from a MEASURED
        platform (:func:`gmt_from_spec`) instead of the default 4 —
        the bridge from :class:`repro.calibrate.PlatformSpec` (physical
        constants) to the abstract process model's memory ratio."""

        return cls(size=size, GMT=gmt_from_spec(spec, el_bytes=el_bytes),
                   **kw)


def gmt_from_spec(spec=None, *, el_bytes: int = 4) -> int:
    """The abstract GMT ratio (global-memory time per unit compute)
    implied by a measured platform: how many element-sized FLOPs the
    device completes in the time one element streams from main memory —
    ``peak_flops * el_bytes / hbm_bw``, floored at 1.  ``spec=None``
    resolves the active :func:`repro.calibrate.get_platform_spec`, so a
    calibration artifact reshapes the abstract platform too."""

    if spec is None:
        from ..calibrate.spec import get_platform_spec
        spec = get_platform_spec()
    return max(1, round(spec.peak_flops * el_bytes / spec.hbm_bw))


def _group_structure(size: int, WG: int, TS: int):
    items = size // TS
    full = items // WG
    rem = items % WG
    g_total = full + (1 if rem else 0)
    return items, full, rem, g_total


def _wave_time(p: WaveParams, TS: int, items: int, resident: int) -> int:
    g = p.gmt_eff(resident)
    if p.kind == "abstract":
        return items * (g * TS + TS) + g
    return g * TS


def _group_time(p: WaveParams, cnt: int, TS: int, items: int) -> int:
    waves = _cdiv(cnt, p.NP)
    resident = min(cnt, p.NP)
    t = waves * _wave_time(p, TS, items, resident)
    if p.kind == "minimum":
        t += (resident - 1) + p.gmt_eff(resident)
    return t + p.L


def model_time(p: WaveParams, WG: int, TS: int) -> int:
    """Exact model termination time for one configuration."""

    items, full, rem, g_total = _group_structure(p.size, WG, TS)
    if items < 1:
        raise ValueError("TS larger than size: no work items")
    if full == 0:            # single short group
        full, rem = 0, items
        g_total = 1

    U = p.ND * p.NU
    t_full = _group_time(p, min(WG, items), TS, items)
    t_rem = _group_time(p, rem, TS, items) if rem else 0

    # round-robin assignment: unit 0 is the fullest; the remainder group
    # (index g_total-1) lands on unit (g_total-1) % U.
    count0 = _cdiv(g_total, U)
    if rem:
        r = (g_total - 1) % U
        count_r = _cdiv(g_total - r, U)
        t0 = count0 * t_full - (t_full - t_rem) * (1 if r == 0 else 0)
        tr = count_r * t_full - (t_full - t_rem)
        device_t = max(t0, tr)
    else:
        device_t = count0 * t_full

    host_t = g_total if p.kind == "minimum" else 0
    return device_t + host_t


def model_time_jnp(p: WaveParams, WG, TS):
    """Vectorized/jittable twin of :func:`model_time` (same formulas).

    Uses int64 when ``jax_enable_x64`` is on, else int32 (values must fit;
    the exact engine for arbitrary sizes is the numpy path in
    :mod:`repro.core.sweep`)."""

    idt = jnp.int64 if jnp.zeros((), jnp.int64).dtype == jnp.int64 else jnp.int32
    WG = jnp.asarray(WG, idt)
    TS = jnp.asarray(TS, idt)
    size = idt(p.size)
    NP = idt(p.NP)
    GMT = idt(p.GMT)

    items = size // TS
    full = items // WG
    rem = items % WG
    # single short group when items < WG
    short = full == 0
    full = jnp.where(short, 0, full)
    rem = jnp.where(short, items, rem)
    g_total = full + (rem > 0)

    cnt_full = jnp.minimum(WG, items)

    def gmt_eff(resident):
        if p.warp is None:
            return GMT
        n_warps = jnp.maximum(1, -(-resident // idt(p.warp)))
        return jnp.maximum(1, -(-GMT // n_warps))

    def wave_time(its, resident):
        g = gmt_eff(resident)
        if p.kind == "abstract":
            return its * (g * TS + TS) + g
        return g * TS

    def group_time(cnt):
        waves = -(-cnt // NP)
        resident = jnp.minimum(cnt, NP)
        t = waves * wave_time(items, resident)
        if p.kind == "minimum":
            t = t + (resident - 1) + gmt_eff(resident)
        return t + p.L

    U = idt(p.ND * p.NU)
    t_full = group_time(cnt_full)
    t_rem = jnp.where(rem > 0, group_time(jnp.maximum(rem, 1)), 0)

    count0 = -(-g_total // U)
    r = (g_total - 1) % U
    count_r = -(-(g_total - r) // U)
    t0 = count0 * t_full - jnp.where(r == 0, t_full - t_rem, 0)
    tr = count_r * t_full - (t_full - t_rem)
    device_t = jnp.where(rem > 0, jnp.maximum(t0, tr), count0 * t_full)

    host_t = g_total if p.kind == "minimum" else 0
    t = device_t + host_t
    # invalid configs (no work items) get +inf-like sentinel
    return jnp.where(items >= 1, t, jnp.iinfo(idt).max)


__all__ = ["WaveParams", "model_time", "model_time_jnp", "gmt_from_spec"]
