"""A Promela-like process runtime with explicit small-step semantics.

This is the substrate for the paper's Step 1 ("represent the parallel
program with its tuning parameters and target architecture in the language
of a model checking tool").  We reproduce the Promela feature subset the
paper's listings use:

* ``proctype`` definitions as straight-line statement lists with labels,
  program counters and local variables,
* rendezvous (capacity-0) channels with handshake send/receive,
* ``atomic`` blocks (exclusive scheduling until exit or block),
* nondeterministic ``select`` (used by ``main`` to pick tuning parameters)
  and guarded ``if`` with multiple simultaneously-true branches,
* dynamic process creation (``run``).

States are immutable and hashable so an explicit-state explorer
(:mod:`repro.core.explorer`) can do SPIN-style DFS with a visited set,
depth bounds and trail recording.

Semantics notes (deviations from SPIN, documented per DESIGN.md):

* Receives never initiate a handshake: a rendezvous transition is
  attributed to the *sender* (one global transition per matching
  sender/receiver pair).  This is observationally equivalent to SPIN's
  semantics for the models used here.
* If a process blocks inside an ``atomic`` block, atomicity is released
  (same as SPIN).
* Variables are plain Python ints/bools/tuples.  Globals and locals are
  kept in immutable mappings.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Mapping, Sequence

# ---------------------------------------------------------------------------
# Frozen mapping helpers (states must be hashable)
# ---------------------------------------------------------------------------


def freeze(d: Mapping[str, Any]) -> tuple[tuple[str, Any], ...]:
    return tuple(sorted(d.items()))


def thaw(t: tuple[tuple[str, Any], ...]) -> dict[str, Any]:
    return dict(t)


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Stmt:
    """Base statement.  Subclasses define executability and effect."""


@dataclass(frozen=True)
class Expr(Stmt):
    """Always-executable effect: ``fn(G, L)`` mutates the dict copies."""

    fn: Callable[[dict, dict], None]
    label_hint: str = "expr"


@dataclass(frozen=True)
class Guard(Stmt):
    """Blocks until ``cond(G, L)`` is true; no effect (Promela expression
    statement)."""

    cond: Callable[[dict, dict], bool]
    label_hint: str = "guard"


@dataclass(frozen=True)
class GuardedExpr(Stmt):
    """Atomic guard+effect: executable iff cond; then applies fn."""

    cond: Callable[[dict, dict], bool]
    fn: Callable[[dict, dict], None]
    label_hint: str = "guarded_expr"


@dataclass(frozen=True)
class Send(Stmt):
    """Rendezvous send: executable iff some process is at a matching Recv."""

    chan: Callable[[dict, dict], str]
    msg: Callable[[dict, dict], tuple]
    label_hint: str = "send"


@dataclass(frozen=True)
class Recv(Stmt):
    """Rendezvous receive; ``bind(G, L, msg)`` stores message fields.

    ``accept(G, L, msg) -> bool`` implements Promela's constant-matching
    receive (e.g. ``u_pex ? 0, stop``)."""

    chan: Callable[[dict, dict], str]
    bind: Callable[[dict, dict, tuple], None] = lambda G, L, m: None
    accept: Callable[[dict, dict, tuple], bool] = lambda G, L, m: True
    label_hint: str = "recv"


@dataclass(frozen=True)
class Select(Stmt):
    """Nondeterministic choice: ``var`` gets each value from ``choices``.

    This is the paper's ``select (i : 1 .. n-1)`` used to pick tuning
    parameters; the explorer branches over every value."""

    var: str
    choices: Callable[[dict, dict], Sequence[Any]]
    label_hint: str = "select"


@dataclass(frozen=True)
class IfGoto(Stmt):
    """Promela ``if``: branches is a tuple of (cond, target_label).

    All branches with a true guard are explored (nondeterminism).  Use
    ``cond=None`` for ``else`` (enabled iff no other branch is)."""

    branches: tuple[tuple[Callable[[dict, dict], bool] | None, str], ...]
    label_hint: str = "if"


@dataclass(frozen=True)
class Goto(Stmt):
    target: str
    label_hint: str = "goto"


@dataclass(frozen=True)
class Run(Stmt):
    """Spawn a new process of ``proctype`` with locals from ``args``."""

    proctype: str
    args: Callable[[dict, dict], dict]
    label_hint: str = "run"


@dataclass(frozen=True)
class AtomicEnter(Stmt):
    label_hint: str = "atomic{"


@dataclass(frozen=True)
class AtomicExit(Stmt):
    label_hint: str = "}atomic"


@dataclass(frozen=True)
class Halt(Stmt):
    """Process end."""

    label_hint: str = "end"


def atomic(*stmts: Stmt | str) -> list[Stmt | str]:
    """Wrap statements in an atomic block."""

    return [AtomicEnter(), *stmts, AtomicExit()]


# ---------------------------------------------------------------------------
# Proctypes
# ---------------------------------------------------------------------------


@dataclass
class Proctype:
    """A compiled proctype: statement list + label table."""

    name: str
    stmts: list[Stmt]
    labels: dict[str, int]

    @staticmethod
    def compile(name: str, body: Sequence) -> "Proctype":
        """Strings in ``body`` are labels for the following statement.
        Nested lists (from helpers like ``for_loop``/``sleep``/``atomic``)
        are flattened recursively."""

        stmts: list[Stmt] = []
        labels: dict[str, int] = {}

        def emit(items) -> None:
            for item in items:
                if isinstance(item, str):
                    labels[item] = len(stmts)
                elif isinstance(item, (list, tuple)):
                    emit(item)
                else:
                    stmts.append(item)

        emit(body)
        stmts.append(Halt())
        labels["__end__"] = len(stmts) - 1
        return Proctype(name, stmts, labels)


# ---------------------------------------------------------------------------
# Program state
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ProcState:
    proctype: str
    pc: int
    locals: tuple[tuple[str, Any], ...]
    alive: bool = True


@dataclass(frozen=True)
class State:
    globals: tuple[tuple[str, Any], ...]
    procs: tuple[ProcState, ...]
    atomic_owner: int = -1  # -1: none

    def get(self, name: str) -> Any:
        return thaw(self.globals)[name]


@dataclass(frozen=True)
class Transition:
    """A global transition: which pid moved, a human label, whether this
    was a *choice* (select/if branch) rather than a scheduling alternative,
    and the successor state."""

    pid: int
    label: str
    state: State
    is_choice: bool = False


class Model:
    """A closed Promela-like model: proctypes + initial process."""

    def __init__(self, proctypes: dict[str, Proctype], init_globals: dict[str, Any],
                 init_proc: str, init_locals: dict[str, Any] | None = None):
        self.proctypes = proctypes
        self._init_globals = dict(init_globals)
        self._init_proc = init_proc
        self._init_locals = dict(init_locals or {})

    def initial_state(self) -> State:
        return State(
            globals=freeze(self._init_globals),
            procs=(ProcState(self._init_proc, 0, freeze(self._init_locals)),),
        )

    # -- small-step semantics ------------------------------------------------

    def _stmt(self, ps: ProcState) -> Stmt:
        return self.proctypes[ps.proctype].stmts[ps.pc]

    def _advance(self, ps: ProcState, new_locals: dict, pc: int | None = None) -> ProcState:
        npc = ps.pc + 1 if pc is None else pc
        proctype = self.proctypes[ps.proctype]
        npc = min(npc, len(proctype.stmts) - 1)
        # Advancing into Halt kills the process immediately (no extra step).
        alive = not isinstance(proctype.stmts[npc], Halt)
        return ProcState(ps.proctype, npc, freeze(new_locals), alive=alive)

    def _label(self, proctype: str, name: str) -> int:
        return self.proctypes[proctype].labels[name]

    def successors(self, state: State) -> list[Transition]:
        """All enabled global transitions from ``state``."""

        G = thaw(state.globals)
        out: list[Transition] = []

        pids: Iterable[int]
        if state.atomic_owner >= 0:
            pids = (state.atomic_owner,)
        else:
            pids = range(len(state.procs))

        for pid in pids:
            out.extend(self._proc_transitions(state, G, pid))

        if not out and state.atomic_owner >= 0:
            # Owner blocked inside atomic: release atomicity (SPIN semantics)
            # and retry with every process schedulable.
            released = dataclasses.replace(state, atomic_owner=-1)
            return self.successors(released)
        return out

    # pylint: disable=too-many-branches,too-many-locals
    def _proc_transitions(self, state: State, G: dict, pid: int) -> list[Transition]:
        ps = state.procs[pid]
        if not ps.alive:
            return []
        stmt = self._stmt(ps)
        L = thaw(ps.locals)
        name = f"{ps.proctype}[{pid}]:{ps.pc}:{stmt.label_hint}"
        out: list[Transition] = []

        def commit(new_G: dict, new_procs: list[ProcState], label: str,
                   is_choice: bool = False, owner: int | None = None) -> None:
            new_owner = state.atomic_owner if owner is None else owner
            out.append(Transition(pid, label, State(freeze(new_G), tuple(new_procs), new_owner),
                                  is_choice))

        def with_proc(new_ps: ProcState, extra: list[ProcState] | None = None) -> list[ProcState]:
            procs = list(state.procs)
            procs[pid] = new_ps
            if extra:
                procs.extend(extra)
            return procs

        if isinstance(stmt, Halt):
            if ps.alive:
                procs = with_proc(dataclasses.replace(ps, alive=False))
                commit(dict(G), procs, name)
            return out

        if isinstance(stmt, Expr):
            G2, L2 = dict(G), dict(L)
            stmt.fn(G2, L2)
            commit(G2, with_proc(self._advance(ps, L2)), name)
        elif isinstance(stmt, Guard):
            if stmt.cond(G, L):
                commit(dict(G), with_proc(self._advance(ps, L)), name)
        elif isinstance(stmt, GuardedExpr):
            if stmt.cond(G, L):
                G2, L2 = dict(G), dict(L)
                stmt.fn(G2, L2)
                commit(G2, with_proc(self._advance(ps, L2)), name)
        elif isinstance(stmt, Select):
            for v in stmt.choices(G, L):
                L2 = dict(L)
                L2[stmt.var] = v
                commit(dict(G), with_proc(self._advance(ps, L2)),
                       f"{name}={v}", is_choice=True)
        elif isinstance(stmt, IfGoto):
            enabled = []
            has_else = None
            for cond, target in stmt.branches:
                if cond is None:
                    has_else = target
                elif cond(G, L):
                    enabled.append(target)
            if not enabled and has_else is not None:
                enabled = [has_else]
            multi = len(enabled) > 1
            for target in enabled:
                commit(dict(G), with_proc(self._advance(ps, L, pc=self._label(ps.proctype, target))),
                       f"{name}->{target}", is_choice=multi)
        elif isinstance(stmt, Goto):
            commit(dict(G), with_proc(self._advance(ps, L, pc=self._label(ps.proctype, stmt.target))),
                   name)
        elif isinstance(stmt, Run):
            child_locals = stmt.args(G, L)
            child = ProcState(stmt.proctype, 0, freeze(child_locals))
            commit(dict(G), with_proc(self._advance(ps, L), extra=[child]),
                   f"{name}:{stmt.proctype}")
        elif isinstance(stmt, AtomicEnter):
            commit(dict(G), with_proc(self._advance(ps, L)), name, owner=pid)
        elif isinstance(stmt, AtomicExit):
            commit(dict(G), with_proc(self._advance(ps, L)), name, owner=-1)
        elif isinstance(stmt, Send):
            chan = stmt.chan(G, L)
            msg = stmt.msg(G, L)
            # Find matching receivers (any process at a Recv on same channel
            # whose accept predicate passes).
            for rpid, rps in enumerate(state.procs):
                if rpid == pid or not rps.alive:
                    continue
                rstmt = self._stmt(rps)
                if not isinstance(rstmt, Recv):
                    continue
                RL = thaw(rps.locals)
                if rstmt.chan(G, RL) != chan:
                    continue
                if not rstmt.accept(G, RL, msg):
                    continue
                G2 = dict(G)
                RL2 = dict(RL)
                rstmt.bind(G2, RL2, msg)
                procs = list(state.procs)
                procs[pid] = self._advance(ps, L)
                procs[rpid] = self._advance(rps, RL2)
                commit(G2, procs, f"{name}!{chan}{msg}->pid{rpid}")
        elif isinstance(stmt, Recv):
            # Receives do not initiate handshakes (sender-attributed).
            pass
        else:  # pragma: no cover - defensive
            raise TypeError(f"unknown statement {stmt}")
        return out


__all__ = [
    "Expr", "Guard", "GuardedExpr", "Send", "Recv", "Select", "IfGoto",
    "Goto", "Run", "AtomicEnter", "AtomicExit", "Halt", "atomic",
    "Proctype", "ProcState", "State", "Transition", "Model",
    "freeze", "thaw",
]
