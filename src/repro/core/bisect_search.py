"""Minimal-termination-time search (Step 3, Fig. 1 bisection).

``cex_oracle(T)`` plays the role of the paper's predicate ``C_ex(T)``:
it runs a verification of Φ_o(T) and returns the counterexample (or
``None``).  Any engine works as oracle — the explicit-state explorer,
the swarm, or the vectorized sweep.

The paper's Fig. 1 bisects on T; we add *witness acceleration*: every
counterexample reaching time ``t ≤ T`` lets us jump the upper bound to
``t`` directly (each counterexample is a feasible schedule, so ``T_min ≤
t``).  The loop ends when Φ_o(T_min − 1) is verified (no counterexample)
— exactly the paper's termination condition."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from .counterexample import Counterexample


@dataclass
class BisectionLog:
    queries: list[tuple[int, bool, int | None]] = field(default_factory=list)

    def record(self, T: int, found: bool, t: int | None) -> None:
        self.queries.append((T, found, t))


@dataclass
class BisectionResult:
    t_min: int
    witness: Counterexample
    log: BisectionLog
    oracle_calls: int


def find_minimal_time(
    cex_oracle: Callable[[int], Counterexample | None],
    *,
    t_ini: int,
    t_max_doublings: int = 20,
) -> BisectionResult:
    """Find T_min = the minimal reachable termination time.

    ``t_ini`` comes from a simulation run (the paper suggests SPIN's
    simulation mode); if no counterexample exists at ``t_ini`` the bound
    is doubled (the program is slower than the simulated estimate)."""

    log = BisectionLog()
    calls = 0

    # Establish a feasible upper bound.
    T = t_ini
    witness = None
    for _ in range(t_max_doublings):
        calls += 1
        witness = cex_oracle(T)
        log.record(T, witness is not None, witness.time if witness else None)
        if witness is not None:
            break
        T = max(T * 2, T + 1)
    if witness is None:
        raise RuntimeError(f"no terminating execution found up to T={T}")

    best = witness
    hi = best.time          # T_min <= hi (feasible)
    lo = 0                  # largest T proven infeasible is lo-1 => T_min >= lo

    # Invariant: lo <= T_min <= hi;  Cex(hi) known-found (== best).
    while lo < hi:
        mid = (lo + hi) // 2
        calls += 1
        w = cex_oracle(mid)
        log.record(mid, w is not None, w.time if w else None)
        if w is not None:
            best = w if w.time < best.time else best
            hi = w.time     # witness acceleration
        else:
            lo = mid + 1

    return BisectionResult(t_min=hi, witness=best, log=log, oracle_calls=calls)


__all__ = ["find_minimal_time", "BisectionResult", "BisectionLog"]
