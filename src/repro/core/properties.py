"""Property formulations (Step 2 of the counterexample method).

The paper expresses the tuning objective as LTL over the model:

* over-time      Φ_o = G(FIN → time > T)
  — "whenever the program terminates, more than T time units have
  passed".  A counterexample is an execution reaching ``FIN`` with
  ``time ≤ T``; its configuration is a candidate tuning.
* non-termination Φ_t = G(¬FIN)
  — used in swarm mode (§5): any path reaching FIN is a counterexample
  and carries a termination time.

For the state-reachability engine these reduce to *violation predicates*
over a state's globals (both formulas are of the form ``G p`` with a
state predicate ``p``, so a counterexample is exactly a reachable state
with ``¬p``).  ``trace_satisfies`` provides the genuine LTL-over-a-trace
check used by tests to confirm the reduction is faithful.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Sequence


@dataclass(frozen=True)
class OverTime:
    """Φ_o = G(FIN → time > T)."""

    T: int
    fin_var: str = "FIN"
    time_var: str = "time"

    def state_ok(self, G: dict) -> bool:
        return (not G[self.fin_var]) or (G[self.time_var] > self.T)

    def violates(self, G: dict) -> bool:
        return bool(G[self.fin_var]) and G[self.time_var] <= self.T


@dataclass(frozen=True)
class NonTermination:
    """Φ_t = G(¬FIN)."""

    fin_var: str = "FIN"

    def state_ok(self, G: dict) -> bool:
        return not G[self.fin_var]

    def violates(self, G: dict) -> bool:
        return bool(G[self.fin_var])


def trace_satisfies(prop, trace: Sequence[dict]) -> bool:
    """Evaluate ``G p`` over a concrete finite trace of global states."""

    return all(prop.state_ok(G) for G in trace)


__all__ = ["OverTime", "NonTermination", "trace_satisfies"]
