"""Counterexample analysis (Step 4 of the method).

SPIN writes a ``.trail`` file which is then re-simulated to read off the
tuning parameters; here the explorer already returns the violating
state's globals and the transition trail.  This module packages that as a
:class:`Counterexample`, supports replay-validation against the model
(the analogue of SPIN's guided simulation), and extracts the tuning
configuration.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from .explorer import Terminal, replay
from .promela import Model


@dataclass(frozen=True)
class Counterexample:
    time: int
    config: dict[str, Any]
    trail: tuple[str, ...]
    depth: int

    @staticmethod
    def from_terminal(term: Terminal,
                      config_vars: tuple[str, ...] = ("WG", "TS")) -> "Counterexample":
        return Counterexample(
            time=term.globals["time"],
            config={k: term.globals[k] for k in config_vars if k in term.globals},
            trail=term.trail,
            depth=term.depth,
        )

    def validate(self, model: Model, *, fin_var: str = "FIN",
                 time_var: str = "time") -> bool:
        """Replay the trail through the model and confirm it reaches the
        same terminating time — the machine-checked analogue of running
        SPIN's trail simulation."""

        if not self.trail:
            return False
        end = replay(model, self.trail)
        G = dict(end.globals)
        return bool(G[fin_var]) and G[time_var] == self.time


__all__ = ["Counterexample"]
